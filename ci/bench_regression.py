#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh BENCH_dist_step.json against the
committed baseline and fail on a >tolerance regression.

Absolute milliseconds are meaningless across heterogeneous CI hosts, so the
gate compares host-normalized and scale-free metrics:

* ``overlap.pipelined_step_per_task`` — the pipelined K=4 batch makespan in
  units of one measured task's compute (the primary "makespan" metric;
  dividing by the same run's calibrated task time cancels host speed);
* ``overlap.speedup`` — serialized / pipelined makespan ratio;
* ``grad_bytes_saved_vs_full`` — measured wire savings (deterministic given
  the seeds, so compared with a tiny absolute slack);
* ``calibration.makespan_drift`` — modeled-vs-measured drift after one
  calibration epoch (absolute slack; the bench itself hard-asserts <= 0.20);
* ``ring.flatness_k2_to_k8`` / ``ring.star_growth_k2_to_k8`` — aggregator
  gradient-socket scaling of the ring vs star exchange (byte counts are
  deterministic given the seeds, so absolute slack);
* ``compression.int8_ratio`` / ``compression.topk10_ratio`` /
  ``compression.ring_int8_chain_ratio`` — measured byte reduction of the
  compressed wire modes vs f32 (deterministic, absolute slack);
* ``tracing.overhead_ratio`` — traced/untraced mean step time (the bench
  itself hard-asserts <= 1.05; the gate keeps a refreshed baseline honest).

A baseline carrying ``"provisional": true`` (committed before any trusted CI
run existed) reports violations as warnings and exits 0. The committed
baseline mirrors the BENCH_dist_step.json schema; ``--refresh`` overwrites it
with a fresh artifact (run it on a green CI run's artifact to tighten the
gate from the bench's hard-assert floors to measured values). Usage:

    python3 ci/bench_regression.py FRESH BASELINE [--tolerance 0.15] [--refresh]
"""

import argparse
import json
import sys

# (dotted JSON path, better-direction, comparison kind)
# kind "relative" uses --tolerance; "absolute:X" uses slack X.
CHECKS = [
    ("overlap.pipelined_step_per_task", "lower", "relative"),
    ("overlap.speedup", "higher", "relative"),
    ("grad_bytes_saved_vs_full", "higher", "absolute:0.01"),
    ("calibration.makespan_drift", "lower", "absolute:0.05"),
    ("ring.flatness_k2_to_k8", "lower", "absolute:0.10"),
    ("ring.star_growth_k2_to_k8", "higher", "absolute:0.10"),
    ("compression.int8_ratio", "higher", "absolute:0.10"),
    ("compression.topk10_ratio", "higher", "absolute:0.25"),
    ("compression.ring_int8_chain_ratio", "higher", "absolute:0.25"),
    ("tracing.overhead_ratio", "lower", "absolute:0.03"),
]


def lookup(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly generated BENCH_dist_step.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative regression tolerance (default 0.15)")
    ap.add_argument("--refresh", action="store_true",
                    help="overwrite BASELINE with FRESH instead of comparing "
                         "(tightens the gate to this run's measured values)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)

    if args.refresh:
        fresh["note"] = ("Measured baseline refreshed by ci/bench_regression.py "
                         "--refresh from a green run's BENCH_dist_step.json. "
                         "Gate compares only the CHECKS paths; timing-free "
                         "metrics are deterministic given the seeds.")
        with open(args.baseline, "w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"refreshed {args.baseline} from {args.fresh}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)

    provisional = bool(base.get("provisional", False))
    tol = args.tolerance
    failures = []

    for path, direction, kind in CHECKS:
        fv = lookup(fresh, path)
        bv = lookup(base, path)
        if fv is None or bv is None:
            print(f"SKIP       {path}: missing "
                  f"({'fresh' if fv is None else 'baseline'})")
            continue
        if kind == "relative":
            slack = abs(bv) * tol
        else:
            slack = float(kind.split(":", 1)[1])
        if direction == "lower":
            ok = fv <= bv + slack
            verdict = f"fresh {fv:.4f} <= baseline {bv:.4f} + {slack:.4f}"
        else:
            ok = fv >= bv - slack
            verdict = f"fresh {fv:.4f} >= baseline {bv:.4f} - {slack:.4f}"
        status = "OK" if ok else ("WARN" if provisional else "REGRESSION")
        print(f"{status:10} {path}: {verdict}")
        if not ok and not provisional:
            failures.append(path)

    if provisional:
        print("baseline is provisional: violations reported as warnings only; "
              "commit a CI-produced BENCH_dist_step.json over the baseline to "
              "arm the gate")
        return 0
    if failures:
        print(f"bench regression in: {', '.join(failures)}", file=sys.stderr)
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
