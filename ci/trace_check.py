#!/usr/bin/env python3
"""Validate a ``--trace-out`` artifact from a live dist run.

The merged Chrome trace-event JSON is a CI-gated contract: CI runs a real
K-worker TCP ring with ``--trace-out`` and this script asserts the artifact
a human would drop into Perfetto actually carries the full step timeline:

* well-formed JSON with a non-empty ``traceEvents`` array and a numeric
  ``truncatedEvents`` counter;
* one named lane (a ``process_name`` metadata event) per process: the
  aggregator (pid 0) plus at least ``--workers`` worker lanes;
* worker lanes carry real work: ``compute`` spans recorded on the worker
  side of the transport, not just aggregator bookkeeping;
* every required category present (``--require-cats``, comma-separated;
  the default covers any topology — ring runs add ``ring``, star runs
  add ``agg``/``codec``);
* complete-span events (``ph == "X"``) have a numeric ``dur >= 0``;
* non-metadata timestamps are monotone non-decreasing — the cross-process
  clock normalization and merge sort actually happened.

Usage:

    python3 ci/trace_check.py trace.json --workers 4 \
        [--require-cats compute,step,ring,net]
"""

import argparse
import json
import sys


def fail(msg):
    print(f"trace_check: FAIL: {msg}", file=sys.stderr)
    return 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="merged Chrome trace-event JSON (--trace-out)")
    ap.add_argument("--workers", type=int, required=True,
                    help="worker count K of the traced run (expects K+1 lanes)")
    ap.add_argument("--require-cats", default="compute,step,net",
                    help="comma-separated categories that must appear")
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return fail("traceEvents missing or empty")
    truncated = doc.get("truncatedEvents")
    if not isinstance(truncated, (int, float)):
        return fail("truncatedEvents counter missing")

    lanes = set()
    named_lanes = set()
    compute_lanes = set()
    cats = set()
    spans = 0
    last_ts = float("-inf")
    for i, e in enumerate(events):
        ph = e.get("ph")
        pid = e.get("pid")
        if not isinstance(pid, int):
            return fail(f"event {i}: non-integer pid {pid!r}")
        lanes.add(pid)
        if ph == "M":
            if e.get("name") == "process_name":
                named_lanes.add(pid)
            continue
        cat = e.get("cat")
        if not cat:
            return fail(f"event {i}: missing category")
        cats.add(cat)
        if cat == "compute":
            compute_lanes.add(pid)
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            return fail(f"event {i}: non-numeric ts {ts!r}")
        if ts < last_ts:
            return fail(f"event {i}: ts {ts} < previous {last_ts} — merge not sorted")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                return fail(f"event {i}: span with bad dur {dur!r}")
            spans += 1

    want_lanes = args.workers + 1
    if len(lanes) < want_lanes:
        return fail(f"expected >= {want_lanes} lanes (aggregator + {args.workers} "
                    f"workers), saw pids {sorted(lanes)}")
    if 0 not in lanes:
        return fail("aggregator lane (pid 0) missing")
    unnamed = lanes - named_lanes
    if unnamed:
        return fail(f"lanes without process_name metadata: {sorted(unnamed)}")
    worker_compute = compute_lanes - {0}
    if len(worker_compute) < args.workers:
        return fail(f"expected compute spans on {args.workers} worker lanes, "
                    f"saw them on {sorted(worker_compute)}")
    if spans == 0:
        return fail("no complete spans (ph X) recorded")
    missing = [c for c in args.require_cats.split(",") if c and c not in cats]
    if missing:
        return fail(f"missing categories {missing} (saw {sorted(cats)})")

    print(f"trace_check: OK: {len(events)} events, {spans} spans, "
          f"{len(lanes)} lanes {sorted(lanes)}, categories {sorted(cats)}, "
          f"{int(truncated)} truncated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
