//! Heterogeneous-cluster scenario (paper §IV-D, Tables VII & VIII):
//! D2FT on a mix of large/small-memory devices and fast/slow devices.
//!
//!     cargo run --release --example heterogeneity
//!     cargo run --release --example heterogeneity -- --backend xla  # needs artifacts

use d2ft::backend::{provider_for, BackendKind, BackendProvider};
use d2ft::cluster::{ExecTimeModel, HeteroSpec};
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::metrics::pct;
use d2ft::schedule::Budget;
use d2ft::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    d2ft::util::log::init();
    let args = Cli::new("heterogeneity", "D2FT on heterogeneous devices")
        .flag("backend", "native", "native | xla")
        .flag("artifacts", "artifacts", "artifacts dir (xla backend only)")
        .flag("batches", "20", "fine-tuning batches")
        .flag("large-memory", "5", "devices hosting 2 heads + a merged FFN share")
        .flag("high-speed", "5", "devices running 3pf+1po instead of 2pf+2po")
        .parse()?;

    let provider = provider_for(
        BackendKind::parse(args.get("backend"))?,
        std::path::Path::new(args.get("artifacts")),
    )?;
    let mc = provider.model_config().clone();
    let batches = args.get_usize("batches")?;
    let base = TrainerConfig::builder()
        .dataset(SyntheticKind::Cifar100Like)
        .scheduler(SchedulerKind::D2ft)
        .budget(Budget::uniform(5, 2, 2))
        .batches(batches)
        .build()?;

    // Memory heterogeneity: merged 2-head subnets.
    let n_large = args.get_usize("large-memory")?;
    let mem_spec = HeteroSpec::memory(n_large);
    let part = mem_spec.partition(&mc);
    println!(
        "memory heterogeneity: {n_large} large devices -> {} devices total (vs {})",
        part.n_subnets() + 2,
        mc.body_subnets() + 2
    );
    let mut mem_cfg = base.clone();
    mem_cfg.hetero = Some(mem_spec);
    let mut trainer = Trainer::new(provider.as_ref(), mem_cfg)?;
    let r_mem = trainer.run()?;
    println!(
        "  top-1 {} | workload var {:.3} | makespan {:.2}ms",
        pct(r_mem.test_top1),
        r_mem.workload_variance,
        r_mem.makespan_ms
    );

    // Computational heterogeneity: per-device budget overrides.
    let n_fast = args.get_usize("high-speed")?;
    let cpu_spec = HeteroSpec::compute(n_fast);
    println!("compute heterogeneity: {n_fast} high-speed devices (3pf+1po), rest slow (2pf+2po)");
    let mut cpu_cfg = base.clone();
    cpu_cfg.hetero = Some(cpu_spec.clone());
    let mut trainer = Trainer::new(provider.as_ref(), cpu_cfg)?;
    let r_cpu = trainer.run()?;
    println!(
        "  top-1 {} | compute {} | comm {}",
        pct(r_cpu.test_top1),
        pct(r_cpu.compute_fraction),
        pct(r_cpu.comm_fraction)
    );
    // Show the exec-time view: fast devices absorb bigger budgets at
    // equal wall time (the paper's balancing argument).
    let model = ExecTimeModel::paper();
    let slow = model.time_ms(d2ft::schedule::Op::Full, 2)
        + model.time_ms(d2ft::schedule::Op::ForwardOnly, 2);
    let fast = (model.time_ms(d2ft::schedule::Op::Full, 3)
        + model.time_ms(d2ft::schedule::Op::ForwardOnly, 1))
        / cpu_spec.speed_factor;
    println!(
        "  modelled per-batch device time: slow {slow:.2}ms vs fast {fast:.2}ms (speed {}x)",
        cpu_spec.speed_factor
    );

    // Homogeneous reference.
    let mut trainer = Trainer::new(provider.as_ref(), base)?;
    let r0 = trainer.run()?;
    println!("homogeneous reference: top-1 {}", pct(r0.test_top1));
    println!(
        "paper shape (Tables VII/VIII): heterogeneity leaves accuracy ~unchanged ({} / {} vs {})",
        pct(r_mem.test_top1),
        pct(r_cpu.test_top1),
        pct(r0.test_top1)
    );
    Ok(())
}
