//! Quickstart: open a compute backend, schedule one batch with D2FT, and
//! run it through the fused trainstep — the whole stack in ~60 lines,
//! with zero setup on the default native backend.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --backend xla   # needs artifacts
//!
//! Flags: --backend native|xla --artifacts <dir>

use d2ft::backend::{provider_for, Backend, BackendKind, BackendProvider, BackendSel};
use d2ft::cluster::CostModel;
use d2ft::data::{Batcher, DatasetSpec, SyntheticKind};
use d2ft::partition::Partition;
use d2ft::schedule::bilevel::BiLevel;
use d2ft::schedule::{Budget, Op, Scheduler};
use d2ft::scores::{ScoreBook, ScoreConfig};
use d2ft::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    d2ft::util::log::init();
    let args = Cli::new("quickstart", "D2FT quickstart (one scheduled batch)")
        .flag("backend", "native", "native | xla")
        .flag("artifacts", "artifacts", "artifacts dir (xla backend only)")
        .parse()?;
    let provider = provider_for(
        BackendKind::parse(args.get("backend"))?,
        std::path::Path::new(args.get("artifacts")),
    )?;
    let mut backend = provider.open(&BackendSel::full(7))?;
    let mc = backend.config().clone();
    println!(
        "backend {}: ViT dim {} / {} blocks / {} heads -> {} schedulable subnets",
        backend.label(), mc.dim, mc.depth, mc.heads, mc.body_subnets()
    );

    // One batch of 5 micro-batches from the CIFAR-100-like dataset.
    let mb = backend.micro_batch();
    let data = DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, 5 * mb, 7)
        .generate("train");
    let mut batcher = Batcher::new(&data, mb, 5, 1);
    let micros = batcher.next_batch().unwrap();

    // Contribution scores for this batch (fisher / gradmag / taylor /
    // weightmag per subnet), via the backend's score probe.
    let part = Partition::per_head(&mc);
    let mut probes = Vec::new();
    for (x, y) in &micros {
        probes.push(backend.score_probe(x, y)?);
    }
    let book = ScoreBook::from_probes(&part, &probes);

    // D2FT bi-level knapsack at the paper's 60%-compute budget
    // (3 p_f + 2 p_s out of 5 micro-batches per device).
    let budget = Budget::uniform(5, 3, 0);
    let mut sched = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let table = sched.schedule(&book, &budget);
    let n_full: usize = (0..table.n_subnets).map(|k| table.count_row(k, Op::Full)).sum();
    let n_skip: usize = (0..table.n_subnets).map(|k| table.count_row(k, Op::Shortcut)).sum();
    println!(
        "schedule: {} p_f / {} p_s cells over {} subnets x 5 micro-batches",
        n_full, n_skip, table.n_subnets
    );

    // Execute: one fused fwd+bwd+SGD step per micro-batch, masked per
    // the schedule. Python is nowhere in this loop.
    for (i, (x, y)) in micros.iter().enumerate() {
        let masks = table.masks_for_micro(&part, i);
        let out = backend.step(x, y, &masks, 0.03)?;
        println!("micro-batch {i}: loss {:.4}", out.loss);
    }
    println!("quickstart OK");
    Ok(())
}
