//! Quickstart: load the AOT artifacts, schedule one batch with D2FT, and
//! run it through the fused trainstep — the whole three-layer stack in
//! ~60 lines.
//!
//!     make artifacts && cargo run --release --example quickstart

use d2ft::cluster::CostModel;
use d2ft::data::{Batcher, DatasetSpec, SyntheticKind};
use d2ft::partition::Partition;
use d2ft::runtime::{ArtifactRegistry, ParamStore, Session, TrainState};
use d2ft::schedule::bilevel::BiLevel;
use d2ft::schedule::{Budget, Op, Scheduler};
use d2ft::scores::{ScoreBook, ScoreConfig};

fn main() -> anyhow::Result<()> {
    d2ft::util::log::init();
    // L2/L1 artifacts: HLO text lowered once by python/compile/aot.py.
    let registry = ArtifactRegistry::open_default()?;
    let manifest = &registry.full_manifest;
    let mc = &manifest.config;
    println!(
        "model: ViT dim {} / {} blocks / {} heads -> {} schedulable subnets",
        mc.dim, mc.depth, mc.heads, mc.body_subnets()
    );

    // Runtime state: init params + zero momentum, as PJRT literals.
    let session = Session::new(&registry, manifest)?;
    let store = ParamStore::load(manifest, registry.dir())?;
    let mut state = TrainState::new(&store)?;

    // One batch of 5 micro-batches from the CIFAR-100-like dataset.
    let data = DatasetSpec::preset(
        SyntheticKind::Cifar100Like,
        mc.img_size,
        5 * manifest.micro_batch,
        7,
    )
    .generate("train");
    let mut batcher = Batcher::new(&data, manifest.micro_batch, 5, 1);
    let micros = batcher.next_batch().unwrap();

    // Contribution scores for this batch (fisher / gradmag / taylor /
    // weightmag per subnet), via the score-probe artifact.
    let part = Partition::per_head(mc);
    let mut probes = Vec::new();
    for (x, y) in &micros {
        probes.push(session.probe_scores(&state, &session.x_literal(x)?, &session.y_literal(y)?)?);
    }
    let book = ScoreBook::from_probes(&part, &probes);

    // D2FT bi-level knapsack at the paper's 60%-compute budget
    // (3 p_f + 2 p_s out of 5 micro-batches per device).
    let budget = Budget::uniform(5, 3, 0);
    let mut sched = BiLevel::new(ScoreConfig::default(), CostModel::paper());
    let table = sched.schedule(&book, &budget);
    let n_full: usize = (0..table.n_subnets).map(|k| table.count_row(k, Op::Full)).sum();
    let n_skip: usize = (0..table.n_subnets).map(|k| table.count_row(k, Op::Shortcut)).sum();
    println!(
        "schedule: {} p_f / {} p_s cells over {} subnets x 5 micro-batches",
        n_full, n_skip, table.n_subnets
    );

    // Execute: one fused fwd+bwd+SGD step per micro-batch, masked per
    // the schedule. Python is nowhere in this loop.
    for (i, (x, y)) in micros.iter().enumerate() {
        let masks = table.masks_for_micro(&part, i);
        let out = session.step(
            &mut state,
            &session.x_literal(x)?,
            &session.y_literal(y)?,
            &masks,
            0.03,
        )?;
        println!("micro-batch {i}: loss {:.4}", out.loss);
    }
    println!("quickstart OK");
    Ok(())
}
