//! End-to-end driver (DESIGN.md "End-to-end validation"): fine-tune the
//! scaled ViT on the synthetic CIFAR-100-like corpus under D2FT's 68%
//! compute budget for a few hundred steps, logging the loss curve and
//! periodic test top-1, then compare against standard fine-tuning.
//!
//!     cargo run --release --example train_e2e
//!     cargo run --release --example train_e2e -- --backend xla  # needs artifacts
//!
//! Flags: --backend native|xla --batches N --dataset c10|c100|cars
//!        --budget-full K --budget-fwd K
//! The recorded run lives in EXPERIMENTS.md §End-to-end.

use d2ft::backend::{provider_for, BackendKind, BackendProvider};
use d2ft::cluster::ExecMode;
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
use d2ft::data::SyntheticKind;
use d2ft::metrics::pct;
use d2ft::schedule::Budget;
use d2ft::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    d2ft::util::log::init();
    let args = Cli::new("train_e2e", "D2FT end-to-end training driver")
        .flag("backend", "native", "native | xla")
        .flag("artifacts", "artifacts", "artifacts dir (xla backend only)")
        .flag("batches", "60", "fine-tuning batches (x5 micro-steps each)")
        .flag("pretrain-batches", "15", "synthetic pre-training batches")
        .flag("dataset", "c100", "c10 | c100 | cars")
        .flag("budget-full", "3", "p_f micro-batches per device")
        .flag("budget-fwd", "1", "p_o micro-batches per device")
        .flag("train-size", "480", "training examples")
        .flag("lr", "0.04", "learning rate")
        .flag("seed", "17", "seed")
        .switch("skip-standard", "skip the standard-FT comparison run")
        .parse()?;

    let provider = provider_for(
        BackendKind::parse(args.get("backend"))?,
        std::path::Path::new(args.get("artifacts")),
    )?;
    let budget = Budget::uniform(5, args.get_usize("budget-full")?, args.get_usize("budget-fwd")?);
    let base = TrainerConfig::builder()
        .dataset(SyntheticKind::parse(args.get("dataset"))?)
        .train_size(args.get_usize("train-size")?)
        .test_size(160)
        .micros_per_batch(5)
        .batches(args.get_usize("batches")?)
        .lr(args.get_f32("lr")?)
        .budget(budget.clone())
        .scheduler(SchedulerKind::D2ft)
        .exec(ExecMode::Parallel { workers: 0 })
        .seed(args.get_u64("seed")?)
        .pretrain_batches(args.get_usize("pretrain-batches")?)
        .eval_every(10)
        .update(UpdateMode::PerMicro)
        .build()?;

    println!(
        "== D2FT ({}) @ compute {} / comm {} ==",
        provider.label(),
        pct(budget.compute_fraction(0.4)),
        pct(budget.comm_fraction())
    );
    let mut trainer = Trainer::new(provider.as_ref(), base.clone())?;
    let r = trainer.run()?;

    println!("\nloss curve (per micro-step, EMA-smoothed):");
    let mut ema = d2ft::metrics::Ema::new(0.08);
    for (i, &l) in r.loss_curve.iter().enumerate() {
        let v = ema.push(l as f64);
        if i % 25 == 0 || i + 1 == r.loss_curve.len() {
            let bars = (v * 12.0).clamp(0.0, 72.0) as usize;
            println!("  step {i:>4}  loss {v:7.4}  {}", "#".repeat(bars));
        }
    }
    if !r.eval_curve.is_empty() {
        println!("\ntest top-1 during training:");
        for (b, top1) in &r.eval_curve {
            println!("  batch {b:>4}  top-1 {}", pct(*top1));
        }
    }
    println!(
        "\nD2FT final: top-1 {} | train loss {:.4} | compute {} | comm {} | workload var \
         {:.3} | {:.0}s",
        pct(r.test_top1),
        r.final_train_loss,
        pct(r.compute_fraction),
        pct(r.comm_fraction),
        r.workload_variance,
        r.wall_s
    );

    if !args.get_bool("skip-standard") {
        println!("\n== Standard fine-tuning (100% budget) ==");
        let mut std_cfg = base;
        std_cfg.scheduler = SchedulerKind::Standard;
        std_cfg.eval_every = 0;
        let mut trainer = Trainer::new(provider.as_ref(), std_cfg)?;
        let rs = trainer.run()?;
        println!(
            "Standard final: top-1 {} | train loss {:.4} | {:.0}s",
            pct(rs.test_top1),
            rs.final_train_loss,
            rs.wall_s
        );
        println!(
            "\npaper shape check: D2FT within a few points of Standard at ~2/3 cost ({} vs {})",
            pct(r.test_top1),
            pct(rs.test_top1)
        );
    }
    Ok(())
}
