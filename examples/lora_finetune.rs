//! D2FT-LoRA (paper §II-D): fine-tune with frozen base weights and
//! per-head LoRA adapters on Q/K/V, scheduling the adapter branches with
//! the same bi-level knapsack.
//!
//!     cargo run --release --example lora_finetune
//!     cargo run --release --example lora_finetune -- --backend xla  # needs artifacts
//!
//! Flags: --backend native|xla --rank N --batches N --budget-full K --budget-fwd K

use d2ft::backend::{provider_for, BackendKind, BackendProvider};
use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig};
use d2ft::data::SyntheticKind;
use d2ft::metrics::pct;
use d2ft::schedule::Budget;
use d2ft::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    d2ft::util::log::init();
    let args = Cli::new("lora_finetune", "D2FT-LoRA fine-tuning")
        .flag("backend", "native", "native | xla")
        .flag("artifacts", "artifacts", "artifacts dir (xla backend only)")
        .flag("batches", "30", "fine-tuning batches")
        .flag("rank", "0", "LoRA rank (0 = the backend's standard rank)")
        .flag("budget-full", "3", "p_f micro-batches per device")
        .flag("budget-fwd", "0", "p_o micro-batches per device")
        .parse()?;

    let provider = provider_for(
        BackendKind::parse(args.get("backend"))?,
        std::path::Path::new(args.get("artifacts")),
    )?;
    anyhow::ensure!(!provider.lora_ranks().is_empty(), "backend advertises no LoRA ranks");
    let rank = match args.get_usize("rank")? {
        0 => provider.lora_standard_rank(),
        r => r,
    };
    let mc = provider.model_config();
    println!(
        "LoRA rank {rank} on the {} backend: A/B x Q/K/V adapters x {} heads x {} blocks",
        provider.label(),
        mc.heads,
        mc.depth
    );

    let budget = Budget::uniform(5, args.get_usize("budget-full")?, args.get_usize("budget-fwd")?);
    let cfg = TrainerConfig::builder()
        .dataset(SyntheticKind::CarsLike)
        .scheduler(SchedulerKind::D2ft)
        .budget(budget.clone())
        .batches(args.get_usize("batches")?)
        .lr(0.05)
        .eval_every(10)
        .lora_rank(rank)
        .build()?;
    println!(
        "D2FT-LoRA on Cars-like @ compute {} (of standard LoRA) / comm {}",
        pct(budget.compute_fraction(0.4)),
        pct(budget.comm_fraction())
    );
    let mut trainer = Trainer::new(provider.as_ref(), cfg.clone())?;
    let r = trainer.run()?;
    println!(
        "D2FT-LoRA:     top-1 {} | train loss {:.4} | workload var {:.3}",
        pct(r.test_top1), r.final_train_loss, r.workload_variance
    );

    // Standard LoRA reference at the same rank (100% budget).
    let mut std_cfg = cfg;
    std_cfg.scheduler = SchedulerKind::Standard;
    std_cfg.budget = Budget::uniform(5, 5, 0);
    std_cfg.eval_every = 0;
    let mut trainer = Trainer::new(provider.as_ref(), std_cfg)?;
    let rs = trainer.run()?;
    println!("Standard LoRA: top-1 {} | train loss {:.4}", pct(rs.test_top1), rs.final_train_loss);
    println!(
        "paper shape: D2FT-LoRA within ~4-6 points of standard LoRA at 60% cost ({} vs {})",
        pct(r.test_top1),
        pct(rs.test_top1)
    );
    Ok(())
}
