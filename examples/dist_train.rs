//! Distributed data-parallel fine-tuning with masked-gradient exchange:
//! run the same D2FT schedule serially and on K worker replicas, verify
//! the loss trajectories agree bitwise, and print the *measured* bytes
//! on the wire against the full (unmasked) schedule.
//!
//!     cargo run --release --example dist_train
//!     cargo run --release --example dist_train -- --workers 8 --exchange ps
//!     cargo run --release --example dist_train -- --transport tcp
//!
//! Flags: --workers K --exchange allreduce|ps --batches N
//!        --model mini|small --threads T --no-overlap
//!        --transport channel|tcp (tcp = real loopback sockets;
//!        bitwise identical to the in-process channel path)

#[cfg(not(feature = "native"))]
fn main() {
    eprintln!("dist_train requires the default `native` feature");
}

#[cfg(feature = "native")]
fn main() -> anyhow::Result<()> {
    use d2ft::backend::native::{NativeProvider, NativeSpec};
    use d2ft::coordinator::{SchedulerKind, Trainer, TrainerConfig, UpdateMode};
    use d2ft::data::SyntheticKind;
    use d2ft::dist::{DistConfig, DistTrainer, ExchangeMode, SpawnMode, TransportKind};
    use d2ft::metrics::{fmt_bytes, pct};
    use d2ft::schedule::Budget;
    use d2ft::util::cli::Cli;

    d2ft::util::log::init();
    let args = Cli::new("dist_train", "D2FT distributed trainer demo")
        .flag("workers", "4", "worker replica threads")
        .flag("exchange", "allreduce", "allreduce | ps")
        .flag("transport", "channel", "channel (in-process) | tcp (loopback sockets)")
        .flag("batches", "6", "fine-tuning batches")
        .flag("model", "mini", "native model preset: mini | small")
        .flag("threads", "1", "matmul kernel threads (0 = auto)")
        .switch("no-overlap", "serialize encode+upload after compute (default pipelines)")
        .parse()?;
    let mut spec = NativeSpec::preset(args.get("model"))?;
    spec.threads = args.get_usize("threads")?;
    let provider = NativeProvider::new(spec);
    let workers = args.get_usize("workers")?.max(1);
    let cfg = TrainerConfig::builder()
        .dataset(SyntheticKind::Cifar100Like)
        .scheduler(SchedulerKind::D2ft)
        // The paper's 50%-communication budget: 2 p_f + 1 p_o of 5.
        .budget(Budget::uniform(5, 2, 1))
        .train_size(240)
        .test_size(48)
        .batches(args.get_usize("batches")?)
        .pretrain_batches(2)
        .update(UpdateMode::BatchAccum)
        .build()?;

    // Serial reference (same batch-accumulation semantics).
    let mut serial = Trainer::new(&provider, cfg.clone())?;
    let rs = serial.run()?;

    // Distributed run: K live replicas, masked-gradient exchange,
    // pipelined encode+upload unless --no-overlap. With --transport
    // tcp the workers connect over real loopback sockets (as threads —
    // this example binary has no worker subcommand to fork; `repro
    // train --dist --transport tcp` demonstrates the subprocess path).
    let transport = match TransportKind::parse(args.get("transport"))? {
        TransportKind::Tcp { listen, .. } => {
            TransportKind::Tcp { listen, spawn: SpawnMode::Threads }
        }
        kind => kind,
    };
    let dcfg = DistConfig::builder(cfg, workers)
        .exchange(ExchangeMode::parse(args.get("exchange"))?)
        .transport(transport)
        .overlap(!args.get_bool("no-overlap"))
        .build()?;
    let mut dist = DistTrainer::new(&provider, dcfg)?;
    let rd = dist.run()?;

    let bitwise = rs
        .loss_curve
        .iter()
        .zip(&rd.train.loss_curve)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!();
    println!(
        "serial    loss {:.4}  top-1 {}",
        rs.final_train_loss,
        pct(rs.test_top1)
    );
    println!(
        "dist x{}   loss {:.4}  top-1 {}  ({})",
        rd.n_workers,
        rd.train.final_train_loss,
        pct(rd.train.test_top1),
        rd.exchange
    );
    println!("bitwise identical trajectories: {bitwise}");
    anyhow::ensure!(bitwise, "serial and distributed trajectories diverged");
    println!();
    println!(
        "gradient uplink: {} measured vs {} unmasked -> {} saved on the wire",
        fmt_bytes(rd.wire.up_bytes),
        fmt_bytes(rd.wire.dense_up_bytes),
        pct(rd.grad_savings)
    );
    println!(
        "downlink: {} ({} broadcasts), straggler {:.3}ms/batch, step {:.3}ms",
        fmt_bytes(rd.wire.down_bytes),
        rd.wire.down_msgs,
        rd.train.straggler_ms,
        rd.mean_step_ms
    );
    println!(
        "transport {}: {} out / {} in across {} frames",
        rd.transport,
        fmt_bytes(rd.socket.bytes_sent),
        fmt_bytes(rd.socket.bytes_recv),
        rd.socket.frames_sent + rd.socket.frames_recv
    );
    println!("dist_train OK");
    Ok(())
}
