"""L2 entry points lowered by aot.py: trainstep / eval / score-probe.

Each function here becomes one HLO artifact. The whole fwd+bwd+SGD update
is a single fused XLA program so the rust hot loop does exactly one PJRT
execute per (micro-batch, step) — no host round-trips between phases
(DESIGN.md §Perf, L2).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .vit import ViTConfig, forward, init_params, loss_fn

MOMENTUM = 0.9  # SGD momentum, paper §IV-A ("SGD optimizer with momentum")


def param_names(cfg: ViTConfig) -> List[str]:
    """Names in jax's dict-flatten (sorted-key) order — the exact HLO
    parameter order, recorded in manifest.json for the rust ParamStore."""
    return sorted(init_params(cfg).keys())


def trainstep(cfg: ViTConfig, params, momentum, x, y, fwd_mask, bwd_mask, lr):
    """One micro-batch SGD-momentum step under a D2FT schedule row.

    Subnets scheduled p_o / p_s receive exactly-zero gradients (cut by
    stop_gradient in the model); their momentum decays like a zero-grad
    PyTorch SGD step.

    Returns ``(new_params, new_momentum, loss, n_correct)``.
    """
    grad_fn = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y, fwd_mask, bwd_mask), has_aux=True
    )
    (loss, n_correct), grads = grad_fn(params)
    new_m = jax.tree_util.tree_map(lambda m, g: MOMENTUM * m + g, momentum, grads)
    new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
    return new_p, new_m, loss, n_correct


def evalstep(cfg: ViTConfig, params, x, y, fwd_mask):
    """Forward-only pass (also the timed ``p_o`` program for Table IV).

    Inference uses all parameters (paper §III-A), i.e. fwd_mask of ones —
    the mask input exists so the same artifact times partial forwards.
    """
    ones = jnp.ones((cfg.depth, cfg.heads), jnp.float32)
    loss, n_correct = loss_fn(cfg, params, x, y, fwd_mask, ones)
    return loss, n_correct


def _subnet_reduce(cfg: ViTConfig, tree: Dict[str, jax.Array], fn) -> jax.Array:
    """Reduce per-(block, head) over every tensor slice owned by a subnet.

    Subnet (l, h) owns: the h-th head slice of wqkv/bqkv, the h-th row
    block of wproj, and the h-th chunk of fc1/fc2 (paper §II-A1). In LoRA
    mode it additionally owns the six per-head LoRA matrices.

    ``fn`` maps an array to a per-head vector of shape [H] (e.g. sum of
    squares over all non-head axes). Returns ``[L, H]``.
    """
    heads, d, dh, mc = cfg.heads, cfg.dim, cfg.head_dim, cfg.mlp_chunk
    rows = []
    for i in range(cfg.depth):
        p = f"b{i:02d}_"
        acc = jnp.zeros((heads,), jnp.float32)
        # wqkv [D, 3D] -> [D, 3, H, dh]: head axis 2.
        acc += fn(tree[p + "wqkv"].reshape(d, 3, heads, dh), (0, 1, 3))
        acc += fn(tree[p + "bqkv"].reshape(3, heads, dh), (0, 2))
        # wproj [D, D] -> [H, dh, D]: head axis 0.
        acc += fn(tree[p + "wproj"].reshape(heads, dh, d), (1, 2))
        acc += fn(tree[p + "fc1_w"].reshape(d, heads, mc), (0, 2))
        acc += fn(tree[p + "fc1_b"].reshape(heads, mc), (1,))
        acc += fn(tree[p + "fc2_w"].reshape(heads, mc, d), (1, 2))
        if cfg.lora_rank > 0:
            for kind in ("q", "k", "v"):
                acc += fn(tree[p + f"lora_a{kind}"], (1, 2))
                acc += fn(tree[p + f"lora_b{kind}"], (1, 2))
        rows.append(acc)
    return jnp.stack(rows)  # [L, H]


def _head_axis_sum(arr, axes, head_axis_fn):
    return jnp.sum(head_axis_fn(arr), axis=axes)


def scorestep(cfg: ViTConfig, params, x, y):
    """Contribution-score probe for one micro-batch (paper §II-A3).

    Runs fwd+bwd with all-ones masks *without updating weights* and emits
    the four candidate metrics per subnet, ``[L, H, 4]``:

      [..., 0] Fisher information   sum g^2          (forward score)
      [..., 1] Gradient magnitude   sum |g|
      [..., 2] Taylor importance    sum |w * g|
      [..., 3] Weight magnitude     sum |w|          (backward score)

    The rust ScoreBook averages probes over micro-batches and feeds the
    selected channels into the bi-level knapsack.
    """
    ones = jnp.ones((cfg.depth, cfg.heads), jnp.float32)
    grad_fn = jax.grad(
        lambda p: loss_fn(cfg, p, x, y, ones, ones)[0]
    )
    grads = grad_fn(params)

    def reduce_with(tree, elem):
        def fn(arr, axes):
            return jnp.sum(elem(arr), axis=axes)

        return _subnet_reduce(cfg, tree, fn)

    fisher = reduce_with(grads, jnp.square)
    gradmag = reduce_with(grads, jnp.abs)
    taylor = _subnet_reduce(
        cfg,
        {k: grads[k] * params[k] for k in grads},
        lambda arr, axes: jnp.sum(jnp.abs(arr), axis=axes),
    )
    weightmag = reduce_with(params, jnp.abs)
    return jnp.stack([fisher, gradmag, taylor, weightmag], axis=-1)
