"""L1 Pallas kernels (build-time only; lowered into the HLO artifacts)."""

from .lora_qkv import lora_delta
from .masked_attention import masked_attention

__all__ = ["masked_attention", "lora_delta"]
