"""L1 Pallas kernel: per-head-masked low-rank (LoRA) projection.

D2FT-LoRA co-locates each head's six LoRA matrices (A/B for Q, K, V) with
the frozen head on the same device (paper §II-D). The scheduled mask
gates the *low-rank delta* per head: a ``p_s`` head contributes no delta
(and the frozen head itself is masked by the attention kernel).

Grid is ``(heads,)``: one program instance per subnet's LoRA branch. The
activation tile ``x`` ([N, D], N = B*T) is broadcast to every instance;
A/B tiles are per-head. Both contractions are MXU-shaped matmuls with the
rank-r intermediate kept in VMEM (N*r*4B — a few KB at LoRA ranks).

interpret=True for CPU-PJRT execution; pure-jnp custom VJP so the LoRA
trainstep lowers to a single HLO module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_kernel(gate_ref, x_ref, a_ref, b_ref, o_ref):
    """One head tile: ``o = gate * (x @ A) @ B``.

    Block shapes: gate (1,), x (N, D), a (1, D, r), b (1, r, d_out),
    o (1, N, d_out).
    """
    g = gate_ref[0]
    x = x_ref[...]
    a = a_ref[0]
    b = b_ref[0]
    # Rank-r bottleneck stays in VMEM between the two MXU contractions.
    z = jnp.dot(x, a)
    o_ref[0] = g * jnp.dot(z, b)


def _lora_forward(x, a, b, gate):
    h, d, r = a.shape
    n = x.shape[0]
    dout = b.shape[-1]
    return pl.pallas_call(
        _lora_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hi: (hi,)),
            pl.BlockSpec((n, d), lambda hi: (0, 0)),
            pl.BlockSpec((1, d, r), lambda hi: (hi, 0, 0)),
            pl.BlockSpec((1, r, dout), lambda hi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, dout), lambda hi: (hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dout), x.dtype),
        interpret=True,
    )(gate, x, a, b)


@jax.custom_vjp
def lora_delta(x, a, b, gate):
    """Masked per-head LoRA delta: ``out[h] = gate[h] * (x @ a[h]) @ b[h]``.

    Args:
      x: ``[N, D]`` activations (N = batch * tokens).
      a: ``[H, D, r]`` down-projections.
      b: ``[H, r, d_out]`` up-projections.
      gate: ``[H]`` f32 forward mask in {0, 1}.

    Returns:
      ``[H, N, d_out]``.
    """
    return _lora_forward(x, a, b, gate)


def _lora_fwd(x, a, b, gate):
    return _lora_forward(x, a, b, gate), (x, a, b, gate)


def _lora_bwd(res, do):
    x, a, b, gate = res
    g = gate[:, None, None]
    do = do * g  # masked heads: no gradient into the LoRA branch
    z = jnp.einsum("nd,hdr->hnr", x, a)
    da = jnp.einsum("nd,hnr->hdr", x, jnp.einsum("hno,hro->hnr", do, b))
    db = jnp.einsum("hnr,hno->hro", z, do)
    dx = jnp.einsum("hno,hro,hdr->nd", do, b, a)
    dgate = jnp.zeros_like(gate)
    return dx, da, db, dgate


lora_delta.defvjp(_lora_fwd, _lora_bwd)
