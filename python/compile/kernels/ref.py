"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest + hypothesis assert kernel == ref across shapes/dtypes)."""

from __future__ import annotations

import jax.numpy as jnp


def masked_attention_ref(q, k, v, mask):
    """Reference per-head masked attention. Same contract as
    ``masked_attention.masked_attention`` ([B, H, T, d_h], mask [H])."""
    dh = q.shape[-1]
    scale = 1.0 / (dh**0.5)
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhts,bhsd->bhtd", p, v)
    return o * mask[None, :, None, None]


def lora_delta_ref(x, a, b, gate):
    """Reference masked LoRA delta. Same contract as
    ``lora_qkv.lora_delta`` (x [N, D], a [H, D, r], b [H, r, d_out])."""
    z = jnp.einsum("nd,hdr->hnr", x, a)
    o = jnp.einsum("hnr,hro->hno", z, b)
    return o * gate[:, None, None]
