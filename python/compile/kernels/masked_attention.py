"""L1 Pallas kernel: per-head-masked multi-head attention (D2FT's hot spot).

The D2FT insight is *head-granular skip*: a subnet is one attention head
(plus its FFN chunk), and a scheduled ``p_s`` operation skips the head
entirely — the residual stream is the paper's "shortcut route".

Hardware adaptation (GPU paper -> TPU kernel, see DESIGN.md
§Hardware-Adaptation): the grid is ``(batch, heads)`` so one program
instance owns one (sample, subnet) tile. The per-head fwd mask is read
first; a masked head writes a zero tile. Q/K/V tiles for a single head are
mapped into VMEM via BlockSpec (T x d_h each, ~260 KB worst case at
ViT-small shapes), and both contractions (q.k^T, p.v) are whole-tile
matmuls shaped for the MXU. Softmax is a VPU-axis reduction inside the
tile; no cross-program communication is needed because one head's
attention is self-contained — exactly the property D2FT's partitioning
exploits.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO ops and the same program
text is what the rust runtime executes. Real-TPU perf is estimated
structurally in DESIGN.md.

The backward pass is a pure-jnp custom VJP (standard attention backward,
masked per head) so the whole fwd+bwd trainstep lowers into one HLO
module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(mask_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (head) tile over the whole micro-batch: masked attention.

    Block shapes: mask (1,), q/k/v/o (B, 1, T, d_h). Batching the tile
    over B keeps both contractions as large batched matmuls — better MXU
    occupancy than per-sample tiles, and one grid step per subnet (the
    D2FT skip unit) instead of B of them. §Perf L1 iteration 1 measured
    this at ~3x on the CPU interpret path as well.

    The mask multiply is the *last* op so a skipped head emits an exact
    zero tile (bitwise, not epsilon) — rust-side tests assert this.
    """
    m = mask_ref[0]
    q = q_ref[:, 0]  # [B, T, d_h] in VMEM
    k = k_ref[:, 0]
    v = v_ref[:, 0]
    # MXU contraction 1 (batched): scores [B, T, T].
    s = jnp.einsum("btd,bsd->bts", q, k) * scale
    # VPU softmax with max-subtraction for stability.
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # MXU contraction 2 (batched): output [B, T, d_h].
    o = jnp.einsum("bts,bsd->btd", p, v)
    o_ref[:, 0] = m * o


def _mha_forward(q, k, v, mask):
    """pallas_call wrapper. q/k/v: [B, H, T, d_h]; mask: [H] f32 in {0,1}."""
    b, h, t, dh = q.shape
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(_mha_kernel, scale=scale)
    spec_qkv = pl.BlockSpec((b, 1, t, dh), lambda hi: (0, hi, 0, 0))
    spec_mask = pl.BlockSpec((1,), lambda hi: (hi,))
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[spec_mask, spec_qkv, spec_qkv, spec_qkv],
        out_specs=spec_qkv,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=True,
    )(mask, q, k, v)


@jax.custom_vjp
def masked_attention(q, k, v, mask):
    """Per-head masked attention: ``out[:, h] = mask[h] * attn(q_h, k_h, v_h)``.

    Args:
      q, k, v: ``[B, H, T, d_h]`` f32.
      mask: ``[H]`` f32 forward mask (0 -> head skipped / shortcut ``p_s``).

    Returns:
      ``[B, H, T, d_h]`` f32.
    """
    return _mha_forward(q, k, v, mask)


def _mha_fwd(q, k, v, mask):
    return _mha_forward(q, k, v, mask), (q, k, v, mask)


def _mha_bwd(res, do):
    """Pure-jnp attention backward, masked per head.

    Recomputes p (cheaper than storing the [B,H,T,T] probabilities for
    ViT-scale T — the rematerialization-vs-memory choice DESIGN.md §Perf
    records for L2).
    """
    q, k, v, mask = res
    dh = q.shape[-1]
    scale = 1.0 / (dh**0.5)
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    m = mask[None, :, None, None]
    do = do * m  # masked heads contribute no gradient
    dv = jnp.einsum("bhts,bhtd->bhsd", p, do)
    dp = jnp.einsum("bhtd,bhsd->bhts", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhts,bhsd->bhtd", ds, k) * scale
    dk = jnp.einsum("bhts,bhtd->bhsd", ds, q) * scale
    dmask = jnp.zeros_like(mask)  # masks are schedule inputs, never trained
    return dq, dk, dv, dmask


masked_attention.defvjp(_mha_fwd, _mha_bwd)
