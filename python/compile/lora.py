"""D2FT-LoRA configuration helpers (paper §II-D, §III-B2).

LoRA mode reuses the same ViT graph (vit.py) with ``lora_rank > 0``: the
base weights are frozen via stop_gradient, each head carries six LoRA
matrices (A/B for Q, K, V) co-located with the frozen head — the paper's
partitioning — and the D2FT masks gate the *delta* branch per subnet.

The paper's ranks (240 standard; 1/60/200 "small-rank" baselines) are
scaled to this repo's model preset with the same orderings and cost
ratios; the cluster cost model (rust/src/cluster/cost.rs) derives each
rank's relative compute cost analytically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .vit import PRESETS, ViTConfig

# Scaled counterparts of the paper's {240, 200, 60, 1}: keep the ordering
# near-standard / medium / small / rank-1. head_dim for the e2e preset is
# 32, so the "standard" rank 8 is 1/4 of head_dim (240/(64*…) in-paper
# proportions are far above head_dim; ranks here stay kernel-meaningful).
LORA_RANKS: List[int] = [8, 6, 4, 1]
STANDARD_RANK: int = 8


def lora_config(base: ViTConfig, rank: int) -> ViTConfig:
    """Clone a preset with LoRA enabled at ``rank``."""
    return dataclasses.replace(base, lora_rank=rank)


def lora_presets(preset: str) -> Dict[int, ViTConfig]:
    """All LoRA rank variants for a named preset."""
    base = PRESETS[preset]
    return {r: lora_config(base, r) for r in LORA_RANKS}
