"""L2 model: Vision Transformer with D2FT subnet masking.

The model follows the paper's partitioning (§II-A1): a subnet is one
attention head plus a 1/H chunk of the block's FFN. Two dense ``[L, H]``
f32 masks drive the three scheduled operations per (subnet, micro-batch):

  p_f  full           fwd_mask = 1, bwd_mask = 1
  p_o  forward-only   fwd_mask = 1, bwd_mask = 0   (stop_gradient on the
                      subnet's output term; gradients still reach earlier
                      blocks through the residual route, as in §II-A2)
  p_s  shortcut       fwd_mask = 0, bwd_mask = 0   (subnet output is an
                      exact zero; the residual stream is the shortcut)

Norm layers are frozen and shared per block (paper §III-A "we freeze the
parameter of norm layers ... and replicate it for every subnet"); biases
of the shared output projection are likewise trained unconditionally —
they belong to every subnet of the block and are negligible in cost.

All parameters live in a flat ``dict[str, Array]``; jax flattens dicts in
sorted-key order, which is exactly the order recorded in
``manifest.json`` and consumed by the rust ``ParamStore``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import lora_delta, masked_attention


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Shape of the transformer. ``heads`` is H, ``depth`` is L: the D2FT
    schedule operates on the L*H (block, head) subnet grid."""

    img_size: int = 32
    patch: int = 4
    dim: int = 192
    depth: int = 6
    heads: int = 6
    mlp_ratio: int = 4
    classes: int = 196
    lora_rank: int = 0  # 0 = full fine-tuning; >0 = D2FT-LoRA mode

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def tokens(self) -> int:
        g = self.img_size // self.patch
        return g * g + 1  # + cls token

    @property
    def mlp_dim(self) -> int:
        return self.dim * self.mlp_ratio

    @property
    def mlp_chunk(self) -> int:
        assert self.mlp_dim % self.heads == 0
        return self.mlp_dim // self.heads


# Presets: `tiny` for tests, `e2e` for the shipped artifacts (scaled
# ViT — see DESIGN.md Substitution 2), `vit-small` is the paper's exact
# topology (compile-path validation only on this CPU-only host).
PRESETS: Dict[str, ViTConfig] = {
    "tiny": ViTConfig(img_size=16, patch=4, dim=48, depth=3, heads=4, classes=10),
    # e2e: sized for the single-core CI host (26 devices = the paper's
    # Table V third row); `e2e-large` matches the original shipped scale.
    "e2e": ViTConfig(img_size=32, patch=4, dim=96, depth=4, heads=6, classes=196),
    "e2e-large": ViTConfig(img_size=32, patch=4, dim=192, depth=6, heads=6, classes=196),
    "vit-small": ViTConfig(img_size=224, patch=16, dim=384, depth=12, heads=6, classes=196),
}


def init_params(cfg: ViTConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Truncated-normal-ish init mirroring timm's ViT defaults.

    This stands in for the paper's timm pre-trained checkpoint (DESIGN.md
    Substitution 4); the e2e pipeline additionally "pre-trains" on a broad
    synthetic distribution before fine-tuning so contribution scores are
    non-degenerate.
    """
    key = jax.random.PRNGKey(seed)
    d, heads = cfg.dim, cfg.heads
    patch_in = cfg.patch * cfg.patch * 3
    params: Dict[str, jax.Array] = {}

    def nrm(key, shape, std):
        return (jax.random.normal(key, shape) * std).astype(jnp.float32)

    n_keys = 6 + cfg.depth * 12
    keys = iter(jax.random.split(key, n_keys))
    params["a_cls"] = nrm(next(keys), (1, 1, d), 0.02)
    params["a_pos"] = nrm(next(keys), (1, cfg.tokens, d), 0.02)
    params["a_patch_w"] = nrm(next(keys), (patch_in, d), patch_in**-0.5)
    params["a_patch_b"] = jnp.zeros((d,), jnp.float32)
    for i in range(cfg.depth):
        p = f"b{i:02d}_"
        params[p + "ln1_g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln1_b"] = jnp.zeros((d,), jnp.float32)
        params[p + "ln2_g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln2_b"] = jnp.zeros((d,), jnp.float32)
        params[p + "wqkv"] = nrm(next(keys), (d, 3 * d), d**-0.5)
        params[p + "bqkv"] = jnp.zeros((3 * d,), jnp.float32)
        params[p + "wproj"] = nrm(next(keys), (d, d), d**-0.5)
        params[p + "bproj"] = jnp.zeros((d,), jnp.float32)
        params[p + "fc1_w"] = nrm(next(keys), (d, cfg.mlp_dim), d**-0.5)
        params[p + "fc1_b"] = jnp.zeros((cfg.mlp_dim,), jnp.float32)
        params[p + "fc2_w"] = nrm(next(keys), (cfg.mlp_dim, d), cfg.mlp_dim**-0.5)
        params[p + "fc2_b"] = jnp.zeros((d,), jnp.float32)
        if cfg.lora_rank > 0:
            r = cfg.lora_rank
            dh = cfg.head_dim
            for kind in ("q", "k", "v"):
                # A ~ N(0, 1/d), B = 0 (standard LoRA init: delta starts at 0).
                params[p + f"lora_a{kind}"] = nrm(next(keys), (heads, d, r), d**-0.5)
                params[p + f"lora_b{kind}"] = jnp.zeros((heads, r, dh), jnp.float32)
    params["z_ln_g"] = jnp.ones((d,), jnp.float32)
    params["z_ln_b"] = jnp.zeros((d,), jnp.float32)
    params["z_head_w"] = nrm(next(keys), (d, cfg.classes), d**-0.5)
    params["z_head_b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return params


def _layer_norm(x, g, b, eps: float = 1e-6):
    # Norm params are frozen (paper §III-A): constants for autodiff.
    g = jax.lax.stop_gradient(g)
    b = jax.lax.stop_gradient(b)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _bwd_gate(term, bwd_mask_l):
    """p_o semantics: keep forward value, cut the subnet's backward path.

    ``term`` has a leading-broadcastable head axis at position 1
    ([B, H, ...]); ``bwd_mask_l`` is [H].
    """
    bm = bwd_mask_l.reshape((1, -1) + (1,) * (term.ndim - 2))
    return bm * term + (1.0 - bm) * jax.lax.stop_gradient(term)


def _patchify(cfg: ViTConfig, x):
    """[B, img, img, 3] -> [B, T0, patch*patch*3] without a conv op."""
    b = x.shape[0]
    g, p = cfg.img_size // cfg.patch, cfg.patch
    x = x.reshape(b, g, p, g, p, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, p * p * 3)


def forward(cfg: ViTConfig, params, x, fwd_mask, bwd_mask):
    """ViT forward with D2FT masking.

    Args:
      params: dict from :func:`init_params`.
      x: ``[B, img, img, 3]`` f32 images.
      fwd_mask, bwd_mask: ``[L, H]`` f32 in {0, 1}.

    Returns:
      ``[B, classes]`` logits.
    """
    d, heads, dh = cfg.dim, cfg.heads, cfg.head_dim
    frozen_base = cfg.lora_rank > 0

    def maybe_frozen(w):
        return jax.lax.stop_gradient(w) if frozen_base else w

    tok = _patchify(cfg, x)
    tok = tok @ maybe_frozen(params["a_patch_w"]) + maybe_frozen(params["a_patch_b"])
    cls = jnp.broadcast_to(
        maybe_frozen(params["a_cls"]), (tok.shape[0], 1, d)
    )
    h = jnp.concatenate([cls, tok], axis=1) + maybe_frozen(params["a_pos"])

    bsz, t = h.shape[0], h.shape[1]
    for i in range(cfg.depth):
        p = f"b{i:02d}_"
        fm, bm = fwd_mask[i], bwd_mask[i]
        # --- attention: one subnet per head --------------------------------
        hn = _layer_norm(h, params[p + "ln1_g"], params[p + "ln1_b"])
        wqkv = maybe_frozen(params[p + "wqkv"])
        bqkv = maybe_frozen(params[p + "bqkv"])
        qkv = (hn @ wqkv + bqkv).reshape(bsz, t, 3, heads, dh)
        qkv = qkv.transpose(2, 0, 3, 1, 4)  # [3, B, H, T, dh]
        q, k, v = qkv[0], qkv[1], qkv[2]
        if frozen_base:
            # L1 LoRA kernel: per-head masked low-rank deltas on Q/K/V.
            flat = hn.reshape(bsz * t, d)
            deltas = []
            for kind in ("q", "k", "v"):
                dq = lora_delta(
                    flat, params[p + f"lora_a{kind}"], params[p + f"lora_b{kind}"], fm
                )
                # [H, N, dh] -> [B, H, T, dh]; p_o cuts the LoRA backward.
                dq = dq.reshape(heads, bsz, t, dh).transpose(1, 0, 2, 3)
                deltas.append(_bwd_gate(dq, bm))
            q, k, v = q + deltas[0], k + deltas[1], v + deltas[2]
        # L1 attention kernel: fwd mask zeroes skipped heads in-kernel.
        attn = masked_attention(q, k, v, fm)  # [B, H, T, dh]
        wproj = maybe_frozen(params[p + "wproj"]).reshape(heads, dh, d)
        per_head = jnp.einsum("bhtd,hde->bhte", attn, wproj)
        if not frozen_base:
            per_head = _bwd_gate(per_head, bm)  # p_o: no grads into head h
        h = h + per_head.sum(axis=1) + maybe_frozen(params[p + "bproj"])
        # --- FFN: chunk c belongs to subnet (i, c) --------------------------
        hn2 = _layer_norm(h, params[p + "ln2_g"], params[p + "ln2_b"])
        fc1_w = maybe_frozen(params[p + "fc1_w"]).reshape(d, heads, cfg.mlp_chunk)
        fc1_b = maybe_frozen(params[p + "fc1_b"]).reshape(heads, cfg.mlp_chunk)
        a = jnp.einsum("btd,dhm->bhtm", hn2, fc1_w) + fc1_b[None, :, None, :]
        a = jax.nn.gelu(a) * fm[None, :, None, None]
        fc2_w = maybe_frozen(params[p + "fc2_w"]).reshape(heads, cfg.mlp_chunk, d)
        chunk = jnp.einsum("bhtm,hmd->bhtd", a, fc2_w)
        if not frozen_base:
            chunk = _bwd_gate(chunk, bm)
        h = h + chunk.sum(axis=1) + maybe_frozen(params[p + "fc2_b"])

    h = _layer_norm(h, params["z_ln_g"], params["z_ln_b"])
    cls_tok = h[:, 0]
    return cls_tok @ params["z_head_w"] + params["z_head_b"]


def loss_fn(cfg: ViTConfig, params, x, y, fwd_mask, bwd_mask):
    """Softmax cross-entropy + top-1 correct count.

    ``y`` is int32 ``[B]``; returns ``(loss, n_correct)`` both f32 scalars.
    """
    logits = forward(cfg, params, x, fwd_mask, bwd_mask)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - picked)
    n_correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, n_correct
