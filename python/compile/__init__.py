"""Build-time compile path: JAX/Pallas model definitions + AOT lowering.

Nothing in this package is imported at runtime — the rust coordinator only
consumes the HLO text + parameter blobs under ``artifacts/``.
"""
