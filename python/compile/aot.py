"""AOT lowering: JAX programs -> HLO *text* + parameter blobs.

This is the only place Python runs; ``make artifacts`` invokes it once and
the rust binary is self-contained afterwards.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published ``xla`` 0.1.6 crate) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.
(See /opt/xla-example/README.md.)

Emitted per full-FT preset:
  trainstep.hlo.txt          (params, momentum, x, y, fwd_mask, bwd_mask, lr)
                             -> (params', momentum', loss, n_correct)
  trainstep_mb{N}.hlo.txt    micro-batch-size variants (Table VI)
  eval.hlo.txt               (params, x, y, fwd_mask) -> (loss, n_correct)
  scores.hlo.txt             (params, x, y) -> [L, H, 4] contribution probe
  params_init.bin            flat little-endian f32 blob
  manifest.json              config + param table (flatten order) + io spec

Per LoRA rank r: lora{r}_trainstep / lora{r}_eval (+ lora{STD}_scores),
lora{r}_params_init.bin, lora{r}_manifest.json.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import lora as lora_mod
from . import model as m
from .vit import PRESETS, ViTConfig, init_params


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn: Callable, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def flat_params(cfg: ViTConfig, seed: int) -> List:
    params = init_params(cfg, seed)
    names = sorted(params.keys())
    return names, [params[n] for n in names]


def dump_params(cfg: ViTConfig, seed: int, bin_path: str) -> List[Dict]:
    """Write the init blob; return the manifest param table."""
    names, leaves = flat_params(cfg, seed)
    table = []
    offset = 0
    with open(bin_path, "wb") as f:
        for name, leaf in zip(names, leaves):
            import numpy as np

            arr = np.asarray(leaf, dtype="<f4")
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "size": int(arr.size),
                    "offset": offset,
                }
            )
            offset += int(arr.size)
    print(f"  wrote {bin_path} ({offset * 4} bytes, {len(table)} tensors)")
    return table


def config_dict(cfg: ViTConfig) -> Dict:
    return {
        "img_size": cfg.img_size,
        "patch": cfg.patch,
        "dim": cfg.dim,
        "depth": cfg.depth,
        "heads": cfg.heads,
        "mlp_ratio": cfg.mlp_ratio,
        "classes": cfg.classes,
        "lora_rank": cfg.lora_rank,
        "head_dim": cfg.head_dim,
        "tokens": cfg.tokens,
    }


def specs(cfg: ViTConfig, mb: int):
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    x = sds((mb, cfg.img_size, cfg.img_size, 3), f32)
    y = sds((mb,), i32)
    mask = sds((cfg.depth, cfg.heads), f32)
    lr = sds((), f32)
    names, leaves = flat_params(cfg, 0)
    ptree = {n: sds(l.shape, l.dtype) for n, l in zip(names, leaves)}
    return ptree, x, y, mask, lr


def emit_model_set(cfg: ViTConfig, out_dir: str, prefix: str, mb: int,
                   mb_variants: List[int], seed: int, with_scores: bool) -> Dict:
    ptree, x, y, mask, lr = specs(cfg, mb)
    mtree = ptree  # momentum mirrors params

    def ts(params, momentum, xx, yy, fm, bm, lrr):
        return m.trainstep(cfg, params, momentum, xx, yy, fm, bm, lrr)

    def ev(params, xx, yy, fm):
        return m.evalstep(cfg, params, xx, yy, fm)

    def sc(params, xx, yy):
        return m.scorestep(cfg, params, xx, yy)

    arts = {}
    path = f"{prefix}trainstep.hlo.txt"
    lower_to_file(ts, (ptree, mtree, x, y, mask, mask, lr), os.path.join(out_dir, path))
    arts["trainstep"] = path
    for v in mb_variants:
        if v == mb:
            continue
        _, xv, yv, _, _ = specs(cfg, v)
        pathv = f"{prefix}trainstep_mb{v}.hlo.txt"
        lower_to_file(ts, (ptree, mtree, xv, yv, mask, mask, lr), os.path.join(out_dir, pathv))
        arts[f"trainstep_mb{v}"] = pathv
    path = f"{prefix}eval.hlo.txt"
    lower_to_file(ev, (ptree, x, y, mask), os.path.join(out_dir, path))
    arts["eval"] = path
    if with_scores:
        path = f"{prefix}scores.hlo.txt"
        lower_to_file(sc, (ptree, x, y), os.path.join(out_dir, path))
        arts["scores"] = path

    table = dump_params(cfg, seed, os.path.join(out_dir, f"{prefix}params_init.bin"))
    manifest = {
        "preset_prefix": prefix,
        "config": config_dict(cfg),
        "micro_batch": mb,
        "mb_variants": [v for v in mb_variants if v != mb],
        "artifacts": arts,
        "params_bin": f"{prefix}params_init.bin",
        "n_params": len(table),
        "total_elems": sum(t["size"] for t in table),
        "params": table,
        "trainstep_io": {
            "inputs": "params*N, momentum*N, x, y, fwd_mask, bwd_mask, lr",
            "outputs": "params*N, momentum*N, loss, n_correct",
        },
    }
    mpath = os.path.join(out_dir, f"{prefix}manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="e2e", choices=sorted(PRESETS.keys()))
    ap.add_argument("--micro-batch", type=int, default=8)
    ap.add_argument("--mb-variants", default="4,16",
                    help="extra trainstep micro-batch sizes (Table VI)")
    ap.add_argument("--lora-micro-batch", type=int, default=5,
                    help="Cars-like LoRA micro-batch (paper: 25/5)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-lora", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cfg = PRESETS[args.preset]
    mb_variants = [int(v) for v in args.mb_variants.split(",") if v]

    print(f"[aot] full fine-tuning set (preset={args.preset})")
    emit_model_set(cfg, args.out_dir, "", args.micro_batch, mb_variants,
                   args.seed, with_scores=True)

    if not args.skip_lora:
        for rank in lora_mod.LORA_RANKS:
            print(f"[aot] LoRA set rank={rank}")
            lcfg = lora_mod.lora_config(cfg, rank)
            emit_model_set(
                lcfg, args.out_dir, f"lora{rank}_", args.lora_micro_batch,
                [], args.seed, with_scores=(rank == lora_mod.STANDARD_RANK),
            )

    # Top-level index the rust ArtifactRegistry reads first.
    index = {
        "preset": args.preset,
        "full": "manifest.json",
        "lora_ranks": [] if args.skip_lora else lora_mod.LORA_RANKS,
        "lora_standard_rank": lora_mod.STANDARD_RANK,
        "lora_manifests": {}
        if args.skip_lora
        else {str(r): f"lora{r}_manifest.json" for r in lora_mod.LORA_RANKS},
    }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print("[aot] done")


if __name__ == "__main__":
    main()
