fn main() {}
