fn main() {}
