fn main() {}
