fn main() {}
