"""§Perf L1 A/B: per-(sample, head) grid vs per-head batched-tile grid.

Times the jitted masked-attention forward and a fwd+bwd step under both
kernel structures on the e2e preset shapes. Run from python/:

    python perf_ab_kernel.py

Results are recorded in EXPERIMENTS.md §Perf. interpret=True timings are
CPU-numpy and are *not* a TPU proxy — the structural argument (one grid
step per subnet, batched MXU-shaped contractions, VMEM tile fits) is the
optimization; this measures the CPU-side effect that motivated it.
"""

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel_per_sample(mask_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    m = mask_ref[0]
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = m * jnp.dot(p, v)


def mha_per_sample(q, k, v, mask):
    b, h, t, dh = q.shape
    kern = functools.partial(kernel_per_sample, scale=1.0 / dh**0.5)
    spec = pl.BlockSpec((1, 1, t, dh), lambda bi, hi: (bi, hi, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[pl.BlockSpec((1,), lambda bi, hi: (hi,)), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(mask, q, k, v)


def kernel_batched(mask_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    m = mask_ref[0]
    q = q_ref[:, 0]
    k = k_ref[:, 0]
    v = v_ref[:, 0]
    s = jnp.einsum("btd,bsd->bts", q, k) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[:, 0] = m * jnp.einsum("bts,bsd->btd", p, v)


def mha_batched(q, k, v, mask):
    b, h, t, dh = q.shape
    kern = functools.partial(kernel_batched, scale=1.0 / dh**0.5)
    spec = pl.BlockSpec((b, 1, t, dh), lambda hi: (0, hi, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(h,),
        in_specs=[pl.BlockSpec((1,), lambda hi: (hi,)), spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(mask, q, k, v)


def bench(fn, *args, reps=20):
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def main():
    for (b, h, t, dh, label) in [
        (8, 6, 65, 16, "e2e preset (B=8, H=6, T=65, dh=16)"),
        (16, 6, 197, 64, "vit-small shape (B=16, H=6, T=197, dh=64)"),
    ]:
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, h, t, dh), jnp.float32)
        mask = jnp.ones((h,), jnp.float32)
        t_old = bench(mha_per_sample, q, q, q, mask)
        t_new = bench(mha_batched, q, q, q, mask)
        print(f"{label}")
        print(f"  forward  per-sample grid (B*H={b*h} steps): {t_old:8.2f}ms")
        print(f"  forward  batched grid    (H={h} steps):     {t_new:8.2f}ms   {t_old/t_new:4.1f}x")
        # (the backward runs through the custom-VJP jnp path in the real
        # model and is identical for both grids — forward structure is
        # the A/B variable)


if __name__ == "__main__":
    main()
