fn main() {}
