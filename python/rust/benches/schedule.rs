fn main() {}
