fn main() {}
