fn main() {}
