"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and mask patterns; every case asserts
``assert_allclose`` against the reference, plus exact-zero guarantees for
masked subnets (the rust cost model relies on skipped == exactly zero).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lora_delta, masked_attention
from compile.kernels.ref import lora_delta_ref, masked_attention_ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


@st.composite
def mha_case(draw):
    b = draw(st.integers(1, 3))
    h = draw(st.integers(1, 4))
    t = draw(st.integers(1, 17))
    dh = draw(st.sampled_from([4, 8, 16]))
    seed = draw(st.integers(0, 2**31 - 1))
    mask_bits = draw(st.lists(st.integers(0, 1), min_size=h, max_size=h))
    return b, h, t, dh, seed, mask_bits


@given(mha_case())
@settings(**SETTINGS)
def test_masked_attention_matches_ref(case):
    b, h, t, dh, seed, mask_bits = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = rand(k1, (b, h, t, dh)), rand(k2, (b, h, t, dh)), rand(k3, (b, h, t, dh))
    mask = jnp.array(mask_bits, jnp.float32)
    got = masked_attention(q, k, v, mask)
    want = masked_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(mha_case())
@settings(**SETTINGS)
def test_masked_attention_grads_match_ref(case):
    b, h, t, dh, seed, mask_bits = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = rand(k1, (b, h, t, dh)), rand(k2, (b, h, t, dh)), rand(k3, (b, h, t, dh))
    mask = jnp.array(mask_bits, jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(jnp.sin(masked_attention(q, k, v, mask)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(masked_attention_ref(q, k, v, mask)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-5)


def test_masked_head_is_exact_zero():
    key = jax.random.PRNGKey(0)
    q = rand(key, (2, 3, 9, 8))
    mask = jnp.array([1.0, 0.0, 1.0])
    out = masked_attention(q, q, q, mask)
    assert np.all(np.asarray(out)[:, 1] == 0.0), "p_s head must emit exact zeros"
    assert np.any(np.asarray(out)[:, 0] != 0.0)


def test_masked_head_gets_zero_grad():
    key = jax.random.PRNGKey(1)
    q = rand(key, (1, 2, 5, 4))
    mask = jnp.array([0.0, 1.0])
    g = jax.grad(lambda v: jnp.sum(masked_attention(q, q, v, mask)))(q)
    assert np.all(np.asarray(g)[:, 0] == 0.0)
    assert np.any(np.asarray(g)[:, 1] != 0.0)


def test_attention_rows_sum_to_one_property():
    # softmax sanity through the kernel: uniform v of ones must return ones
    # for active heads (sum_j p_ij * 1 = 1).
    key = jax.random.PRNGKey(2)
    q = rand(key, (2, 2, 7, 4))
    v = jnp.ones_like(q)
    mask = jnp.array([1.0, 1.0])
    out = masked_attention(q, q, v, mask)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


def test_attention_softmax_stability_large_logits():
    key = jax.random.PRNGKey(3)
    q = rand(key, (1, 1, 6, 8)) * 100.0  # would overflow exp() without max-sub
    out = masked_attention(q, q, q, jnp.ones((1,)))
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_attention_dtypes(dtype):
    key = jax.random.PRNGKey(4)
    q = rand(key, (1, 2, 5, 4), dtype)
    mask = jnp.ones((2,), dtype)
    out = masked_attention(q, q, q, mask)
    assert out.dtype == dtype
    want = masked_attention_ref(q, q, q, mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@st.composite
def lora_case(draw):
    n = draw(st.integers(1, 12))
    d = draw(st.sampled_from([4, 8, 12]))
    h = draw(st.integers(1, 4))
    r = draw(st.sampled_from([1, 2, 4]))
    dout = draw(st.sampled_from([4, 8]))
    seed = draw(st.integers(0, 2**31 - 1))
    gate_bits = draw(st.lists(st.integers(0, 1), min_size=h, max_size=h))
    return n, d, h, r, dout, seed, gate_bits


@given(lora_case())
@settings(**SETTINGS)
def test_lora_delta_matches_ref(case):
    n, d, h, r, dout, seed, gate_bits = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (n, d))
    a = rand(k2, (h, d, r))
    b = rand(k3, (h, r, dout))
    gate = jnp.array(gate_bits, jnp.float32)
    got = lora_delta(x, a, b, gate)
    want = lora_delta_ref(x, a, b, gate)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(lora_case())
@settings(**SETTINGS)
def test_lora_delta_grads_match_ref(case):
    n, d, h, r, dout, seed, gate_bits = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (n, d))
    a = rand(k2, (h, d, r))
    b = rand(k3, (h, r, dout))
    gate = jnp.array(gate_bits, jnp.float32)

    def lk(x, a, b):
        return jnp.sum(jnp.cos(lora_delta(x, a, b, gate)))

    def lr_(x, a, b):
        return jnp.sum(jnp.cos(lora_delta_ref(x, a, b, gate)))

    gk = jax.grad(lk, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(lr_, argnums=(0, 1, 2))(x, a, b)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_lora_gated_head_zero_delta_and_grad():
    key = jax.random.PRNGKey(5)
    x = rand(key, (6, 8))
    a = rand(key, (3, 8, 2))
    b = rand(key, (3, 2, 4))
    gate = jnp.array([1.0, 0.0, 1.0])
    out = lora_delta(x, a, b, gate)
    assert np.all(np.asarray(out)[1] == 0.0)
    ga = jax.grad(lambda a: jnp.sum(lora_delta(x, a, b, gate)))(a)
    assert np.all(np.asarray(ga)[1] == 0.0)
    assert np.any(np.asarray(ga)[0] != 0.0)


def test_lora_zero_b_is_identity_delta():
    # Standard LoRA init (B = 0) must contribute exactly nothing forward.
    key = jax.random.PRNGKey(6)
    x = rand(key, (5, 8))
    a = rand(key, (2, 8, 3))
    b = jnp.zeros((2, 3, 4))
    out = lora_delta(x, a, b, jnp.ones((2,)))
    assert np.all(np.asarray(out) == 0.0)
