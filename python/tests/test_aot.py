"""AOT path: lowering to HLO text, manifest/blob consistency.

These tests exercise the exact code `make artifacts` runs, on the tiny
preset, and validate the invariants the rust ArtifactRegistry depends on.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as m
from compile.vit import PRESETS, init_params

CFG = PRESETS["tiny"]


def test_hlo_text_roundtrippable_header():
    ptree, x, y, mask, lr = aot.specs(CFG, 2)
    lowered = jax.jit(lambda p, xx, yy, fm: m.evalstep(CFG, p, xx, yy, fm)).lower(
        ptree, x, y, mask
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # text format (not proto): ids are reassigned by the parser — must not
    # contain any serialized-proto artifacts.
    assert "\x00" not in text


def test_trainstep_param_arity():
    """HLO parameter count must be 2*n_params + 5 (x, y, 2 masks, lr) —
    the contract the rust runtime builds its argument vector around."""
    ptree, x, y, mask, lr = aot.specs(CFG, 2)
    lowered = jax.jit(
        lambda p, mm, xx, yy, fm, bm, lrr: m.trainstep(CFG, p, mm, xx, yy, fm, bm, lrr)
    ).lower(ptree, ptree, x, y, mask, mask, lr)
    text = aot.to_hlo_text(lowered)
    import re

    # ENTRY parameters carry unique indices 0..n-1 (subcomputations reuse
    # small indices, so the max+1 is the entry arity).
    idxs = [int(s) for s in re.findall(r"parameter\((\d+)\)", text)]
    n_params = max(idxs) + 1
    assert n_params == 2 * len(ptree) + 5, (n_params, len(ptree))


def test_manifest_and_blob(tmp_path):
    manifest = aot.emit_model_set(
        CFG, str(tmp_path), "t_", mb=2, mb_variants=[], seed=3, with_scores=False
    )
    # blob size matches manifest accounting
    blob = (tmp_path / "t_params_init.bin").read_bytes()
    assert len(blob) == manifest["total_elems"] * 4
    # manifest order is sorted-key (jax dict flatten order)
    names = [p["name"] for p in manifest["params"]]
    assert names == sorted(names)
    # offsets are contiguous
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        off += p["size"]
    # spot-check one tensor's bytes against a fresh init
    params = init_params(CFG, seed=3)
    entry = next(p for p in manifest["params"] if p["name"] == "z_head_w")
    arr = np.frombuffer(
        blob[entry["offset"] * 4 : (entry["offset"] + entry["size"]) * 4], "<f4"
    ).reshape(entry["shape"])
    np.testing.assert_array_equal(arr, np.asarray(params["z_head_w"]))


def test_manifest_config_fields(tmp_path):
    manifest = aot.emit_model_set(
        CFG, str(tmp_path), "t_", mb=2, mb_variants=[], seed=0, with_scores=False
    )
    c = manifest["config"]
    assert c["depth"] == CFG.depth and c["heads"] == CFG.heads
    assert c["tokens"] == CFG.tokens
    assert manifest["micro_batch"] == 2
    assert set(manifest["artifacts"]) == {"trainstep", "eval"}


def test_param_names_stable():
    """Flatten order is part of the artifact ABI; lock it down."""
    names = m.param_names(CFG)
    assert names[0] == "a_cls"
    assert names[-1] == "z_ln_g" or names[-1].startswith("z_")
    assert names == sorted(names)
    # block params sort between the 'a_' embeddings and 'z_' head
    assert all(n.startswith(("a_", "b", "z_")) for n in names)
