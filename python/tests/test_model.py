"""L2 correctness: ViT forward/backward under D2FT masks, trainstep
semantics, contribution-score probe."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile.lora import lora_config
from compile.vit import PRESETS, ViTConfig, forward, init_params, loss_fn

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=7)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (4, CFG.img_size, CFG.img_size, 3))
    y = jnp.array([0, 1, 2, 3], jnp.int32)
    return x, y


def ones_mask():
    return jnp.ones((CFG.depth, CFG.heads), jnp.float32)


def test_forward_shape_and_finite(params, batch):
    x, _ = batch
    logits = forward(CFG, params, x, ones_mask(), ones_mask())
    assert logits.shape == (4, CFG.classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_loss_near_log_classes_at_init(params, batch):
    x, y = batch
    loss, _ = loss_fn(CFG, params, x, y, ones_mask(), ones_mask())
    assert abs(float(loss) - np.log(CFG.classes)) < 1.0


def test_ps_skip_equals_head_removal(params, batch):
    """fwd_mask[l,h]=0 must equal analytically removing subnet (l,h):
    zeroing the head's wqkv/wproj slices and its FFN chunk."""
    x, _ = batch
    l, h = 1, 2
    fm = ones_mask().at[l, h].set(0.0)
    got = forward(CFG, params, x, fm, ones_mask())

    dh, d, mc = CFG.head_dim, CFG.dim, CFG.mlp_chunk
    p2 = dict(params)
    pfx = f"b{l:02d}_"
    wproj = np.asarray(p2[pfx + "wproj"]).reshape(CFG.heads, dh, d).copy()
    wproj[h] = 0.0
    p2[pfx + "wproj"] = jnp.asarray(wproj.reshape(d, d))
    fc2 = np.asarray(p2[pfx + "fc2_w"]).reshape(CFG.heads, mc, d).copy()
    fc2[h] = 0.0
    p2[pfx + "fc2_w"] = jnp.asarray(fc2.reshape(CFG.mlp_dim, d))
    # fc1 bias of the chunk also contributes through gelu(0 + b): zero the
    # whole chunk path on the fc2 side already removes it, so wproj+fc2
    # suffice for equality.
    want = forward(CFG, p2, x, ones_mask(), ones_mask())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_po_zeroes_subnet_grads_only(params, batch):
    x, y = batch
    l, h = 2, 1
    bm = ones_mask().at[l, h].set(0.0)
    g = jax.grad(lambda p: loss_fn(CFG, p, x, y, ones_mask(), bm)[0])(params)
    pfx = f"b{l:02d}_"
    gq = np.asarray(g[pfx + "wqkv"]).reshape(CFG.dim, 3, CFG.heads, CFG.head_dim)
    assert np.all(gq[:, :, h, :] == 0.0)
    other = [i for i in range(CFG.heads) if i != h]
    assert np.any(gq[:, :, other, :] != 0.0)
    gp = np.asarray(g[pfx + "wproj"]).reshape(CFG.heads, CFG.head_dim, CFG.dim)
    assert np.all(gp[h] == 0.0) and np.any(gp[other] != 0.0)
    gf1 = np.asarray(g[pfx + "fc1_w"]).reshape(CFG.dim, CFG.heads, CFG.mlp_chunk)
    assert np.all(gf1[:, h] == 0.0) and np.any(gf1[:, other] != 0.0)
    gf2 = np.asarray(g[pfx + "fc2_w"]).reshape(CFG.heads, CFG.mlp_chunk, CFG.dim)
    assert np.all(gf2[h] == 0.0)
    # other blocks unaffected
    g0 = np.asarray(g["b00_wqkv"])
    assert np.any(g0 != 0.0)


def test_po_does_not_change_forward(params, batch):
    x, _ = batch
    bm = ones_mask().at[0, 0].set(0.0).at[2, 3].set(0.0)
    a = forward(CFG, params, x, ones_mask(), ones_mask())
    b = forward(CFG, params, x, ones_mask(), bm)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_residual_route_keeps_upstream_grads(params, batch):
    """Even with a whole block set to p_s, earlier blocks still learn via
    the residual route (paper §II-A2)."""
    x, y = batch
    fm = ones_mask().at[1, :].set(0.0)
    bm = ones_mask().at[1, :].set(0.0)
    g = jax.grad(lambda p: loss_fn(CFG, p, x, y, fm, bm)[0])(params)
    assert np.any(np.asarray(g["b00_wqkv"]) != 0.0)
    assert np.any(np.asarray(g["b02_wqkv"]) != 0.0)
    assert np.all(np.asarray(g["b01_wqkv"]) == 0.0)


def test_norm_params_frozen(params, batch):
    x, y = batch
    g = jax.grad(lambda p: loss_fn(CFG, p, x, y, ones_mask(), ones_mask())[0])(params)
    for k in g:
        if "_ln" in k or k.startswith("z_ln"):
            assert np.all(np.asarray(g[k]) == 0.0), k


def test_trainstep_decreases_loss(params, batch):
    x, y = batch
    p = params
    mom = {k: jnp.zeros_like(v) for k, v in p.items()}
    lr = jnp.float32(0.05)
    first = None
    step = jax.jit(lambda p, m_, x, y: m.trainstep(CFG, p, m_, x, y, ones_mask(), ones_mask(), lr))
    for i in range(8):
        p, mom, loss, _ = step(p, mom, x, y)
        if first is None:
            first = float(loss)
    assert float(loss) < first, (float(loss), first)


def test_trainstep_under_schedule_updates_selected_only(params, batch):
    x, y = batch
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}
    bm = ones_mask().at[0, 1].set(0.0)
    newp, _, _, _ = m.trainstep(CFG, params, mom, x, y, ones_mask(), bm, jnp.float32(0.1))
    dq = np.asarray(newp["b00_wqkv"] - params["b00_wqkv"]).reshape(
        CFG.dim, 3, CFG.heads, CFG.head_dim
    )
    assert np.all(dq[:, :, 1, :] == 0.0)
    assert np.any(dq[:, :, 0, :] != 0.0)


def test_scorestep_channels(params, batch):
    x, y = batch
    s = np.asarray(m.scorestep(CFG, params, x, y))
    assert s.shape == (CFG.depth, CFG.heads, 4)
    assert np.all(s >= 0.0)
    assert np.all(s[..., 3] > 0.0), "weight magnitude must be positive"
    assert np.any(s[..., 0] > 0.0), "fisher must be non-degenerate"


def test_scorestep_weightmag_independent_of_batch(params, batch):
    x, y = batch
    s1 = np.asarray(m.scorestep(CFG, params, x, y))
    s2 = np.asarray(m.scorestep(CFG, params, -x, (y + 1) % CFG.classes))
    np.testing.assert_allclose(s1[..., 3], s2[..., 3], rtol=1e-6)
    assert not np.allclose(s1[..., 0], s2[..., 0]), "fisher must be sample-dependent"


# ---------------------------------------------------------------- LoRA mode


LCFG = lora_config(CFG, rank=2)


@pytest.fixture(scope="module")
def lora_params():
    return init_params(LCFG, seed=7)


def test_lora_init_matches_base_forward(lora_params, batch):
    """B = 0 at init: the LoRA model must equal the base model forward."""
    x, _ = batch
    base = {k: v for k, v in lora_params.items() if "lora_" not in k}
    a = forward(LCFG, lora_params, x, ones_mask(), ones_mask())
    b = forward(CFG, base, x, ones_mask(), ones_mask())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_lora_trainstep_freezes_base(lora_params, batch):
    x, y = batch
    mom = {k: jnp.zeros_like(v) for k, v in lora_params.items()}
    newp, _, _, _ = m.trainstep(
        LCFG, lora_params, mom, x, y, ones_mask(), ones_mask(), jnp.float32(0.1)
    )
    for k in newp:
        arr_new, arr_old = np.asarray(newp[k]), np.asarray(lora_params[k])
        if "lora_b" in k or k.startswith("z_head"):
            assert np.any(arr_new != arr_old), f"{k} should train"
        elif "lora_a" not in k:
            np.testing.assert_array_equal(arr_new, arr_old, err_msg=f"{k} should be frozen")


def test_lora_po_cuts_lora_grads(lora_params, batch):
    x, y = batch
    bm = ones_mask().at[1, 0].set(0.0)
    g = jax.grad(lambda p: loss_fn(LCFG, p, x, y, ones_mask(), bm)[0])(lora_params)
    gb = np.asarray(g["b01_lora_bq"])
    assert np.all(gb[0] == 0.0)
    assert np.any(gb[1:] != 0.0)


def test_lora_scores_shape(lora_params, batch):
    x, y = batch
    s = np.asarray(m.scorestep(LCFG, lora_params, x, y))
    assert s.shape == (LCFG.depth, LCFG.heads, 4)
    assert np.all(s[..., 3] > 0.0)
