//! Deterministic fault injection for the distributed runtime.
//!
//! A [`FaultPlan`] is a scripted sequence of failure behaviors a worker
//! acts out while otherwise running the normal `run_worker` loop — the
//! seam that makes every chaos scenario in `tests/dist_fault.rs`
//! reproducible in-process, over loopback TCP, and in a forked
//! subprocess, without real machine failures. Plans have a compact
//! string grammar for the CLI (`repro dist-worker --fault ...` and
//! `repro train --dist --fault 0:kill-after-micro=2`):
//!
//! ```text
//! plan    := action (';' action)*
//! action  := 'kill-after-micro=' N     # exit abruptly after N gradient sends
//!          | 'stall-ms=' MS '@' N      # sleep MS ms once, before send N
//!          | 'drop-uplink=' N          # compute but drop gradient send N
//!          | 'rejoin-at-epoch=' E      # (trainer-side) respawn at epoch E
//!          | 'reset-after-frame=' N    # network: fail send N like a TCP reset
//!          | 'corrupt-frame=' N        # network: damage frame N's CRC trailer
//!          | 'delay-ms=' MS '@' N      # network: sleep MS ms before frame N
//!          | 'partition-ms=' MS '@' E  # network: both directions dead for MS
//!                                      #   ms starting at frame E, then heal
//! ```
//!
//! Compute verbs count in *gradient sends*: deterministic under the
//! overlap pipeline because actions trigger at queueing time, before
//! any timing-dependent interleaving. Network verbs count in *outbound
//! frames* on the aggregator link (handshake, heartbeats, and trace
//! flushes included) and are acted out by
//! [`super::transport::FlakyTransport`], which wraps the worker's
//! transport when a plan carries any of them.

use anyhow::Result;

/// One scripted failure behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Exit abruptly (no Bye, link dropped) after `n` gradient sends.
    KillAfterMicro(usize),
    /// Sleep `ms` milliseconds once, just before gradient send
    /// `after_micro` — a slow-but-alive straggler.
    StallMs {
        /// Gradient-send index the stall precedes.
        after_micro: usize,
        /// Stall duration in milliseconds.
        ms: u64,
    },
    /// Compute gradient send `n` normally but never send it.
    DropUplinkFrame(usize),
    /// Trainer-side: respawn this worker at the start of epoch `e`.
    RejoinAtEpoch(usize),
    /// Network: fail outbound frame `n` as a connection reset would,
    /// and surface one matching error on the receive half.
    ResetAfterFrame(usize),
    /// Network: deliver outbound frame `n` with a damaged CRC trailer.
    CorruptFrame(usize),
    /// Network: sleep `ms` milliseconds before outbound frame `at`.
    DelayMs {
        /// Delay duration in milliseconds.
        ms: u64,
        /// Outbound frame index the delay precedes.
        at: usize,
    },
    /// Network: both directions fail from outbound frame `at` for `ms`
    /// wall-clock milliseconds, then the link heals.
    PartitionMs {
        /// Partition duration in milliseconds.
        ms: u64,
        /// Outbound frame index that opens the partition.
        at: usize,
    },
}

impl std::fmt::Display for FaultAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultAction::KillAfterMicro(n) => write!(f, "kill-after-micro={n}"),
            FaultAction::StallMs { after_micro, ms } => write!(f, "stall-ms={ms}@{after_micro}"),
            FaultAction::DropUplinkFrame(n) => write!(f, "drop-uplink={n}"),
            FaultAction::RejoinAtEpoch(e) => write!(f, "rejoin-at-epoch={e}"),
            FaultAction::ResetAfterFrame(n) => write!(f, "reset-after-frame={n}"),
            FaultAction::CorruptFrame(n) => write!(f, "corrupt-frame={n}"),
            FaultAction::DelayMs { ms, at } => write!(f, "delay-ms={ms}@{at}"),
            FaultAction::PartitionMs { ms, at } => write!(f, "partition-ms={ms}@{at}"),
        }
    }
}

/// A worker's scripted fault schedule (empty = fault-free).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scripted actions, matched against the worker's gradient-send
    /// counter (order in the vector is irrelevant; triggers are by index).
    pub actions: Vec<FaultAction>,
}

impl FaultPlan {
    /// Parse the `;`-joined action grammar (see module docs).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut actions = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault action {part:?} is missing '='"))?;
            let action = match key {
                "kill-after-micro" => FaultAction::KillAfterMicro(parse_num(val, part)?),
                "drop-uplink" => FaultAction::DropUplinkFrame(parse_num(val, part)?),
                "rejoin-at-epoch" => FaultAction::RejoinAtEpoch(parse_num(val, part)?),
                "reset-after-frame" => FaultAction::ResetAfterFrame(parse_num(val, part)?),
                "corrupt-frame" => FaultAction::CorruptFrame(parse_num(val, part)?),
                "stall-ms" => {
                    let (ms, at) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("stall action {part:?} needs 'stall-ms=MS@N'")
                    })?;
                    FaultAction::StallMs {
                        after_micro: parse_num(at, part)?,
                        ms: parse_num::<u64>(ms, part)?,
                    }
                }
                "delay-ms" => {
                    let (ms, at) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("delay action {part:?} needs 'delay-ms=MS@N'")
                    })?;
                    FaultAction::DelayMs {
                        ms: parse_num::<u64>(ms, part)?,
                        at: parse_num(at, part)?,
                    }
                }
                "partition-ms" => {
                    let (ms, at) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("partition action {part:?} needs 'partition-ms=MS@E'")
                    })?;
                    FaultAction::PartitionMs {
                        ms: parse_num::<u64>(ms, part)?,
                        at: parse_num(at, part)?,
                    }
                }
                _ => anyhow::bail!(
                    "unknown fault action {key:?} \
                     (kill-after-micro|stall-ms|drop-uplink|rejoin-at-epoch\
                     |reset-after-frame|corrupt-frame|delay-ms|partition-ms)"
                ),
            };
            actions.push(action);
        }
        Ok(FaultPlan { actions })
    }

    /// True when no actions are scripted.
    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, a) in self.actions.iter().enumerate() {
            if i > 0 {
                write!(f, ";")?;
            }
            write!(f, "{a}")?;
        }
        Ok(())
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, ctx: &str) -> Result<T> {
    s.trim()
        .parse::<T>()
        .map_err(|_| anyhow::anyhow!("fault action {ctx:?}: {s:?} is not a valid number"))
}

/// Parse a per-worker fault spec: `WORKER:PLAN` entries joined by `,`,
/// e.g. `0:kill-after-micro=2,1:stall-ms=100@0`.
pub fn parse_worker_plans(s: &str) -> Result<Vec<(usize, FaultPlan)>> {
    let mut out = Vec::new();
    for entry in s.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (w, plan) = entry
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("fault spec {entry:?} needs 'WORKER:PLAN'"))?;
        out.push((parse_num(w, entry)?, FaultPlan::parse(plan)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips_through_display() {
        let plan = FaultPlan {
            actions: vec![
                FaultAction::KillAfterMicro(2),
                FaultAction::StallMs { after_micro: 1, ms: 200 },
                FaultAction::DropUplinkFrame(4),
                FaultAction::RejoinAtEpoch(1),
            ],
        };
        let s = plan.to_string();
        assert_eq!(s, "kill-after-micro=2;stall-ms=200@1;drop-uplink=4;rejoin-at-epoch=1");
        assert_eq!(FaultPlan::parse(&s).unwrap(), plan);
    }

    #[test]
    fn network_verbs_round_trip_through_display() {
        let plan = FaultPlan {
            actions: vec![
                FaultAction::ResetAfterFrame(5),
                FaultAction::CorruptFrame(3),
                FaultAction::DelayMs { ms: 40, at: 2 },
                FaultAction::PartitionMs { ms: 250, at: 7 },
            ],
        };
        let s = plan.to_string();
        assert_eq!(s, "reset-after-frame=5;corrupt-frame=3;delay-ms=40@2;partition-ms=250@7");
        assert_eq!(FaultPlan::parse(&s).unwrap(), plan);
        // Mixed compute + network verbs coexist in one plan.
        let mixed = FaultPlan::parse("kill-after-micro=4;corrupt-frame=1").unwrap();
        assert_eq!(mixed.actions.len(), 2);
        // Malformed network verbs error descriptively.
        let err = FaultPlan::parse("delay-ms=40").unwrap_err().to_string();
        assert!(err.contains("delay-ms=MS@N"), "got: {err}");
        let err = FaultPlan::parse("partition-ms=9").unwrap_err().to_string();
        assert!(err.contains("partition-ms=MS@E"), "got: {err}");
    }

    #[test]
    fn empty_and_whitespace_plans_parse_as_fault_free() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
        assert_eq!(FaultPlan::default().to_string(), "");
    }

    #[test]
    fn malformed_plans_error_descriptively() {
        let err = FaultPlan::parse("explode=1").unwrap_err().to_string();
        assert!(err.contains("unknown fault action"), "got: {err}");
        let err = FaultPlan::parse("kill-after-micro").unwrap_err().to_string();
        assert!(err.contains("missing '='"), "got: {err}");
        let err = FaultPlan::parse("stall-ms=100").unwrap_err().to_string();
        assert!(err.contains("stall-ms=MS@N"), "got: {err}");
        let err = FaultPlan::parse("drop-uplink=banana").unwrap_err().to_string();
        assert!(err.contains("not a valid number"), "got: {err}");
    }

    #[test]
    fn worker_plans_parse_per_worker() {
        let plans = parse_worker_plans("0:kill-after-micro=2,3:stall-ms=100@0").unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].0, 0);
        assert_eq!(plans[0].1.actions, vec![FaultAction::KillAfterMicro(2)]);
        assert_eq!(plans[1].0, 3);
        assert_eq!(
            plans[1].1.actions,
            vec![FaultAction::StallMs { after_micro: 0, ms: 100 }]
        );
        assert!(parse_worker_plans("nope").is_err());
    }
}
