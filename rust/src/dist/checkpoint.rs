//! Epoch-boundary training checkpoints for the distributed control
//! plane.
//!
//! A [`Checkpoint`] freezes everything the aggregator needs to resume a
//! run bitwise: the flattened parameter and momentum vectors (exported
//! via `NativeBackend::export_state_flat`, the same payload the `State`
//! frame ships to a rejoining worker) plus the per-position score-book
//! cache. The score books matter: D2FT computes contribution scores
//! during epoch 0 and *reuses* them in later epochs, so recomputing
//! them from resumed parameters would change the masks and break the
//! bitwise-resume guarantee `tests/dist_fault.rs` pins.
//!
//! The on-disk format is deliberately dependency-free: little-endian
//! fields behind a magic/version header, with a trailing FNV-1a
//! checksum over everything before it. Loading is defensive end to
//! end — a truncated, corrupt, or foreign file produces a descriptive
//! error, never a panic or a garbage resume.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::scores::{Metric, ScoreBook};

use super::proto::Cursor;

/// File magic: `D2CK` little-endian.
const MAGIC: u32 = u32::from_le_bytes(*b"D2CK");
/// Format version (bump on any layout change).
const VERSION: u32 = 1;
/// Metric serialization order (fixed: the enum's probe channel order).
const METRICS: [Metric; 4] = [Metric::Fisher, Metric::GradMag, Metric::Taylor, Metric::WeightMag];

/// One resumable snapshot of a distributed run at an epoch boundary.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Epochs fully completed when the snapshot was taken.
    pub epoch: usize,
    /// Global batch counter at the snapshot (start of `epoch`'s next).
    pub batch: usize,
    /// Flattened parameters in canonical order, bit-exact.
    pub params: Vec<f32>,
    /// Flattened momentum in canonical order, bit-exact.
    pub momentum: Vec<f32>,
    /// The per-epoch-position score cache (`None` = not yet probed).
    pub score_books: Vec<Option<ScoreBook>>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Checkpoint {
    /// Serialize to the `D2CK` byte format (header + state + checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + 4 * (self.params.len() + self.momentum.len()));
        put_u32(&mut out, MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, self.epoch as u32);
        put_u32(&mut out, self.batch as u32);
        put_u64(&mut out, self.params.len() as u64);
        for &v in &self.params {
            put_u32(&mut out, v.to_bits());
        }
        put_u64(&mut out, self.momentum.len() as u64);
        for &v in &self.momentum {
            put_u32(&mut out, v.to_bits());
        }
        put_u32(&mut out, self.score_books.len() as u32);
        for slot in &self.score_books {
            match slot {
                None => out.push(0),
                Some(book) => {
                    out.push(1);
                    put_u32(&mut out, book.n_subnets as u32);
                    put_u32(&mut out, book.n_micro as u32);
                    for metric in METRICS {
                        for s in 0..book.n_subnets {
                            for m in 0..book.n_micro {
                                put_u64(&mut out, book.get(metric, s, m).to_bits());
                            }
                        }
                    }
                }
            }
        }
        let sum = fnv64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Parse a `D2CK` byte blob (see [`Self::encode`]).
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        anyhow::ensure!(
            bytes.len() >= 8,
            "checkpoint is {} bytes — too short to even hold its checksum",
            bytes.len()
        );
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv64(body);
        anyhow::ensure!(
            stored == actual,
            "checkpoint checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) \
             — the file is corrupt or truncated"
        );
        let mut c = Cursor::new(body);
        let magic = c.u32("checkpoint magic")?;
        anyhow::ensure!(
            magic == MAGIC,
            "not a d2ft checkpoint: bad magic {magic:#010x} (expected {MAGIC:#010x})"
        );
        let version = c.u32("checkpoint version")?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads {VERSION})"
        );
        let epoch = c.u32("checkpoint epoch")? as usize;
        let batch = c.u32("checkpoint batch")? as usize;
        let read_f32s = |c: &mut Cursor<'_>, what: &str| -> Result<Vec<f32>> {
            let n = c.u64(what)? as usize;
            anyhow::ensure!(
                n.saturating_mul(4) <= c.remaining(),
                "corrupt count: {what} claims {n} f32s but only {} bytes remain",
                c.remaining()
            );
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(c.f32(what)?);
            }
            Ok(v)
        };
        let params = read_f32s(&mut c, "checkpoint params")?;
        let momentum = read_f32s(&mut c, "checkpoint momentum")?;
        let n_slots = c.count(1, "score slot count")?;
        let mut score_books = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            let present = c.u8("score slot presence")?;
            match present {
                0 => score_books.push(None),
                1 => {
                    let n_subnets = c.u32("score book subnets")? as usize;
                    let n_micro = c.u32("score book micros")? as usize;
                    let cells = n_subnets.checked_mul(n_micro).ok_or_else(|| {
                        anyhow::anyhow!("corrupt count: score book dimensions overflow")
                    })?;
                    anyhow::ensure!(
                        cells.saturating_mul(4 * 8) <= c.remaining(),
                        "corrupt count: score book claims {cells} cells but only {} bytes remain",
                        c.remaining()
                    );
                    let mut book = ScoreBook::zeros(n_subnets, n_micro);
                    for metric in METRICS {
                        for s in 0..n_subnets {
                            for m in 0..n_micro {
                                book.set(metric, s, m, c.f64("score cell")?);
                            }
                        }
                    }
                    score_books.push(Some(book));
                }
                p => anyhow::bail!("corrupt score slot presence byte {p} (expected 0 or 1)"),
            }
        }
        Ok(Checkpoint { epoch, batch, params, momentum, score_books })
    }

    /// Write the checkpoint to `path` atomically enough for a crash
    /// between epochs: encode fully in memory, then one `write`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let _sp = crate::obs::trace::span("ckpt", "save");
        std::fs::write(path, self.encode())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read and validate a checkpoint from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::decode(&bytes)
            .with_context(|| format!("parsing checkpoint {}", path.display()))
    }

    /// Write the checkpoint to `path` crash-atomically: encode to a
    /// `.tmp` sibling, fsync it, then rename over the target. A crash
    /// at any point leaves either the previous checkpoint intact or
    /// the new one complete — never a half-written file under the real
    /// name (the leftover `.tmp`, if any, is ignored by loaders).
    pub fn save_atomic(&self, path: &Path) -> Result<()> {
        let _sp = crate::obs::trace::span("ckpt", "save");
        write_atomic(path, &self.encode())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }
}

/// The tmp-write + fsync + rename dance shared by checkpoints and
/// progress records.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow::anyhow!("atomic write target {} has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    // Durability of the rename itself needs the directory synced; best
    // effort — a failure here degrades crash-durability, not
    // correctness of what a reader observes.
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Epoch number parsed from a rotated checkpoint file name
/// (`ckpt_e{N}.d2ck`), `None` for anything else (including `.tmp`
/// leftovers from an interrupted atomic write).
fn ckpt_epoch(name: &str) -> Option<usize> {
    name.strip_prefix("ckpt_e")?.strip_suffix(".d2ck")?.parse().ok()
}

/// Path of the epoch-`e` checkpoint inside a checkpoint directory.
pub fn ckpt_path(dir: &Path, epoch: usize) -> PathBuf {
    dir.join(format!("ckpt_e{epoch}.d2ck"))
}

/// Delete all but the `retain` newest `ckpt_e{N}.d2ck` files in `dir`.
/// Returns how many were removed. Foreign files and `.tmp` leftovers
/// are never touched.
pub fn rotate(dir: &Path, retain: usize) -> Result<usize> {
    let mut epochs: Vec<usize> = std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| ckpt_epoch(&e.file_name().to_string_lossy()))
        .collect();
    epochs.sort_unstable_by(|a, b| b.cmp(a));
    let mut removed = 0;
    for &e in epochs.iter().skip(retain.max(1)) {
        if std::fs::remove_file(ckpt_path(dir, e)).is_ok() {
            removed += 1;
        }
    }
    Ok(removed)
}

/// Find the newest *loadable* checkpoint in `dir`: scan `ckpt_e{N}`
/// names newest-first and return the first that decodes, skipping any
/// corrupt or truncated newer one — which is what makes a crash during
/// (or right before) a checkpoint write recoverable from the previous
/// epoch.
pub fn latest_valid(dir: &Path) -> Result<Option<(PathBuf, Checkpoint)>> {
    let mut epochs: Vec<usize> = std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| ckpt_epoch(&e.file_name().to_string_lossy()))
        .collect();
    epochs.sort_unstable_by(|a, b| b.cmp(a));
    for e in epochs {
        let path = ckpt_path(dir, e);
        match Checkpoint::load(&path) {
            Ok(ck) => return Ok(Some((path, ck))),
            Err(err) => {
                eprintln!("[resume] skipping unreadable checkpoint {}: {err:#}", path.display());
            }
        }
    }
    Ok(None)
}

// ---------------------------------------------------------------------------
// Progress record: step-granular position between epoch checkpoints
// ---------------------------------------------------------------------------

/// File name of the progress record inside a checkpoint directory.
pub const PROGRESS_FILE: &str = "progress.d2pr";

/// Progress-record magic: `D2PR` little-endian.
const PR_MAGIC: u32 = u32::from_le_bytes(*b"D2PR");
/// Progress-record format version.
const PR_VERSION: u32 = 1;

/// A tiny step-granular position record, rewritten (atomically) after
/// every batch. It does NOT carry state — resume always replays from
/// the last epoch checkpoint — but it tells a restarted aggregator
/// where the crash landed and how many restarts the run has absorbed,
/// and gives operators a live progress probe that is always loadable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Progress {
    /// Epochs fully completed.
    pub epoch: usize,
    /// Batches completed within the current epoch.
    pub batch: usize,
    /// Global step counter after the last completed batch.
    pub step: u64,
    /// Aggregator restarts absorbed so far in this run.
    pub restarts: u32,
}

impl Progress {
    /// Serialize to the `D2PR` byte format (header + fields + fnv64).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(36);
        put_u32(&mut out, PR_MAGIC);
        put_u32(&mut out, PR_VERSION);
        put_u32(&mut out, self.epoch as u32);
        put_u32(&mut out, self.batch as u32);
        put_u64(&mut out, self.step);
        put_u32(&mut out, self.restarts);
        let sum = fnv64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Parse a `D2PR` byte blob.
    pub fn decode(bytes: &[u8]) -> Result<Progress> {
        anyhow::ensure!(
            bytes.len() >= 8,
            "progress record is {} bytes — too short to hold its checksum",
            bytes.len()
        );
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv64(body);
        anyhow::ensure!(
            stored == actual,
            "progress record checksum mismatch — the file is corrupt or truncated"
        );
        let mut c = Cursor::new(body);
        let magic = c.u32("progress magic")?;
        anyhow::ensure!(
            magic == PR_MAGIC,
            "not a d2ft progress record: bad magic {magic:#010x} (expected {PR_MAGIC:#010x})"
        );
        let version = c.u32("progress version")?;
        anyhow::ensure!(
            version == PR_VERSION,
            "unsupported progress record version {version} (this build reads {PR_VERSION})"
        );
        Ok(Progress {
            epoch: c.u32("progress epoch")? as usize,
            batch: c.u32("progress batch")? as usize,
            step: c.u64("progress step")?,
            restarts: c.u32("progress restarts")?,
        })
    }

    /// Atomically (re)write the record at `dir/progress.d2pr`.
    pub fn save_atomic(&self, dir: &Path) -> Result<()> {
        let path = dir.join(PROGRESS_FILE);
        write_atomic(&path, &self.encode())
            .with_context(|| format!("writing progress record {}", path.display()))
    }

    /// Load the record from `dir/progress.d2pr` if one exists and is
    /// valid; `Ok(None)` when absent, an error when present but
    /// unreadable.
    pub fn load(dir: &Path) -> Result<Option<Progress>> {
        let path = dir.join(PROGRESS_FILE);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", path.display()));
            }
        };
        Progress::decode(&bytes)
            .with_context(|| format!("parsing progress record {}", path.display()))
            .map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut book = ScoreBook::zeros(2, 3);
        for s in 0..2 {
            for m in 0..3 {
                book.set(Metric::Fisher, s, m, 1.5 * (s * 3 + m) as f64);
                book.set(Metric::WeightMag, s, m, -0.25 + m as f64);
            }
        }
        Checkpoint {
            epoch: 2,
            batch: 9,
            params: vec![0.5, -0.0, f32::MIN_POSITIVE, 3.25],
            momentum: vec![-1.5, 2.0e-8, 0.0, 7.0],
            score_books: vec![Some(book), None],
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn round_trips_bitwise() {
        let ck = sample();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.epoch, 2);
        assert_eq!(back.batch, 9);
        assert_eq!(bits(&back.params), bits(&ck.params));
        assert_eq!(bits(&back.momentum), bits(&ck.momentum));
        assert_eq!(back.score_books.len(), 2);
        assert!(back.score_books[1].is_none());
        let book = back.score_books[0].as_ref().unwrap();
        assert_eq!(book.n_subnets, 2);
        assert_eq!(book.n_micro, 3);
        assert_eq!(book.get(Metric::Fisher, 1, 2).to_bits(), (1.5f64 * 5.0).to_bits());
        assert_eq!(book.get(Metric::WeightMag, 0, 1).to_bits(), 0.75f64.to_bits());
        assert_eq!(book.get(Metric::Taylor, 1, 1), 0.0);
    }

    #[test]
    fn save_and_load_through_a_file() {
        let dir = std::env::temp_dir().join(format!("d2ft-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt_e1.d2ck");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(bits(&back.params), bits(&ck.params));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_a_descriptive_error_not_a_panic() {
        let good = sample().encode();
        // A flipped byte in the middle trips the checksum.
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        let err = Checkpoint::decode(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
        // Truncation trips it too (the checksum tail is gone).
        let err = Checkpoint::decode(&good[..good.len() - 13]).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
        // Nearly-empty files are called out by size.
        let err = Checkpoint::decode(&good[..5]).unwrap_err().to_string();
        assert!(err.contains("too short"), "got: {err}");
        // A foreign file with a valid checksum is rejected by magic.
        let mut foreign = b"definitely not a checkpoint".to_vec();
        let sum = super::fnv64(&foreign);
        foreign.extend_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::decode(&foreign).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "got: {err}");
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("d2ft-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn at_epoch(epoch: usize) -> Checkpoint {
        let mut ck = sample();
        ck.epoch = epoch;
        ck
    }

    #[test]
    fn atomic_save_survives_a_crash_between_tmp_write_and_rename() {
        let dir = temp_dir("ckpt-atomic");
        let path = ckpt_path(&dir, 1);
        at_epoch(1).save_atomic(&path).unwrap();
        // Simulate a crash mid-upgrade: the NEXT save died after
        // writing its tmp file but before the rename. The tmp sibling
        // is garbage; the previous checkpoint must remain loadable and
        // must be what the resume scan picks.
        std::fs::write(dir.join("ckpt_e2.d2ck.tmp"), b"half-written").unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 1);
        let (picked, ck) = latest_valid(&dir).unwrap().expect("previous checkpoint loadable");
        assert_eq!(picked, path);
        assert_eq!(ck.epoch, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_retains_only_the_newest_checkpoints() {
        let dir = temp_dir("ckpt-rotate");
        for e in 0..5 {
            at_epoch(e).save_atomic(&ckpt_path(&dir, e)).unwrap();
        }
        let removed = rotate(&dir, 2).unwrap();
        assert_eq!(removed, 3);
        assert!(!ckpt_path(&dir, 0).exists());
        assert!(!ckpt_path(&dir, 2).exists());
        assert!(ckpt_path(&dir, 3).exists());
        assert!(ckpt_path(&dir, 4).exists());
        // Idempotent: a second rotation removes nothing more.
        assert_eq!(rotate(&dir, 2).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_scan_skips_a_corrupt_newest_checkpoint() {
        let dir = temp_dir("ckpt-scan");
        at_epoch(1).save_atomic(&ckpt_path(&dir, 1)).unwrap();
        at_epoch(2).save_atomic(&ckpt_path(&dir, 2)).unwrap();
        // Corrupt the newest in place (torn write after the rename —
        // e.g. a dying disk); the scan must fall back to epoch 1.
        let newest = ckpt_path(&dir, 2);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&newest, &bytes).unwrap();
        let (picked, ck) = latest_valid(&dir).unwrap().expect("older checkpoint valid");
        assert_eq!(picked, ckpt_path(&dir, 1));
        assert_eq!(ck.epoch, 1);
        // An empty/garbage-only dir resumes as None, not an error.
        let empty = temp_dir("ckpt-scan-empty");
        assert!(latest_valid(&empty).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn progress_records_round_trip_and_reject_corruption() {
        let pr = Progress { epoch: 3, batch: 7, step: 131, restarts: 2 };
        assert_eq!(Progress::decode(&pr.encode()).unwrap(), pr);
        let mut bad = pr.encode();
        bad[9] ^= 0x01;
        let err = Progress::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");

        let dir = temp_dir("progress");
        assert_eq!(Progress::load(&dir).unwrap(), None);
        pr.save_atomic(&dir).unwrap();
        assert_eq!(Progress::load(&dir).unwrap(), Some(pr));
        // Overwrites are atomic replacements, not appends.
        let pr2 = Progress { epoch: 3, batch: 8, step: 132, restarts: 2 };
        pr2.save_atomic(&dir).unwrap();
        assert_eq!(Progress::load(&dir).unwrap(), Some(pr2));
        std::fs::remove_dir_all(&dir).ok();
    }
}
