//! Epoch-boundary training checkpoints for the distributed control
//! plane.
//!
//! A [`Checkpoint`] freezes everything the aggregator needs to resume a
//! run bitwise: the flattened parameter and momentum vectors (exported
//! via `NativeBackend::export_state_flat`, the same payload the `State`
//! frame ships to a rejoining worker) plus the per-position score-book
//! cache. The score books matter: D2FT computes contribution scores
//! during epoch 0 and *reuses* them in later epochs, so recomputing
//! them from resumed parameters would change the masks and break the
//! bitwise-resume guarantee `tests/dist_fault.rs` pins.
//!
//! The on-disk format is deliberately dependency-free: little-endian
//! fields behind a magic/version header, with a trailing FNV-1a
//! checksum over everything before it. Loading is defensive end to
//! end — a truncated, corrupt, or foreign file produces a descriptive
//! error, never a panic or a garbage resume.

use std::path::Path;

use anyhow::{Context, Result};

use crate::scores::{Metric, ScoreBook};

use super::proto::Cursor;

/// File magic: `D2CK` little-endian.
const MAGIC: u32 = u32::from_le_bytes(*b"D2CK");
/// Format version (bump on any layout change).
const VERSION: u32 = 1;
/// Metric serialization order (fixed: the enum's probe channel order).
const METRICS: [Metric; 4] = [Metric::Fisher, Metric::GradMag, Metric::Taylor, Metric::WeightMag];

/// One resumable snapshot of a distributed run at an epoch boundary.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Epochs fully completed when the snapshot was taken.
    pub epoch: usize,
    /// Global batch counter at the snapshot (start of `epoch`'s next).
    pub batch: usize,
    /// Flattened parameters in canonical order, bit-exact.
    pub params: Vec<f32>,
    /// Flattened momentum in canonical order, bit-exact.
    pub momentum: Vec<f32>,
    /// The per-epoch-position score cache (`None` = not yet probed).
    pub score_books: Vec<Option<ScoreBook>>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Checkpoint {
    /// Serialize to the `D2CK` byte format (header + state + checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + 4 * (self.params.len() + self.momentum.len()));
        put_u32(&mut out, MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, self.epoch as u32);
        put_u32(&mut out, self.batch as u32);
        put_u64(&mut out, self.params.len() as u64);
        for &v in &self.params {
            put_u32(&mut out, v.to_bits());
        }
        put_u64(&mut out, self.momentum.len() as u64);
        for &v in &self.momentum {
            put_u32(&mut out, v.to_bits());
        }
        put_u32(&mut out, self.score_books.len() as u32);
        for slot in &self.score_books {
            match slot {
                None => out.push(0),
                Some(book) => {
                    out.push(1);
                    put_u32(&mut out, book.n_subnets as u32);
                    put_u32(&mut out, book.n_micro as u32);
                    for metric in METRICS {
                        for s in 0..book.n_subnets {
                            for m in 0..book.n_micro {
                                put_u64(&mut out, book.get(metric, s, m).to_bits());
                            }
                        }
                    }
                }
            }
        }
        let sum = fnv64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Parse a `D2CK` byte blob (see [`Self::encode`]).
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        anyhow::ensure!(
            bytes.len() >= 8,
            "checkpoint is {} bytes — too short to even hold its checksum",
            bytes.len()
        );
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv64(body);
        anyhow::ensure!(
            stored == actual,
            "checkpoint checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) \
             — the file is corrupt or truncated"
        );
        let mut c = Cursor::new(body);
        let magic = c.u32("checkpoint magic")?;
        anyhow::ensure!(
            magic == MAGIC,
            "not a d2ft checkpoint: bad magic {magic:#010x} (expected {MAGIC:#010x})"
        );
        let version = c.u32("checkpoint version")?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads {VERSION})"
        );
        let epoch = c.u32("checkpoint epoch")? as usize;
        let batch = c.u32("checkpoint batch")? as usize;
        let read_f32s = |c: &mut Cursor<'_>, what: &str| -> Result<Vec<f32>> {
            let n = c.u64(what)? as usize;
            anyhow::ensure!(
                n.saturating_mul(4) <= c.remaining(),
                "corrupt count: {what} claims {n} f32s but only {} bytes remain",
                c.remaining()
            );
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(c.f32(what)?);
            }
            Ok(v)
        };
        let params = read_f32s(&mut c, "checkpoint params")?;
        let momentum = read_f32s(&mut c, "checkpoint momentum")?;
        let n_slots = c.count(1, "score slot count")?;
        let mut score_books = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            let present = c.u8("score slot presence")?;
            match present {
                0 => score_books.push(None),
                1 => {
                    let n_subnets = c.u32("score book subnets")? as usize;
                    let n_micro = c.u32("score book micros")? as usize;
                    let cells = n_subnets.checked_mul(n_micro).ok_or_else(|| {
                        anyhow::anyhow!("corrupt count: score book dimensions overflow")
                    })?;
                    anyhow::ensure!(
                        cells.saturating_mul(4 * 8) <= c.remaining(),
                        "corrupt count: score book claims {cells} cells but only {} bytes remain",
                        c.remaining()
                    );
                    let mut book = ScoreBook::zeros(n_subnets, n_micro);
                    for metric in METRICS {
                        for s in 0..n_subnets {
                            for m in 0..n_micro {
                                book.set(metric, s, m, c.f64("score cell")?);
                            }
                        }
                    }
                    score_books.push(Some(book));
                }
                p => anyhow::bail!("corrupt score slot presence byte {p} (expected 0 or 1)"),
            }
        }
        Ok(Checkpoint { epoch, batch, params, momentum, score_books })
    }

    /// Write the checkpoint to `path` atomically enough for a crash
    /// between epochs: encode fully in memory, then one `write`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let _sp = crate::obs::trace::span("ckpt", "save");
        std::fs::write(path, self.encode())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read and validate a checkpoint from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::decode(&bytes)
            .with_context(|| format!("parsing checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut book = ScoreBook::zeros(2, 3);
        for s in 0..2 {
            for m in 0..3 {
                book.set(Metric::Fisher, s, m, 1.5 * (s * 3 + m) as f64);
                book.set(Metric::WeightMag, s, m, -0.25 + m as f64);
            }
        }
        Checkpoint {
            epoch: 2,
            batch: 9,
            params: vec![0.5, -0.0, f32::MIN_POSITIVE, 3.25],
            momentum: vec![-1.5, 2.0e-8, 0.0, 7.0],
            score_books: vec![Some(book), None],
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn round_trips_bitwise() {
        let ck = sample();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.epoch, 2);
        assert_eq!(back.batch, 9);
        assert_eq!(bits(&back.params), bits(&ck.params));
        assert_eq!(bits(&back.momentum), bits(&ck.momentum));
        assert_eq!(back.score_books.len(), 2);
        assert!(back.score_books[1].is_none());
        let book = back.score_books[0].as_ref().unwrap();
        assert_eq!(book.n_subnets, 2);
        assert_eq!(book.n_micro, 3);
        assert_eq!(book.get(Metric::Fisher, 1, 2).to_bits(), (1.5f64 * 5.0).to_bits());
        assert_eq!(book.get(Metric::WeightMag, 0, 1).to_bits(), 0.75f64.to_bits());
        assert_eq!(book.get(Metric::Taylor, 1, 1), 0.0);
    }

    #[test]
    fn save_and_load_through_a_file() {
        let dir = std::env::temp_dir().join(format!("d2ft-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt_e1.d2ck");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(bits(&back.params), bits(&ck.params));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_a_descriptive_error_not_a_panic() {
        let good = sample().encode();
        // A flipped byte in the middle trips the checksum.
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        let err = Checkpoint::decode(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
        // Truncation trips it too (the checksum tail is gone).
        let err = Checkpoint::decode(&good[..good.len() - 13]).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
        // Nearly-empty files are called out by size.
        let err = Checkpoint::decode(&good[..5]).unwrap_err().to_string();
        assert!(err.contains("too short"), "got: {err}");
        // A foreign file with a valid checksum is rejected by magic.
        let mut foreign = b"definitely not a checkpoint".to_vec();
        let sum = super::fnv64(&foreign);
        foreign.extend_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::decode(&foreign).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "got: {err}");
    }
}
