//! Deterministic gradient aggregation: fixed reduction order ⇒ bitwise
//! serial ≡ parallel parity.
//!
//! Workers finish in host-dependent order, but floating-point addition
//! is not associative, so a reduction that sums "whatever arrived next"
//! would make the training trajectory depend on thread timing. The
//! [`OrderedReducer`] therefore slots messages by micro-batch index and
//! reduces them in ascending micro order once the barrier is complete —
//! the same element-wise add sequence the serial
//! [`crate::coordinator::UpdateMode::BatchAccum`] trainer performs, which
//! is the whole determinism contract of `tests/dist.rs`.

use anyhow::Result;

use super::grads::GradCodec;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

/// How the aggregated gradient gets back to the workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Broadcast the reduced gradient under the batch's *union* mask;
    /// every replica applies the identical fused SGD-momentum update
    /// locally (each holds its own momentum copy — same bits, no
    /// parameter traffic). The masked-exchange path the paper's
    /// communication numbers correspond to.
    MaskedAllReduce,
    /// Parameter server: the aggregator owns the optimizer state,
    /// applies the update centrally, and ships **dense** update deltas
    /// (`lr * m`) for every trainable tensor. Momentum mixes old and new
    /// gradients, so deltas cannot be masked — the downlink costs full
    /// bytes. Useful when workers are too small to hold optimizer state
    /// (heterogeneous clusters); bitwise the same trajectory either way.
    ParamServer,
    /// Ring exchange over direct worker↔worker links (negotiated by the
    /// aggregator): the partial gradient sum travels the chain
    /// `0 → 1 → … → K-1`, each worker adding its own micro-batches in
    /// ascending order, so the reduction bracketing is exactly the
    /// serial trainer's and per-node traffic is O(1) in K instead of
    /// the star's O(K) at the aggregator. The finished sum is forwarded
    /// verbatim around the wrap link so every replica decodes identical
    /// bytes.
    Ring,
    /// Two-level ring: the same chain reduce (bitwise-identical
    /// bracketing), but the distribute leg fans out through one leader
    /// per group (`DistConfig::ring_group` members each) — the
    /// aggregator's downlink scales with the number of groups, not K.
    Hierarchical,
}

impl ExchangeMode {
    /// Parse a CLI label (`allreduce` | `ps` | `ring` | `hier`).
    pub fn parse(s: &str) -> Result<ExchangeMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "allreduce" | "star" => ExchangeMode::MaskedAllReduce,
            "ps" | "param-server" | "paramserver" => ExchangeMode::ParamServer,
            "ring" => ExchangeMode::Ring,
            "hier" | "hierarchical" => ExchangeMode::Hierarchical,
            _ => anyhow::bail!("unknown exchange mode {s:?} (allreduce|ps|ring|hier)"),
        })
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            ExchangeMode::MaskedAllReduce => "masked-allreduce",
            ExchangeMode::ParamServer => "param-server",
            ExchangeMode::Ring => "ring",
            ExchangeMode::Hierarchical => "hierarchical",
        }
    }

    /// True for the direct worker↔worker topologies (both need
    /// negotiated ring links and the hold-gradients worker mode).
    pub fn is_ring(&self) -> bool {
        matches!(self, ExchangeMode::Ring | ExchangeMode::Hierarchical)
    }
}

/// Barrier + fixed-order reduction over one batch's gradient messages.
///
/// Slots hold whole transport frames plus the offset where the codec
/// message starts, so the aggregator reduces straight out of the
/// received frame — no copy between the socket and the reduction.
pub struct OrderedReducer {
    slots: Vec<Option<(Vec<u8>, usize)>>,
}

impl OrderedReducer {
    /// Reducer expecting one message per micro-batch.
    pub fn new(n_micro: usize) -> OrderedReducer {
        OrderedReducer { slots: vec![None; n_micro] }
    }

    /// Deposit micro-batch `micro`'s gradient message: the codec bytes
    /// start at `grad_off` within `frame` (0 for a bare message).
    pub fn push(&mut self, micro: usize, frame: Vec<u8>, grad_off: usize) -> Result<()> {
        anyhow::ensure!(micro < self.slots.len(), "micro {micro} out of range");
        anyhow::ensure!(
            self.slots[micro].is_none(),
            "duplicate gradient message for micro {micro}"
        );
        anyhow::ensure!(
            grad_off <= frame.len(),
            "gradient offset {grad_off} beyond the {}-byte frame",
            frame.len()
        );
        self.slots[micro] = Some((frame, grad_off));
        Ok(())
    }

    /// Whether every slot has reported.
    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    /// Whether `micro`'s slot has reported (out-of-range counts as
    /// filled so the control plane never reassigns a bogus index).
    pub fn filled(&self, micro: usize) -> bool {
        self.slots.get(micro).map(|s| s.is_some()).unwrap_or(true)
    }

    /// Decode every message into `acc` in ascending micro order and
    /// scale by `1/n` (the batch-mean gradient). `masks[i]` must be the
    /// mask pair micro `i` was scheduled (and encoded) under; `acc`
    /// must start zeroed.
    pub fn reduce(
        &self,
        codec: &GradCodec,
        masks: &[MaskPair],
        acc: &mut [Tensor],
    ) -> Result<()> {
        anyhow::ensure!(self.is_complete(), "reduce before barrier completion");
        anyhow::ensure!(masks.len() == self.slots.len(), "one mask pair per micro");
        let _sp = crate::obs::trace::span("reduce", "ordered_reduce");
        for (i, slot) in self.slots.iter().enumerate() {
            let (frame, off) = slot.as_ref().unwrap();
            let micro = codec.decode_add(&frame[*off..], &masks[i], acc)?;
            anyhow::ensure!(micro == i, "message for micro {micro} in slot {i}");
        }
        let scale = 1.0 / self.slots.len() as f32;
        for a in acc.iter_mut() {
            a.scale(scale);
        }
        Ok(())
    }

    /// Consume the reducer and hand back every deposited frame buffer
    /// (ascending micro order) so the aggregator can recycle them into
    /// the encode-buffer pool ([`super::grads::BufPool`]) — the second
    /// half of the zero-allocation steady state.
    pub fn into_blobs(self) -> Vec<Vec<u8>> {
        self.slots.into_iter().flatten().map(|(frame, _)| frame).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, NativeSpec};
    use crate::backend::Backend;
    use crate::data::{DatasetSpec, SyntheticKind};
    use crate::runtime::ModelConfig;

    #[test]
    fn filled_tracks_slots_and_tolerates_bad_indices() {
        let mut r = OrderedReducer::new(3);
        assert!(!r.filled(0));
        r.push(1, vec![0u8; 4], 0).unwrap();
        assert!(r.filled(1));
        assert!(!r.filled(2));
        // Out of range reads as filled: nothing to reassign there.
        assert!(r.filled(99));
    }

    fn backend() -> NativeBackend {
        let spec = NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![],
            lora_standard_rank: 0,
            init_seed: 0xACE,
            threads: 1,
        };
        NativeBackend::new(&spec, 0, 2, 9)
    }

    #[test]
    fn exchange_mode_parses() {
        assert_eq!(ExchangeMode::parse("allreduce").unwrap(), ExchangeMode::MaskedAllReduce);
        assert_eq!(ExchangeMode::parse("PS").unwrap(), ExchangeMode::ParamServer);
        assert_eq!(ExchangeMode::parse("ring").unwrap(), ExchangeMode::Ring);
        assert_eq!(ExchangeMode::parse("hier").unwrap(), ExchangeMode::Hierarchical);
        assert!(ExchangeMode::parse("gossip").is_err());
        assert_eq!(ExchangeMode::ParamServer.label(), "param-server");
        assert_eq!(ExchangeMode::Ring.label(), "ring");
        assert!(ExchangeMode::Hierarchical.is_ring() && !ExchangeMode::ParamServer.is_ring());
    }

    #[test]
    fn ordered_reduce_matches_serial_accumulation_bitwise() {
        let be = backend();
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 6, 4).generate("train");
        let masks: Vec<MaskPair> = (0..3).map(|_| MaskPair::ones(2, 2)).collect();
        let per_micro: Vec<Vec<crate::tensor::Tensor>> = (0..3)
            .map(|i| {
                let (x, y) = data.gather(&[2 * i, 2 * i + 1]);
                be.grad_step(&x, &y, &masks[i]).unwrap().1
            })
            .collect();
        // Serial reference: dense sum in micro order, then mean.
        let mut serial = be.zeros_like_params();
        for grads in &per_micro {
            for (a, g) in serial.iter_mut().zip(grads) {
                a.add_assign(g);
            }
        }
        let scale = 1.0 / 3.0f32;
        for a in &mut serial {
            a.scale(scale);
        }
        // Deposit out of arrival order on purpose: 2, 0, 1.
        let mut reducer = OrderedReducer::new(3);
        for &i in &[2usize, 0, 1] {
            reducer.push(i, codec.encode(i, &masks[i], &per_micro[i]), 0).unwrap();
        }
        assert!(reducer.is_complete());
        let mut reduced = be.zeros_like_params();
        reducer.reduce(&codec, &masks, &mut reduced).unwrap();
        for (s, r) in serial.iter().zip(&reduced) {
            assert_eq!(s.data(), r.data(), "ordered reduce must reproduce serial bits");
        }
    }

    #[test]
    fn adversarial_arrival_orders_reduce_bitwise_serial() {
        // K ∈ {2, 4} workers delivering 8 micro-batch messages in
        // reverse and in K-way interleaved order (worker w owns micros
        // w, w+K, w+2K, ... and its deliveries interleave round-robin
        // backwards) — every order must reduce to the serial bits.
        let be = backend();
        let codec = GradCodec::new(&be);
        let n = 8usize;
        let data =
            DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2 * n, 21).generate("train");
        let masks: Vec<MaskPair> = (0..n).map(|_| MaskPair::ones(2, 2)).collect();
        let per_micro: Vec<Vec<crate::tensor::Tensor>> = (0..n)
            .map(|i| {
                let (x, y) = data.gather(&[2 * i, 2 * i + 1]);
                be.grad_step(&x, &y, &masks[i]).unwrap().1
            })
            .collect();
        // Serial reference: dense sum in ascending micro order, mean.
        let mut serial = be.zeros_like_params();
        for grads in &per_micro {
            for (a, g) in serial.iter_mut().zip(grads) {
                a.add_assign(g);
            }
        }
        let scale = 1.0 / n as f32;
        for a in &mut serial {
            a.scale(scale);
        }
        let mut orders: Vec<(String, Vec<usize>)> =
            vec![("reverse".into(), (0..n).rev().collect())];
        for k in [2usize, 4] {
            // Worker w's stream is its micros in reverse; streams drain
            // round-robin: the worst-case interleaving a real cluster
            // of K stragglers could produce.
            // (`pop` drains each Vec from the back, so collecting
            // ascending yields descending delivery per worker.)
            let mut streams: Vec<Vec<usize>> =
                (0..k).map(|w| (0..n).filter(|i| i % k == w).collect()).collect();
            let mut order = Vec::with_capacity(n);
            while order.len() < n {
                for s in streams.iter_mut() {
                    if let Some(i) = s.pop() {
                        order.push(i);
                    }
                }
            }
            // Rotate so the first delivery is from the *last* worker.
            order.rotate_right(1);
            orders.push((format!("interleaved-K{k}"), order));
        }
        for (name, order) in orders {
            let mut reducer = OrderedReducer::new(n);
            for &i in &order {
                reducer.push(i, codec.encode(i, &masks[i], &per_micro[i]), 0).unwrap();
            }
            assert!(reducer.is_complete(), "{name}");
            let mut reduced = be.zeros_like_params();
            reducer.reduce(&codec, &masks, &mut reduced).unwrap();
            for (s, r) in serial.iter().zip(&reduced) {
                assert_eq!(
                    s.data(),
                    r.data(),
                    "{name}: arrival order must not change a single bit"
                );
            }
        }
    }

    #[test]
    fn into_blobs_returns_every_message_in_micro_order() {
        let mut r = OrderedReducer::new(3);
        r.push(2, vec![2, 2], 0).unwrap();
        r.push(0, vec![0], 0).unwrap();
        r.push(1, vec![9, 1, 1, 1], 1).unwrap();
        let blobs = r.into_blobs();
        assert_eq!(blobs, vec![vec![0], vec![9, 1, 1, 1], vec![2, 2]]);
    }

    #[test]
    fn reducer_rejects_misuse() {
        let be = backend();
        let codec = GradCodec::new(&be);
        let mut r = OrderedReducer::new(2);
        assert!(r.push(5, vec![], 0).is_err(), "out of range");
        assert!(r.push(1, vec![1, 2], 9).is_err(), "offset beyond frame");
        r.push(0, vec![1, 2, 3], 0).unwrap();
        assert!(r.push(0, vec![], 0).is_err(), "duplicate");
        assert!(!r.is_complete());
        let masks: Vec<MaskPair> = (0..2).map(|_| MaskPair::ones(2, 2)).collect();
        let mut acc = be.zeros_like_params();
        assert!(r.reduce(&codec, &masks, &mut acc).is_err(), "incomplete barrier");
    }
}
