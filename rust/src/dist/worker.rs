//! The dist worker: one replica's side of the protocol, generic over
//! the [`Transport`] it speaks.
//!
//! [`run_worker`] is the **only** worker implementation in the runtime
//! — an in-process thread over a [`super::transport::ChannelTransport`],
//! a thread over a loopback socket, a `repro dist-worker` subprocess,
//! and a worker on another machine all execute this exact function.
//! That is the heart of the cross-transport bitwise guarantee: there is
//! no second code path whose numerics could drift.
//!
//! A worker announces itself with a `Join` frame (protocol version
//! check), becomes a replica when its [`InitMsg`] arrives — built from
//! the message's `(spec, lora_rank, seed)`, bitwise identical to the
//! aggregator's and to every sibling — confirms readiness through the
//! transport barrier, then serves jobs until a shutdown or eviction
//! frame. A background heartbeat thread pings the aggregator every
//! `heartbeat_ms` so a busy (or deliberately stalled) worker reads as
//! *alive*, merely slow. With `overlap` the loop splits into a compute
//! thread and a dedicated sender thread over a bounded one-slot channel
//! — the PR 4 double-buffered pipeline, unchanged, just ending in
//! `send_blob` instead of a hardcoded mpsc.
//!
//! [`run_worker_with_faults`] threads a scripted
//! [`FaultPlan`](super::fault::FaultPlan) through the same loop: fault
//! actions trigger on the worker's gradient-send counter at *queueing*
//! time, which keeps every chaos scenario deterministic even under the
//! overlap pipeline.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

use super::fault::{FaultAction, FaultPlan};
use super::grads::{BufPool, GradCodec};
use super::proto::{
    decode_apply, decode_compute, decode_deltas, decode_init, decode_pong, decode_state,
    encode_bye, encode_join, encode_ping, encode_up_header, peek_tag, InitMsg, UpHdr,
    PROTO_VERSION, TAG_APPLY, TAG_COMPUTE, TAG_DELTAS, TAG_EVICT, TAG_PONG, TAG_RESET,
    TAG_SHUTDOWN, TAG_STATE, UP_GRAD_OFF,
};
use super::transport::{BlobRx, BlobTx, Transport};

/// The uplink half, shared between the compute/sender path and the
/// heartbeat thread. Every send takes the lock only for the actual
/// `send_blob` — simulated NIC delays sleep *outside* it, so a slow
/// wire never starves the heartbeat.
type SharedTx = Arc<Mutex<Box<dyn BlobTx>>>;

fn send_shared(tx: &SharedTx, frame: Vec<u8>) -> Result<()> {
    match tx.lock() {
        Ok(mut guard) => guard.send_blob(frame),
        Err(poisoned) => poisoned.into_inner().send_blob(frame),
    }
}

/// Compute-thread → sender-thread handoff (overlap mode): one computed
/// gradient awaiting encode + upload. The tensors are owned — the
/// sender never reads the replica.
struct Computed {
    micro: usize,
    loss: f32,
    n_correct: f32,
    masks: MaskPair,
    grads: Vec<Tensor>,
    ms: f64,
    step: u64,
}

/// What the serve loop should do after a frame (or fault action).
enum Flow {
    /// Keep serving.
    Continue,
    /// Clean shutdown: drain, send Bye, exit Ok.
    Shutdown,
    /// Abrupt exit: no Bye, just drop the link (scripted kill or an
    /// eviction notice) — the aggregator sees the peer vanish.
    Die,
}

/// Scripted-fault progress: actions trigger on the gradient-send
/// counter, decided at queueing time (deterministic under overlap).
struct FaultState {
    plan: FaultPlan,
    sends: usize,
}

enum SendVerdict {
    /// Compute and deliver normally.
    Send,
    /// Compute, but silently drop the gradient frame.
    Drop,
    /// Exit abruptly before computing (kill point reached).
    Die,
}

impl FaultState {
    fn new(plan: FaultPlan) -> FaultState {
        FaultState { plan, sends: 0 }
    }

    /// Consult the plan for gradient send number `self.sends`. Sleeps
    /// out any scheduled stall here, on the compute thread — the
    /// heartbeat thread keeps pinging, so a stalled worker reads as
    /// slow-but-alive, exactly the scenario the liveness window must
    /// not confuse with death.
    fn on_grad_send(&mut self) -> SendVerdict {
        let idx = self.sends;
        for a in &self.plan.actions {
            if let FaultAction::StallMs { after_micro, ms } = a {
                if *after_micro == idx {
                    thread::sleep(Duration::from_millis(*ms));
                }
            }
        }
        for a in &self.plan.actions {
            if let FaultAction::KillAfterMicro(n) = a {
                if idx >= *n {
                    return SendVerdict::Die;
                }
            }
        }
        self.sends += 1;
        for a in &self.plan.actions {
            if let FaultAction::DropUplinkFrame(n) = a {
                if *n == idx {
                    return SendVerdict::Drop;
                }
            }
        }
        SendVerdict::Send
    }
}

/// Sleep out the simulated NIC time for one `bytes`-sized message. A
/// sleep — not a spin — because a real NIC moves bytes by DMA without
/// burning a core: the sender thread must *wait* without stealing CPU
/// from the compute threads, or the measured overlap win would vanish
/// on core-saturated hosts for the wrong reason.
fn sim_wire_delay(bytes: usize, ms_per_mib: f64) {
    if ms_per_mib > 0.0 {
        let ms = bytes as f64 / (1024.0 * 1024.0) * ms_per_mib;
        thread::sleep(Duration::from_secs_f64(ms / 1e3));
    }
}

/// Encode one computed gradient into a recycled buffer (Up header +
/// codec payload as the frame tail), pay the optional simulated NIC
/// outside the uplink lock, and upload it.
fn encode_and_send(
    codec: &GradCodec,
    pool: &BufPool,
    wire_ms_per_mib: f64,
    tx: &SharedTx,
    c: Computed,
) -> Result<()> {
    let mut frame = pool.checkout();
    encode_up_header(
        &UpHdr {
            micro: c.micro,
            loss: c.loss,
            n_correct: c.n_correct,
            ms: c.ms,
            step: c.step,
        },
        &mut frame,
    );
    codec.encode_append(c.micro, &c.masks, &c.grads, &mut frame);
    sim_wire_delay(frame.len() - UP_GRAD_OFF, wire_ms_per_mib);
    send_shared(tx, frame)
}

/// Dispatch one decoded frame.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    frame: &[u8],
    be: &mut NativeBackend,
    codec: &GradCodec,
    init: &InitMsg,
    pool: &BufPool,
    sender_tx: &Option<mpsc::SyncSender<Computed>>,
    tx: &SharedTx,
    faults: &mut FaultState,
) -> Result<Flow> {
    match peek_tag(frame)? {
        TAG_COMPUTE => {
            let (step, jobs) = decode_compute(frame)?;
            for job in jobs {
                let verdict = faults.on_grad_send();
                if let SendVerdict::Die = verdict {
                    return Ok(Flow::Die);
                }
                let t0 = Instant::now();
                let (out, grads) = be
                    .grad_step(&job.x, &job.y, &job.masks)
                    .context("native grad step on worker")?;
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                if let SendVerdict::Drop = verdict {
                    continue;
                }
                let c = Computed {
                    micro: job.micro,
                    loss: out.loss,
                    n_correct: out.n_correct,
                    masks: job.masks,
                    grads,
                    ms,
                    step,
                };
                match sender_tx {
                    Some(stx) => stx
                        .send(c)
                        .map_err(|_| anyhow::anyhow!("sender thread exited early"))?,
                    None => {
                        encode_and_send(codec, pool, init.sim_wire_ms_per_mib, tx, c)?
                    }
                }
            }
            Ok(Flow::Continue)
        }
        TAG_APPLY => {
            let (lr, union, off) = decode_apply(frame)?;
            let mut acc = be.zeros_like_params();
            codec
                .decode_add(&frame[off..], &union, &mut acc)
                .context("decoding reduced gradient broadcast")?;
            be.apply_grads(&acc, lr).context("applying reduced gradient")?;
            Ok(Flow::Continue)
        }
        TAG_DELTAS => {
            let off = decode_deltas(frame)?;
            let deltas =
                codec.decode_dense(&frame[off..]).context("decoding delta broadcast")?;
            be.apply_deltas(&deltas).context("installing deltas")?;
            Ok(Flow::Continue)
        }
        TAG_STATE => {
            let (params, momentum) = decode_state(frame)?;
            be.import_state_flat(&params, &momentum)
                .context("installing aggregator state")?;
            Ok(Flow::Continue)
        }
        TAG_PONG => {
            decode_pong(frame)?;
            Ok(Flow::Continue)
        }
        TAG_RESET => {
            be.reset_momentum().context("resetting momentum")?;
            Ok(Flow::Continue)
        }
        TAG_EVICT => Ok(Flow::Die),
        TAG_SHUTDOWN => Ok(Flow::Shutdown),
        tag => anyhow::bail!("worker received unexpected frame tag {tag:#x}"),
    }
}

/// Serve one aggregator over `link` until it sends a shutdown frame,
/// with no scripted faults. See the module docs; returns an error
/// (never hangs) when the link dies or a frame is malformed.
pub fn run_worker(link: Box<dyn Transport>, pool: Arc<BufPool>) -> Result<()> {
    run_worker_with_faults(link, pool, FaultPlan::default())
}

/// [`run_worker`] with a scripted [`FaultPlan`] acted out against the
/// gradient-send counter (see [`super::fault`] for the grammar).
pub fn run_worker_with_faults(
    mut link: Box<dyn Transport>,
    pool: Arc<BufPool>,
    plan: FaultPlan,
) -> Result<()> {
    // Announce ourselves first: the Join frame carries the protocol
    // version so a mismatched worker is rejected descriptively at the
    // aggregator instead of misparsing frames mid-run.
    let mut join = pool.checkout();
    encode_join(PROTO_VERSION, &mut join);
    link.send_blob(join).context("sending Join")?;
    let frame = link.recv_blob().context("waiting for Init")?;
    let init = decode_init(&frame)?;
    pool.give_back(frame);
    let be = NativeBackend::new(&init.spec, init.lora_rank, init.spec.micro_batch, init.seed);
    let codec = Arc::new(GradCodec::new(&be).with_precision(init.precision));
    // Replica built: release the aggregator's handshake.
    link.barrier().context("worker handshake barrier")?;
    let (tx, rx) = link.split();
    serve(be, codec, &init, rx, tx, pool, plan)
}

/// The post-handshake serve loop (compute thread).
fn serve(
    mut be: NativeBackend,
    codec: Arc<GradCodec>,
    init: &InitMsg,
    mut rx: Box<dyn BlobRx>,
    tx: Box<dyn BlobTx>,
    pool: Arc<BufPool>,
    plan: FaultPlan,
) -> Result<()> {
    let tx: SharedTx = Arc::new(Mutex::new(tx));
    let mut faults = FaultState::new(plan);

    // Heartbeat thread: pings every `heartbeat_ms` until stopped (or
    // the uplink dies — then the aggregator already knows more than a
    // missing ping could tell it).
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_handle = if init.heartbeat_ms > 0 {
        let tx = Arc::clone(&tx);
        let pool = Arc::clone(&pool);
        let stop = Arc::clone(&hb_stop);
        let interval = Duration::from_millis(init.heartbeat_ms);
        Some(
            thread::Builder::new()
                .name(format!("d2ft-dist-{}-hb", init.worker))
                .spawn(move || {
                    let mut seq = 0u64;
                    'beat: loop {
                        // Sleep in slices so shutdown joins promptly.
                        let mut slept = Duration::ZERO;
                        while slept < interval {
                            let slice = (interval - slept).min(Duration::from_millis(50));
                            thread::sleep(slice);
                            slept += slice;
                            if stop.load(Ordering::Relaxed) {
                                break 'beat;
                            }
                        }
                        let mut ping = pool.checkout();
                        encode_ping(seq, &mut ping);
                        seq += 1;
                        if send_shared(&tx, ping).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawning dist heartbeat thread"),
        )
    } else {
        None
    };

    // With overlap a dedicated sender thread drains the one-slot queue;
    // it shares the uplink with the heartbeat via the mutex.
    let (sender_tx, sender_handle) = if init.overlap {
        let (stx, srx) = mpsc::sync_channel::<Computed>(1);
        let codec = Arc::clone(&codec);
        let pool = Arc::clone(&pool);
        let tx = Arc::clone(&tx);
        let wire_ms = init.sim_wire_ms_per_mib;
        let handle = thread::Builder::new()
            .name(format!("d2ft-dist-{}-tx", init.worker))
            .spawn(move || {
                while let Ok(c) = srx.recv() {
                    if encode_and_send(&codec, &pool, wire_ms, &tx, c).is_err() {
                        // Aggregator gone: stop draining; the compute
                        // thread will notice on its own half.
                        break;
                    }
                }
            })
            .expect("spawning dist sender thread");
        (Some(stx), Some(handle))
    } else {
        (None, None)
    };

    let mut result = Ok(());
    let mut dying = false;
    loop {
        let frame = match rx.recv_blob() {
            Ok(f) => f,
            Err(e) => {
                result = Err(e.context("receiving job frame"));
                break;
            }
        };
        let flow = handle_frame(&frame, &mut be, &codec, init, &pool, &sender_tx, &tx, &mut faults);
        pool.give_back(frame);
        match flow {
            Ok(Flow::Continue) => continue,
            Ok(Flow::Shutdown) => break,
            Ok(Flow::Die) => {
                dying = true;
                break;
            }
            Err(e) => {
                result = Err(e);
                break;
            }
        }
    }

    // Drain the pipeline: by the time a Shutdown frame arrives the
    // aggregator has received every gradient of every batch; on a
    // scripted kill the queued (pre-kill) sends still flush, keeping
    // the delivered-gradient count exact.
    drop(sender_tx);
    if let Some(h) = sender_handle {
        h.join().expect("joining dist sender thread");
    }
    hb_stop.store(true, Ordering::Relaxed);
    if let Some(h) = hb_handle {
        h.join().expect("joining dist heartbeat thread");
    }
    if dying {
        // Abrupt exit: no Bye — dropping the uplink is the message.
        return Ok(());
    }
    if result.is_ok() {
        let mut bye = pool.checkout();
        encode_bye(pool.fresh_allocs(), pool.reuses(), &mut bye);
        result = send_shared(&tx, bye).context("sending Bye");
    }
    result
}
