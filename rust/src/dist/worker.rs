//! The dist worker: one replica's side of the protocol, generic over
//! the [`Transport`] it speaks.
//!
//! [`run_worker`] is the **only** worker implementation in the runtime
//! — an in-process thread over a [`super::transport::ChannelTransport`],
//! a thread over a loopback socket, a `repro dist-worker` subprocess,
//! and a worker on another machine all execute this exact function.
//! That is the heart of the cross-transport bitwise guarantee: there is
//! no second code path whose numerics could drift.
//!
//! A worker announces itself with a `Join` frame (protocol version
//! check), becomes a replica when its [`InitMsg`] arrives — built from
//! the message's `(spec, lora_rank, seed)`, bitwise identical to the
//! aggregator's and to every sibling — confirms readiness through the
//! transport barrier, then serves jobs until a shutdown or eviction
//! frame. A background heartbeat thread pings the aggregator every
//! `heartbeat_ms` so a busy (or deliberately stalled) worker reads as
//! *alive*, merely slow. With `overlap` the loop splits into a compute
//! thread and a dedicated sender thread over a bounded one-slot channel
//! — the PR 4 double-buffered pipeline, unchanged, just ending in
//! `send_blob` instead of a hardcoded mpsc.
//!
//! [`run_worker_with_faults`] threads a scripted
//! [`FaultPlan`](super::fault::FaultPlan) through the same loop: fault
//! actions trigger on the worker's gradient-send counter at *queueing*
//! time, which keeps every chaos scenario deterministic even under the
//! overlap pipeline.
//!
//! [`run_worker_reconnecting`] is the durable TCP entry point: it
//! redials a broken link with capped exponential [`Backoff`] + jitter,
//! re-presenting the [`WorkerSession`] identity (worker id +
//! incarnation token) learned from the first Init — which is how a
//! worker outlives both transient resets and a full aggregator
//! restart. A corrupt inbound frame at the aggregator comes back as a
//! `Nack`, answered by resending the retained last Up frame; the step
//! stamp makes an unnecessary resend harmless.
//!
//! ## Ring mode
//!
//! When `InitMsg.ring` is set the worker *holds* its computed
//! micro-gradients locally (each `Compute` frame **replaces** the held
//! set for its step, so reassignment can never double-count a micro)
//! and sends metric-only `Up` frames. Gradients then move over direct
//! worker↔worker links: the aggregator negotiates them with
//! `RingListen`/`RingPeers` frames, and a `RingExec` frame drives one
//! exchange — receive the predecessor's partial sum, fold own micros in
//! ascending order (through the codec, so the bits match the star
//! reduce exactly), forward, and finally apply the distributed result.
//! Every apply is acknowledged with a `RingReady` frame so the
//! aggregator can hold the next batch until all replicas moved in
//! lockstep; a `RingReset` aborts an in-flight exchange (the worker
//! drops its links and waits for renegotiation).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

use crate::obs::trace;

use super::fault::{FaultAction, FaultPlan};
use super::grads::{BufPool, GradCodec};
use super::proto::{
    decode_apply, decode_compute, decode_deltas, decode_init, decode_nack, decode_pong,
    decode_ring_cast, decode_ring_castd, decode_ring_exec, decode_ring_listen, decode_ring_part,
    decode_ring_peers, decode_ring_reset, decode_state, encode_bye, encode_join, encode_ping,
    encode_ring_addr, encode_ring_cast_header, encode_ring_final_header, encode_ring_part_header,
    encode_ring_ready, encode_trace, encode_up_header, peek_tag, ByeMsg, CastRole, InitMsg,
    JoinMsg, RingExec, UpHdr, PROTO_VERSION, TAG_APPLY, TAG_COMPUTE, TAG_DELTAS, TAG_EVICT,
    TAG_NACK, TAG_PONG, TAG_RESET, TAG_RING_CASTD, TAG_RING_EXEC, TAG_RING_LISTEN, TAG_RING_PEERS,
    TAG_RING_RESET, TAG_SHUTDOWN, TAG_STATE, UP_GRAD_OFF,
};
use super::transport::{
    ring_connect, BlobRx, BlobTx, FlakyState, FlakyTransport, RingListener, TcpTransport,
    Transport,
};

/// The uplink half, shared between the compute/sender path and the
/// heartbeat thread. Every send takes the lock only for the actual
/// `send_blob` — simulated NIC delays sleep *outside* it, so a slow
/// wire never starves the heartbeat.
type SharedTx = Arc<Mutex<Box<dyn BlobTx>>>;

fn send_shared(tx: &SharedTx, frame: Vec<u8>) -> Result<()> {
    match tx.lock() {
        Ok(mut guard) => guard.send_blob(frame),
        Err(poisoned) => poisoned.into_inner().send_blob(frame),
    }
}

/// Drain this process's trace rings and ship them home in a
/// `TAG_TRACE` frame (no-op unless the Init armed tracing). Called on
/// every epoch beacon (Pong) and once more before the Bye, so the
/// aggregator holds the full worker timeline by the time it writes the
/// merged artifact.
fn flush_trace(init: &InitMsg, offset_us: i64, tx: &SharedTx, pool: &BufPool) -> Result<()> {
    if !init.trace {
        return Ok(());
    }
    let batch = trace::drain();
    let mut frame = pool.checkout();
    encode_trace(init.worker, offset_us, batch.truncated, &batch.events, &mut frame);
    send_shared(tx, frame).context("sending trace batch")
}

/// Compute-thread → sender-thread handoff (overlap mode): one computed
/// gradient awaiting encode + upload. The tensors are owned — the
/// sender never reads the replica.
struct Computed {
    micro: usize,
    loss: f32,
    n_correct: f32,
    masks: MaskPair,
    grads: Vec<Tensor>,
    ms: f64,
    step: u64,
}

/// What the serve loop should do after a frame (or fault action).
enum Flow {
    /// Keep serving.
    Continue,
    /// Clean shutdown: drain, send Bye, exit Ok.
    Shutdown,
    /// Abrupt exit: no Bye, just drop the link (scripted kill or an
    /// eviction notice) — the aggregator sees the peer vanish.
    Die,
    /// Run one ring exchange (needs the receive half, so it cannot run
    /// inside the frame handler).
    Ring(RingExec),
}

/// Gradients held for the ring exchange of one step: `(step, entries)`
/// where each entry is `(micro, masks, grads)`. A `Compute` frame for a
/// step replaces the whole set, so the held micros are always exactly
/// the aggregator's latest block assignment.
type HeldStep = (u64, Vec<(usize, MaskPair, Vec<Tensor>)>);

/// The worker's ring-collective state: negotiated links, the cached
/// marching orders of the newest exchange (for the aggregator's
/// direct-cast recovery path), and byte counters that survive link
/// teardown (reported in the Bye frame).
struct RingState {
    listener: Option<RingListener>,
    /// Link to the ring successor (we send).
    out: Option<Box<dyn Transport>>,
    /// Link from the ring predecessor (we receive).
    inl: Option<Box<dyn Transport>>,
    /// The newest `RingExec` — kept so a post-abort `RingCastDown` on
    /// the main link can still be applied (`lr`/`n_micros`/union live
    /// here, not in the cast frame).
    last_exec: Option<RingExec>,
    /// Highest step whose reduced gradient was applied; makes the
    /// apply idempotent when the recovery path re-delivers a cast.
    last_applied: u64,
    sent: u64,
    recv: u64,
}

impl RingState {
    fn new() -> RingState {
        RingState {
            listener: None,
            out: None,
            inl: None,
            last_exec: None,
            last_applied: 0,
            sent: 0,
            recv: 0,
        }
    }

    fn fold(&mut self, link: Box<dyn Transport>) {
        let s = link.stats();
        self.sent += s.bytes_sent;
        self.recv += s.bytes_recv;
    }

    fn drop_out(&mut self) {
        if let Some(l) = self.out.take() {
            self.fold(l);
        }
    }

    fn drop_in(&mut self) {
        if let Some(l) = self.inl.take() {
            self.fold(l);
        }
    }

    /// Tear down both peer links and the listener (reset or
    /// renegotiation); the byte counters keep accumulating.
    fn drop_links(&mut self) {
        self.drop_out();
        self.drop_in();
        self.listener = None;
    }

    /// Send a blob to the ring successor. `false` means the successor
    /// is gone — the caller falls back to waiting for the aggregator's
    /// reset instead of dying (the failure detector owns membership).
    fn send_out(&mut self, blob: Vec<u8>) -> bool {
        match self.out.as_mut() {
            Some(out) => match out.send_blob(blob) {
                Ok(()) => true,
                Err(_) => {
                    self.drop_out();
                    false
                }
            },
            None => false,
        }
    }
}

/// How one ring exchange ended.
enum RingOutcome {
    /// Exchange complete, update applied and acknowledged.
    Done,
    /// Aggregator reset the exchange; links were dropped and the serve
    /// loop resumes (renegotiation frames follow).
    Aborted,
    /// Eviction notice mid-exchange.
    Die,
    /// Shutdown frame mid-exchange.
    Shutdown,
}

/// A frame from the *aggregator* link observed while a ring exchange is
/// in flight.
enum MainEvent {
    /// Heartbeat ack or a stale frame — keep waiting.
    Ignore,
    /// Reset for this (or a newer) step.
    Abort,
    Die,
    Shutdown,
    /// Hierarchical distribute: the final gradient, aggregator → leader.
    Castd { hops: u32, blob: Vec<u8>, off: usize },
}

/// Classify one main-link frame received mid-exchange. Consumes the
/// frame (recycled unless returned inside the event).
fn ring_main_event(frame: Vec<u8>, step: u64, pool: &BufPool) -> Result<MainEvent> {
    match peek_tag(&frame)? {
        TAG_PONG => {
            decode_pong(&frame)?;
            pool.give_back(frame);
            Ok(MainEvent::Ignore)
        }
        TAG_RING_RESET => {
            let s = decode_ring_reset(&frame)?;
            pool.give_back(frame);
            Ok(if s >= step { MainEvent::Abort } else { MainEvent::Ignore })
        }
        TAG_EVICT => {
            pool.give_back(frame);
            Ok(MainEvent::Die)
        }
        TAG_SHUTDOWN => {
            pool.give_back(frame);
            Ok(MainEvent::Shutdown)
        }
        TAG_RING_CASTD => {
            let (s, hops, off) = decode_ring_castd(&frame)?;
            if s == step {
                Ok(MainEvent::Castd { hops, blob: frame, off })
            } else {
                pool.give_back(frame);
                Ok(MainEvent::Ignore)
            }
        }
        tag => anyhow::bail!("unexpected frame tag {tag:#x} on the main link mid-ring-exchange"),
    }
}

/// What a wait on the predecessor link produced.
enum LinkWait {
    Blob { blob: Vec<u8>, off: usize, hops: u32 },
    Abort,
    Die,
    Shutdown,
}

/// Wait for the predecessor's next ring blob (`RingPart` during the
/// reduce leg, `RingCast` during the distribute leg), alternating with
/// short polls of the aggregator link so a reset, eviction, or shutdown
/// is honored promptly. A dead predecessor is not fatal: its link is
/// dropped and the wait continues on the main link only — the
/// aggregator's failure detector will reset the exchange.
fn ring_wait_link(
    ring: &mut RingState,
    rx: &mut dyn BlobRx,
    pool: &BufPool,
    step: u64,
    want_cast: bool,
) -> Result<LinkWait> {
    loop {
        let main_window =
            if ring.inl.is_some() { Duration::from_millis(1) } else { Duration::from_millis(50) };
        if let Some(frame) = rx.recv_blob_timeout(main_window)? {
            match ring_main_event(frame, step, pool)? {
                MainEvent::Ignore => {}
                MainEvent::Abort => return Ok(LinkWait::Abort),
                MainEvent::Die => return Ok(LinkWait::Die),
                MainEvent::Shutdown => return Ok(LinkWait::Shutdown),
                MainEvent::Castd { .. } => {
                    anyhow::bail!("cast-down arrived while waiting on a ring peer blob")
                }
            }
        }
        let Some(inl) = ring.inl.as_mut() else { continue };
        match inl.recv_blob_timeout(Duration::from_millis(50)) {
            Ok(None) => {}
            Ok(Some(blob)) => {
                let (s, off, hops) = if want_cast {
                    let (s, hops, off) = decode_ring_cast(&blob)?;
                    (s, off, hops)
                } else {
                    let (s, off) = decode_ring_part(&blob)?;
                    (s, off, 0)
                };
                if s < step {
                    // A leftover blob from an aborted attempt.
                    pool.give_back(blob);
                    continue;
                }
                anyhow::ensure!(s == step, "ring blob for future step {s} during step {step}");
                return Ok(LinkWait::Blob { blob, off, hops });
            }
            Err(_) => {
                // Predecessor died mid-exchange; wait for the reset.
                ring.drop_in();
            }
        }
    }
}

/// After a dead successor swallowed a send: hold position until the
/// aggregator resets the exchange (or evicts / shuts us down).
fn ring_wait_abort(rx: &mut dyn BlobRx, pool: &BufPool, step: u64) -> Result<RingOutcome> {
    loop {
        if let Some(frame) = rx.recv_blob_timeout(Duration::from_millis(50))? {
            match ring_main_event(frame, step, pool)? {
                MainEvent::Ignore => {}
                MainEvent::Abort => return Ok(RingOutcome::Aborted),
                MainEvent::Die => return Ok(RingOutcome::Die),
                MainEvent::Shutdown => return Ok(RingOutcome::Shutdown),
                // The aggregator has not noticed the dead peer yet; the
                // reset will follow. The blob inside was recycled by
                // the event classifier only for stale steps, so recycle
                // this one here.
                MainEvent::Castd { blob, .. } => pool.give_back(blob),
            }
        }
    }
}

/// Decode the final reduced gradient, scale it to the batch mean, and
/// apply — exactly the serial trainer's op order (`sum → ×1/n →
/// apply`), on the exact bytes every replica decodes. Idempotent per
/// step (the recovery path may deliver the same cast twice); always
/// acknowledged with a `RingReady` so the aggregator can hold the next
/// batch until every replica has moved.
fn ring_apply(
    be: &mut NativeBackend,
    codec: &GradCodec,
    exec: &RingExec,
    payload: &[u8],
    last_applied: &mut u64,
    tx: &SharedTx,
    pool: &BufPool,
) -> Result<()> {
    if *last_applied < exec.step {
        let mut acc = be.zeros_like_params();
        codec
            .decode_add(payload, &exec.union, &mut acc)
            .context("decoding the ring-reduced gradient")?;
        let scale = 1.0 / exec.n_micros as f32;
        for t in acc.iter_mut() {
            t.scale(scale);
        }
        be.apply_grads(&acc, exec.lr).context("applying the ring-reduced gradient")?;
        *last_applied = exec.step;
    }
    let mut ack = pool.checkout();
    encode_ring_ready(exec.step, &mut ack);
    send_shared(tx, ack).context("acknowledging the ring apply")
}

/// Run one ring exchange end to end: reduce leg (receive partial, fold
/// held micros, forward or finish), then distribute leg (cast per the
/// assigned [`CastRole`]) and the local apply.
#[allow(clippy::too_many_arguments)]
fn ring_exec(
    be: &mut NativeBackend,
    codec: &GradCodec,
    exec: &RingExec,
    held: &Option<HeldStep>,
    ef: &mut Option<Vec<Tensor>>,
    ring: &mut RingState,
    rx: &mut dyn BlobRx,
    tx: &SharedTx,
    pool: &BufPool,
) -> Result<RingOutcome> {
    let step = exec.step;
    let union = &exec.union;
    let _sp = trace::span("ring", "ring_exec");
    // --- Reduce leg: partial sum in chain order -----------------------
    let mut acc = be.zeros_like_params();
    if exec.has_in {
        match ring_wait_link(ring, rx, pool, step, false)? {
            LinkWait::Blob { blob, off, .. } => {
                codec
                    .decode_add(&blob[off..], union, &mut acc)
                    .context("decoding the predecessor's partial sum")?;
                pool.give_back(blob);
            }
            LinkWait::Abort => return Ok(RingOutcome::Aborted),
            LinkWait::Die => return Ok(RingOutcome::Die),
            LinkWait::Shutdown => return Ok(RingOutcome::Shutdown),
        }
    }
    // Fold the held micros in ascending order through an encode→decode
    // round trip: the accumulator sees the exact bits the star
    // aggregator would have reduced (masked slices only, plus any
    // precision/compression loss and error feedback).
    if let Some((hstep, entries)) = held {
        if *hstep == step {
            let mut order: Vec<usize> = (0..entries.len()).collect();
            order.sort_by_key(|&i| entries[i].0);
            let mut tmp = pool.checkout();
            for i in order {
                let (micro, masks, grads) = &entries[i];
                codec.encode_into_ef(
                    *micro,
                    masks,
                    grads,
                    ef.as_mut().map(|v| v.as_mut_slice()),
                    &mut tmp,
                );
                codec
                    .decode_add(&tmp, masks, &mut acc)
                    .context("folding a held micro-gradient")?;
            }
            pool.give_back(tmp);
        }
    }
    // Ship the updated partial (or hand the finished sum up).
    let mut payload = pool.checkout();
    codec.encode_into(0, union, &acc, &mut payload);
    let delivered = if exec.is_last {
        let mut frame = pool.checkout();
        encode_ring_final_header(step, &mut frame);
        frame.extend_from_slice(&payload);
        send_shared(tx, frame).context("sending the ring final to the aggregator")?;
        true
    } else {
        let mut frame = pool.checkout();
        encode_ring_part_header(step, &mut frame);
        frame.extend_from_slice(&payload);
        ring.send_out(frame)
    };
    if !delivered {
        pool.give_back(payload);
        return ring_wait_abort(rx, pool, step);
    }
    trace::instant("ring", if exec.is_last { "final_sent" } else { "part_forwarded" });
    // --- Distribute leg + apply ---------------------------------------
    match exec.cast {
        CastRole::Origin { hops } => {
            if hops > 0 {
                let mut frame = pool.checkout();
                encode_ring_cast_header(step, hops, &mut frame);
                frame.extend_from_slice(&payload);
                if !ring.send_out(frame) {
                    pool.give_back(payload);
                    return ring_wait_abort(rx, pool, step);
                }
                trace::instant("ring", "cast_originated");
            }
            ring_apply(be, codec, exec, &payload, &mut ring.last_applied, tx, pool)?;
            pool.give_back(payload);
        }
        CastRole::Leader { hops } => {
            pool.give_back(payload);
            // The final bytes come straight from the aggregator.
            loop {
                let Some(frame) = rx.recv_blob_timeout(Duration::from_millis(50))? else {
                    continue;
                };
                match ring_main_event(frame, step, pool)? {
                    MainEvent::Ignore => {}
                    MainEvent::Abort => return Ok(RingOutcome::Aborted),
                    MainEvent::Die => return Ok(RingOutcome::Die),
                    MainEvent::Shutdown => return Ok(RingOutcome::Shutdown),
                    MainEvent::Castd { hops: _, blob, off } => {
                        if hops > 0 {
                            let mut fwd = pool.checkout();
                            encode_ring_cast_header(step, hops, &mut fwd);
                            fwd.extend_from_slice(&blob[off..]);
                            if !ring.send_out(fwd) {
                                pool.give_back(blob);
                                return ring_wait_abort(rx, pool, step);
                            }
                        }
                        ring_apply(
                            be,
                            codec,
                            exec,
                            &blob[off..],
                            &mut ring.last_applied,
                            tx,
                            pool,
                        )?;
                        pool.give_back(blob);
                        break;
                    }
                }
            }
        }
        CastRole::Member => {
            pool.give_back(payload);
            match ring_wait_link(ring, rx, pool, step, true)? {
                LinkWait::Blob { mut blob, off, hops } => {
                    ring_apply(
                        be,
                        codec,
                        exec,
                        &blob[off..],
                        &mut ring.last_applied,
                        tx,
                        pool,
                    )?;
                    if hops > 1 {
                        // Decrement the hop count in place; the gradient
                        // bytes travel on verbatim.
                        blob[12..16].copy_from_slice(&(hops - 1).to_le_bytes());
                        if !ring.send_out(blob) {
                            return ring_wait_abort(rx, pool, step);
                        }
                        trace::instant("ring", "cast_forwarded");
                    } else {
                        pool.give_back(blob);
                    }
                }
                LinkWait::Abort => return Ok(RingOutcome::Aborted),
                LinkWait::Die => return Ok(RingOutcome::Die),
                LinkWait::Shutdown => return Ok(RingOutcome::Shutdown),
            }
        }
    }
    Ok(RingOutcome::Done)
}

/// Scripted-fault progress: actions trigger on the gradient-send
/// counter, decided at queueing time (deterministic under overlap).
struct FaultState {
    plan: FaultPlan,
    sends: usize,
}

enum SendVerdict {
    /// Compute and deliver normally.
    Send,
    /// Compute, but silently drop the gradient frame.
    Drop,
    /// Exit abruptly before computing (kill point reached).
    Die,
}

impl FaultState {
    fn new(plan: FaultPlan) -> FaultState {
        FaultState { plan, sends: 0 }
    }

    /// Consult the plan for gradient send number `self.sends`. Sleeps
    /// out any scheduled stall here, on the compute thread — the
    /// heartbeat thread keeps pinging, so a stalled worker reads as
    /// slow-but-alive, exactly the scenario the liveness window must
    /// not confuse with death.
    fn on_grad_send(&mut self) -> SendVerdict {
        let idx = self.sends;
        for a in &self.plan.actions {
            if let FaultAction::StallMs { after_micro, ms } = a {
                if *after_micro == idx {
                    thread::sleep(Duration::from_millis(*ms));
                }
            }
        }
        for a in &self.plan.actions {
            if let FaultAction::KillAfterMicro(n) = a {
                if idx >= *n {
                    return SendVerdict::Die;
                }
            }
        }
        self.sends += 1;
        for a in &self.plan.actions {
            if let FaultAction::DropUplinkFrame(n) = a {
                if *n == idx {
                    return SendVerdict::Drop;
                }
            }
        }
        SendVerdict::Send
    }
}

/// Sleep out the simulated NIC time for one `bytes`-sized message. A
/// sleep — not a spin — because a real NIC moves bytes by DMA without
/// burning a core: the sender thread must *wait* without stealing CPU
/// from the compute threads, or the measured overlap win would vanish
/// on core-saturated hosts for the wrong reason.
fn sim_wire_delay(bytes: usize, ms_per_mib: f64) {
    if ms_per_mib > 0.0 {
        let ms = bytes as f64 / (1024.0 * 1024.0) * ms_per_mib;
        thread::sleep(Duration::from_secs_f64(ms / 1e3));
    }
}

/// The last Up frame's bytes, kept for a NACK resend. One slot is
/// enough: the aggregator detects corruption on arrival and NACKs
/// before the worker computes the next micro, and a duplicate resend
/// is dropped idempotently by its step stamp anyway.
type Retained = Arc<Mutex<Vec<u8>>>;

fn retain_frame(retained: &Retained, frame: &[u8]) {
    let mut slot = match retained.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    slot.clear();
    slot.extend_from_slice(frame);
}

/// Encode one computed gradient into a recycled buffer (Up header +
/// codec payload as the frame tail), pay the optional simulated NIC
/// outside the uplink lock, and upload it. `ef` is the worker's
/// error-feedback residual state, threaded through every lossy encode
/// so quantization error carries to the next step instead of vanishing.
/// A copy of the encoded frame is retained for a NACK resend.
fn encode_and_send(
    codec: &GradCodec,
    pool: &BufPool,
    wire_ms_per_mib: f64,
    tx: &SharedTx,
    ef: &mut Option<Vec<Tensor>>,
    retained: &Retained,
    c: Computed,
) -> Result<()> {
    let mut frame = pool.checkout();
    encode_up_header(
        &UpHdr {
            micro: c.micro,
            loss: c.loss,
            n_correct: c.n_correct,
            ms: c.ms,
            step: c.step,
        },
        &mut frame,
    );
    codec.encode_append_ef(
        c.micro,
        &c.masks,
        &c.grads,
        ef.as_mut().map(|v| v.as_mut_slice()),
        &mut frame,
    );
    retain_frame(retained, &frame);
    sim_wire_delay(frame.len() - UP_GRAD_OFF, wire_ms_per_mib);
    send_shared(tx, frame)
}

/// Dispatch one decoded frame.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    frame: &[u8],
    be: &mut NativeBackend,
    codec: &GradCodec,
    init: &InitMsg,
    trace_offset_us: i64,
    pool: &Arc<BufPool>,
    sender_tx: &Option<mpsc::SyncSender<Computed>>,
    tx: &SharedTx,
    faults: &mut FaultState,
    ring: &mut RingState,
    held: &mut Option<HeldStep>,
    ef: &mut Option<Vec<Tensor>>,
    retained: &Retained,
) -> Result<Flow> {
    match peek_tag(frame)? {
        TAG_COMPUTE if init.ring => {
            // Ring mode: compute, hold the gradients for the exchange,
            // and report metrics only. The frame's job list REPLACES
            // the held set for its step — reassignment after a stall or
            // eviction resends whole blocks, so a micro can never be
            // folded twice.
            let (step, jobs) = decode_compute(frame)?;
            let mut entries = Vec::with_capacity(jobs.len());
            for job in jobs {
                let verdict = faults.on_grad_send();
                if let SendVerdict::Die = verdict {
                    return Ok(Flow::Die);
                }
                let t0 = Instant::now();
                let (out, grads) = {
                    let _sp = trace::span("compute", "grad_step");
                    be.grad_step(&job.x, &job.y, &job.masks)
                        .context("native grad step on worker")?
                };
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                if !matches!(verdict, SendVerdict::Drop) {
                    let mut up = pool.checkout();
                    encode_up_header(
                        &UpHdr {
                            micro: job.micro,
                            loss: out.loss,
                            n_correct: out.n_correct,
                            ms,
                            step,
                        },
                        &mut up,
                    );
                    retain_frame(retained, &up);
                    send_shared(tx, up).context("sending metric-only Up")?;
                }
                entries.push((job.micro, job.masks, grads));
            }
            *held = Some((step, entries));
            Ok(Flow::Continue)
        }
        TAG_COMPUTE => {
            let (step, jobs) = decode_compute(frame)?;
            for job in jobs {
                let verdict = faults.on_grad_send();
                if let SendVerdict::Die = verdict {
                    return Ok(Flow::Die);
                }
                let t0 = Instant::now();
                let (out, grads) = {
                    let _sp = trace::span("compute", "grad_step");
                    be.grad_step(&job.x, &job.y, &job.masks)
                        .context("native grad step on worker")?
                };
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                if let SendVerdict::Drop = verdict {
                    continue;
                }
                let c = Computed {
                    micro: job.micro,
                    loss: out.loss,
                    n_correct: out.n_correct,
                    masks: job.masks,
                    grads,
                    ms,
                    step,
                };
                match sender_tx {
                    Some(stx) => stx
                        .send(c)
                        .map_err(|_| anyhow::anyhow!("sender thread exited early"))?,
                    None => encode_and_send(
                        codec,
                        pool,
                        init.sim_wire_ms_per_mib,
                        tx,
                        ef,
                        retained,
                        c,
                    )?,
                }
            }
            Ok(Flow::Continue)
        }
        TAG_RING_LISTEN => {
            let (tcp, nonce) = decode_ring_listen(frame)?;
            // A fresh negotiation tears down everything from the old
            // topology first: stale links must not deliver stale blobs
            // into the next exchange.
            ring.drop_links();
            trace::instant("ring", "negotiate_listen");
            let listener = RingListener::open(tcp).context("opening ring listener")?;
            let mut reply = pool.checkout();
            encode_ring_addr(nonce, &listener.addr(), &mut reply);
            ring.listener = Some(listener);
            send_shared(tx, reply).context("sending ring listener address")?;
            Ok(Flow::Continue)
        }
        TAG_RING_PEERS => {
            let (nonce, succ, accept) = decode_ring_peers(frame)?;
            // Connect-then-accept is deadlock-free because the
            // aggregator only sends Peers after every listener is up.
            if !succ.is_empty() {
                let link = ring_connect(&succ, Duration::from_secs(10), Arc::clone(pool))
                    .context("dialing ring successor")?;
                ring.out = Some(link);
            }
            if accept {
                let listener = ring
                    .listener
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("ring peers before a listener was opened"))?;
                let link = listener
                    .accept(Duration::from_secs(10), Arc::clone(pool))
                    .context("accepting ring predecessor")?;
                ring.inl = Some(link);
            }
            let mut reply = pool.checkout();
            encode_ring_ready(nonce, &mut reply);
            send_shared(tx, reply).context("confirming ring links")?;
            trace::instant("ring", "negotiate_ready");
            Ok(Flow::Continue)
        }
        TAG_RING_EXEC => Ok(Flow::Ring(decode_ring_exec(frame)?)),
        TAG_RING_RESET => {
            // A reset outside an exchange: the aggregator is about to
            // renegotiate — drop the old topology, keep the held
            // gradients (a re-dispatch will replace them).
            decode_ring_reset(frame)?;
            ring.drop_links();
            Ok(Flow::Continue)
        }
        TAG_RING_CASTD => {
            // Recovery path: the exchange aborted mid-distribute, and
            // the aggregator re-delivers the final bytes directly. The
            // apply is idempotent, the ack unconditional.
            let (step, _hops, off) = decode_ring_castd(frame)?;
            let exec = ring
                .last_exec
                .clone()
                .ok_or_else(|| anyhow::anyhow!("direct cast before any ring exchange"))?;
            anyhow::ensure!(
                exec.step == step,
                "direct cast for step {step} but the last exchange was step {}",
                exec.step
            );
            let mut last = ring.last_applied;
            ring_apply(be, codec, &exec, &frame[off..], &mut last, tx, pool)?;
            ring.last_applied = last;
            Ok(Flow::Continue)
        }
        TAG_APPLY => {
            let (lr, union, off) = decode_apply(frame)?;
            let mut acc = be.zeros_like_params();
            codec
                .decode_add(&frame[off..], &union, &mut acc)
                .context("decoding reduced gradient broadcast")?;
            be.apply_grads(&acc, lr).context("applying reduced gradient")?;
            Ok(Flow::Continue)
        }
        TAG_DELTAS => {
            let off = decode_deltas(frame)?;
            let deltas =
                codec.decode_dense(&frame[off..]).context("decoding delta broadcast")?;
            be.apply_deltas(&deltas).context("installing deltas")?;
            Ok(Flow::Continue)
        }
        TAG_STATE => {
            let (params, momentum) = decode_state(frame)?;
            be.import_state_flat(&params, &momentum)
                .context("installing aggregator state")?;
            Ok(Flow::Continue)
        }
        TAG_PONG => {
            // The Pong doubles as the epoch beacon: flush the local
            // trace rings home so the merged artifact stays bounded by
            // one epoch of events per worker.
            decode_pong(frame)?;
            flush_trace(init, trace_offset_us, tx, pool)?;
            Ok(Flow::Continue)
        }
        TAG_NACK => {
            // The aggregator saw our last frame arrive corrupt: resend
            // the retained copy. No retained frame (e.g. the corrupt
            // frame was a heartbeat) is fine — the stall detector
            // re-dispatches lost work, and the NACK itself told us
            // nothing was poisoned.
            let step = decode_nack(frame)?;
            let copy = {
                let slot = match retained.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                slot.clone()
            };
            if !copy.is_empty() {
                trace::instant("hb", "nack_resend");
                let mut resend = pool.checkout();
                resend.extend_from_slice(&copy);
                send_shared(tx, resend)
                    .with_context(|| format!("resending after a NACK for step {step}"))?;
            }
            Ok(Flow::Continue)
        }
        TAG_RESET => {
            be.reset_momentum().context("resetting momentum")?;
            Ok(Flow::Continue)
        }
        TAG_EVICT => Ok(Flow::Die),
        TAG_SHUTDOWN => Ok(Flow::Shutdown),
        tag => anyhow::bail!("worker received unexpected frame tag {tag:#x}"),
    }
}

/// Capped exponential backoff with deterministic jitter, driving the
/// redial loop of [`run_worker_reconnecting`]. `next_delay` is pure
/// computation over internal state — no clock, no sleeping — so tests
/// assert the whole schedule against a virtual clock without waiting
/// out a single delay.
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    rng: u64,
}

impl Backoff {
    /// A schedule starting at `base_ms`, doubling per attempt, capped
    /// at `cap_ms`. `seed` drives the jitter stream deterministically.
    pub fn new(base_ms: u64, cap_ms: u64, seed: u64) -> Backoff {
        Backoff { base_ms: base_ms.max(1), cap_ms: cap_ms.max(1), attempt: 0, rng: seed | 1 }
    }

    /// The next delay: `min(cap, base << attempt)` jittered uniformly
    /// into `[raw/2, raw]` (decorrelating a fleet of workers redialing
    /// a restarted aggregator without ever under-waiting by more than
    /// half a step).
    pub fn next_delay(&mut self) -> Duration {
        let shift = self.attempt.min(16);
        let raw = self.base_ms.checked_shl(shift).unwrap_or(u64::MAX).min(self.cap_ms).max(1);
        self.attempt = self.attempt.saturating_add(1);
        // LCG (Knuth MMIX constants); take high bits for the jitter.
        self.rng = self
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let frac = (self.rng >> 33) as f64 / (1u64 << 31) as f64; // [0, 1)
        let ms = raw / 2 + ((raw as f64 / 2.0) * frac) as u64;
        Duration::from_millis(ms.clamp(raw / 2, raw))
    }

    /// Reset after a successful connection: the next outage starts the
    /// schedule from `base_ms` again.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// The identity a worker carries across link incarnations: its worker
/// id, the run's incarnation token (both learned from the first Init),
/// and the last aggregator step it answered. A redial presents these
/// in its Join so the aggregator recognizes a returning replica.
#[derive(Clone, Copy, Debug)]
pub struct WorkerSession {
    worker: u32,
    incarnation: u64,
    last_step: u64,
}

impl WorkerSession {
    /// A fresh session: no identity yet (`worker = u32::MAX`).
    pub fn fresh() -> WorkerSession {
        WorkerSession { worker: u32::MAX, incarnation: 0, last_step: 0 }
    }

    fn join_msg(&self) -> JoinMsg {
        JoinMsg {
            version: PROTO_VERSION,
            incarnation: self.incarnation,
            worker: self.worker,
            last_step: self.last_step,
        }
    }
}

/// Serve one aggregator over `link` until it sends a shutdown frame,
/// with no scripted faults. See the module docs; returns an error
/// (never hangs) when the link dies or a frame is malformed.
pub fn run_worker(link: Box<dyn Transport>, pool: Arc<BufPool>) -> Result<()> {
    run_worker_with_faults(link, pool, FaultPlan::default())
}

/// [`run_worker`] with a scripted [`FaultPlan`] acted out against the
/// gradient-send counter (see [`super::fault`] for the grammar).
pub fn run_worker_with_faults(
    link: Box<dyn Transport>,
    pool: Arc<BufPool>,
    plan: FaultPlan,
) -> Result<()> {
    let mut session = WorkerSession::fresh();
    run_worker_session(link, pool, plan, &mut session)
}

/// Keep a TCP worker alive across link failures: dial `addr`, serve,
/// and on a link error (drop, reset, aggregator restart) redial with
/// [`Backoff`] for up to `redial_window` — re-presenting the learned
/// [`WorkerSession`] identity in each Join. A clean exit (shutdown,
/// eviction, scripted death) never redials. Network fault verbs in
/// `plan` wrap every dialed link in one shared
/// [`FlakyTransport`] script, so the scripted fault sequence spans
/// redials instead of restarting on each.
pub fn run_worker_reconnecting(
    addr: &str,
    pool: Arc<BufPool>,
    plan: FaultPlan,
    redial_window: Duration,
) -> Result<()> {
    let flaky = FlakyState::from_plan(&plan);
    let mut session = WorkerSession::fresh();
    // Seed from the dial address so a fleet's jitter streams diverge.
    let mut backoff = Backoff::new(50, 2_000, super::checkpoint::fnv64(addr.as_bytes()));
    let start = Instant::now();
    loop {
        let link: Box<dyn Transport> = match TcpTransport::connect(
            addr,
            Duration::from_secs(10),
            Arc::clone(&pool),
        ) {
            Ok(l) => match &flaky {
                Some(state) => Box::new(FlakyTransport::wrap(Box::new(l), Arc::clone(state))),
                None => Box::new(l),
            },
            Err(e) => {
                if start.elapsed() >= redial_window {
                    return Err(e.context("dialing the aggregator beyond the redial window"));
                }
                thread::sleep(backoff.next_delay());
                continue;
            }
        };
        match run_worker_session(link, Arc::clone(&pool), plan.clone(), &mut session) {
            Ok(()) => return Ok(()),
            Err(e) => {
                if start.elapsed() >= redial_window {
                    return Err(e.context("worker link failed beyond the redial window"));
                }
                let delay = backoff.next_delay();
                eprintln!(
                    "[dist-worker] link to {addr} failed ({e:#}); redialing in {}ms",
                    delay.as_millis()
                );
                thread::sleep(delay);
            }
        }
    }
}

/// One link incarnation: Join (with the session's identity), Init,
/// handshake barrier, then the serve loop. Updates `session` from the
/// Init so a later redial presents the learned identity.
fn run_worker_session(
    mut link: Box<dyn Transport>,
    pool: Arc<BufPool>,
    plan: FaultPlan,
    session: &mut WorkerSession,
) -> Result<()> {
    // Announce ourselves first: the Join frame carries the protocol
    // version so a mismatched worker is rejected descriptively at the
    // aggregator instead of misparsing frames mid-run.
    let mut join = pool.checkout();
    encode_join(&session.join_msg(), &mut join);
    link.send_blob(join).context("sending Join")?;
    let frame = link.recv_blob().context("waiting for Init")?;
    let init = decode_init(&frame)?;
    pool.give_back(frame);
    // Learn (or confirm) our identity: a redial after this point
    // presents these in its Join, which is how the aggregator tells a
    // returning replica from a fresh dialer.
    session.worker = init.worker as u32;
    session.incarnation = init.incarnation;
    // Clock handshake: the Init carries the aggregator's trace clock
    // at encode time; sampling ours at decode time gives the offset
    // that maps local timestamps onto the aggregator timeline (transit
    // is treated as zero — exact in-process, sub-ms on loopback).
    let trace_offset_us = if init.trace {
        trace::set_enabled(true);
        init.clock_anchor_us as i64 - trace::now_us() as i64
    } else {
        0
    };
    trace::set_lane(init.worker as u32 + 1);
    let be = NativeBackend::new(&init.spec, init.lora_rank, init.spec.micro_batch, init.seed);
    let codec = Arc::new(
        GradCodec::new(&be).with_precision(init.precision).with_compression(init.compress),
    );
    // Replica built: release the aggregator's handshake.
    link.barrier().context("worker handshake barrier")?;
    let (tx, rx) = link.split();
    serve(be, codec, &init, trace_offset_us, rx, tx, pool, plan)
}

/// The post-handshake serve loop (compute thread).
fn serve(
    mut be: NativeBackend,
    codec: Arc<GradCodec>,
    init: &InitMsg,
    trace_offset_us: i64,
    mut rx: Box<dyn BlobRx>,
    tx: Box<dyn BlobTx>,
    pool: Arc<BufPool>,
    plan: FaultPlan,
) -> Result<()> {
    let tx: SharedTx = Arc::new(Mutex::new(tx));
    let mut faults = FaultState::new(plan);
    let mut ring = RingState::new();
    let mut held: Option<HeldStep> = None;
    // Last Up frame, kept for NACK resends (shared with the overlap
    // sender thread, which is where Up frames are encoded in that mode).
    let retained: Retained = Arc::new(Mutex::new(Vec::new()));
    // Error-feedback residuals exist once per worker for lossy wires;
    // with the overlap sender thread they live (and mutate) there.
    let mut ef: Option<Vec<Tensor>> =
        if codec.compression().is_lossy() { Some(be.zeros_like_params()) } else { None };
    let use_sender = init.overlap && !init.ring;

    // Heartbeat thread: pings every `heartbeat_ms` until stopped (or
    // the uplink dies — then the aggregator already knows more than a
    // missing ping could tell it).
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_handle = if init.heartbeat_ms > 0 {
        let tx = Arc::clone(&tx);
        let pool = Arc::clone(&pool);
        let stop = Arc::clone(&hb_stop);
        let interval = Duration::from_millis(init.heartbeat_ms);
        let lane = init.worker as u32 + 1;
        Some(
            thread::Builder::new()
                .name(format!("d2ft-dist-{}-hb", init.worker))
                .spawn(move || {
                    trace::set_lane(lane);
                    let mut seq = 0u64;
                    'beat: loop {
                        // Sleep in slices so shutdown joins promptly.
                        let mut slept = Duration::ZERO;
                        while slept < interval {
                            let slice = (interval - slept).min(Duration::from_millis(50));
                            thread::sleep(slice);
                            slept += slice;
                            if stop.load(Ordering::Relaxed) {
                                break 'beat;
                            }
                        }
                        let mut ping = pool.checkout();
                        encode_ping(seq, &mut ping);
                        seq += 1;
                        trace::instant("hb", "ping");
                        if send_shared(&tx, ping).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawning dist heartbeat thread"),
        )
    } else {
        None
    };

    // With overlap a dedicated sender thread drains the one-slot queue;
    // it shares the uplink with the heartbeat via the mutex. Ring mode
    // never uploads gradients, so there is nothing to pipeline.
    let (sender_tx, sender_handle) = if use_sender {
        let (stx, srx) = mpsc::sync_channel::<Computed>(1);
        let codec = Arc::clone(&codec);
        let pool = Arc::clone(&pool);
        let tx = Arc::clone(&tx);
        let wire_ms = init.sim_wire_ms_per_mib;
        let mut ef = ef.take();
        let lane = init.worker as u32 + 1;
        let retained = Arc::clone(&retained);
        let handle = thread::Builder::new()
            .name(format!("d2ft-dist-{}-tx", init.worker))
            .spawn(move || {
                trace::set_lane(lane);
                while let Ok(c) = srx.recv() {
                    if encode_and_send(&codec, &pool, wire_ms, &tx, &mut ef, &retained, c)
                        .is_err()
                    {
                        // Aggregator gone: stop draining; the compute
                        // thread will notice on its own half.
                        break;
                    }
                }
            })
            .expect("spawning dist sender thread");
        (Some(stx), Some(handle))
    } else {
        (None, None)
    };

    let mut result = Ok(());
    let mut dying = false;
    loop {
        let frame = match rx.recv_blob() {
            Ok(f) => f,
            Err(e) => {
                result = Err(e.context("receiving job frame"));
                break;
            }
        };
        let flow = handle_frame(
            &frame,
            &mut be,
            &codec,
            init,
            trace_offset_us,
            &pool,
            &sender_tx,
            &tx,
            &mut faults,
            &mut ring,
            &mut held,
            &mut ef,
            &retained,
        );
        pool.give_back(frame);
        match flow {
            Ok(Flow::Continue) => continue,
            Ok(Flow::Shutdown) => break,
            Ok(Flow::Die) => {
                dying = true;
                break;
            }
            Ok(Flow::Ring(exec)) => {
                // Cache the orders first: the recovery cast path needs
                // them even if this exchange aborts.
                ring.last_exec = Some(exec.clone());
                match ring_exec(
                    &mut be,
                    &codec,
                    &exec,
                    &held,
                    &mut ef,
                    &mut ring,
                    rx.as_mut(),
                    &tx,
                    &pool,
                ) {
                    Ok(RingOutcome::Done) | Ok(RingOutcome::Aborted) => continue,
                    Ok(RingOutcome::Shutdown) => break,
                    Ok(RingOutcome::Die) => {
                        dying = true;
                        break;
                    }
                    Err(e) => {
                        result = Err(e.context("running ring exchange"));
                        break;
                    }
                }
            }
            Err(e) => {
                result = Err(e);
                break;
            }
        }
    }

    // Drain the pipeline: by the time a Shutdown frame arrives the
    // aggregator has received every gradient of every batch; on a
    // scripted kill the queued (pre-kill) sends still flush, keeping
    // the delivered-gradient count exact.
    drop(sender_tx);
    if let Some(h) = sender_handle {
        h.join().expect("joining dist sender thread");
    }
    hb_stop.store(true, Ordering::Relaxed);
    if let Some(h) = hb_handle {
        h.join().expect("joining dist heartbeat thread");
    }
    // Fold any live ring links into the byte counters before reporting.
    ring.drop_links();
    if dying {
        // Abrupt exit: no Bye — dropping the uplink is the message.
        return Ok(());
    }
    if result.is_ok() {
        // Final flush: whatever recorded since the last epoch beacon
        // still reaches the merged artifact.
        result = flush_trace(init, trace_offset_us, &tx, &pool);
    }
    if result.is_ok() {
        let mut bye = pool.checkout();
        encode_bye(
            &ByeMsg {
                fresh: pool.fresh_allocs(),
                reused: pool.reuses(),
                ring_sent: ring.sent,
                ring_recv: ring.recv,
            },
            &mut bye,
        );
        result = send_shared(&tx, bye).context("sending Bye");
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_caps_and_jitters_within_bounds() {
        let mut b = Backoff::new(50, 2_000, 7);
        let mut prev_raw = 0u64;
        for attempt in 0..12 {
            let raw = 50u64.checked_shl(attempt.min(16)).unwrap_or(u64::MAX).min(2_000);
            let d = b.next_delay().as_millis() as u64;
            assert!(
                d >= raw / 2 && d <= raw,
                "attempt {attempt}: delay {d}ms outside [{}, {raw}]",
                raw / 2
            );
            assert!(raw >= prev_raw, "raw schedule must be monotonic");
            prev_raw = raw;
        }
        // Deep into the schedule every delay is pinned to the cap band.
        for _ in 0..20 {
            let d = b.next_delay().as_millis() as u64;
            assert!((1_000..=2_000).contains(&d), "capped delay {d}ms outside [1000, 2000]");
        }
    }

    #[test]
    fn backoff_is_deterministic_per_seed_and_resets() {
        let schedule = |seed: u64| -> Vec<u128> {
            let mut b = Backoff::new(10, 500, seed);
            (0..8).map(|_| b.next_delay().as_millis()).collect()
        };
        assert_eq!(schedule(42), schedule(42), "same seed, same jitter stream");
        assert_ne!(schedule(1), schedule(2), "different seeds must decorrelate");
        let mut b = Backoff::new(10, 500, 9);
        for _ in 0..6 {
            b.next_delay();
        }
        b.reset();
        let d = b.next_delay().as_millis() as u64;
        assert!((5..=10).contains(&d), "reset must restart from the base: got {d}ms");
    }

    #[test]
    fn fresh_sessions_join_with_no_identity() {
        let s = WorkerSession::fresh();
        let j = s.join_msg();
        assert_eq!(j.version, PROTO_VERSION);
        assert_eq!(j.incarnation, 0);
        assert_eq!(j.worker, u32::MAX);
        assert_eq!(j.last_step, 0);
    }
}
