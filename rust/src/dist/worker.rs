//! The dist worker: one replica's side of the protocol, generic over
//! the [`Transport`] it speaks.
//!
//! [`run_worker`] is the **only** worker implementation in the runtime
//! — an in-process thread over a [`super::transport::ChannelTransport`],
//! a thread over a loopback socket, a `repro dist-worker` subprocess,
//! and a worker on another machine all execute this exact function.
//! That is the heart of the cross-transport bitwise guarantee: there is
//! no second code path whose numerics could drift.
//!
//! A worker is model-agnostic until its [`InitMsg`] arrives: it builds
//! a [`NativeBackend`] replica from the message's `(spec, lora_rank,
//! seed)` (bitwise identical to the aggregator's and to every sibling),
//! confirms readiness through the transport barrier, then serves jobs
//! until a shutdown frame. With `overlap` the loop splits into a
//! compute thread and a dedicated sender thread over a bounded one-slot
//! channel — the PR 4 double-buffered pipeline, unchanged, just ending
//! in `send_blob` instead of a hardcoded mpsc.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

use super::grads::{BufPool, GradCodec};
use super::proto::{
    decode_apply, decode_compute, decode_deltas, decode_init, encode_bye, encode_up_header,
    peek_tag, InitMsg, UpHdr, TAG_APPLY, TAG_COMPUTE, TAG_DELTAS, TAG_RESET, TAG_SHUTDOWN,
    UP_GRAD_OFF,
};
use super::transport::{BlobRx, BlobTx, Transport};

/// Compute-thread → sender-thread handoff (overlap mode): one computed
/// gradient awaiting encode + upload. The tensors are owned — the
/// sender never reads the replica.
struct Computed {
    micro: usize,
    loss: f32,
    n_correct: f32,
    masks: MaskPair,
    grads: Vec<Tensor>,
    ms: f64,
}

/// Sleep out the simulated NIC time for one `bytes`-sized message. A
/// sleep — not a spin — because a real NIC moves bytes by DMA without
/// burning a core: the sender thread must *wait* without stealing CPU
/// from the compute threads, or the measured overlap win would vanish
/// on core-saturated hosts for the wrong reason.
fn sim_wire_delay(bytes: usize, ms_per_mib: f64) {
    if ms_per_mib > 0.0 {
        let ms = bytes as f64 / (1024.0 * 1024.0) * ms_per_mib;
        thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
    }
}

/// Encode one computed gradient into a recycled buffer (Up header +
/// codec payload as the frame tail), pay the optional simulated NIC,
/// and upload it.
fn encode_and_send(
    codec: &GradCodec,
    pool: &BufPool,
    wire_ms_per_mib: f64,
    tx: &mut dyn BlobTx,
    c: Computed,
) -> Result<()> {
    let mut frame = pool.checkout();
    encode_up_header(
        &UpHdr { micro: c.micro, loss: c.loss, n_correct: c.n_correct, ms: c.ms },
        &mut frame,
    );
    codec.encode_append(c.micro, &c.masks, &c.grads, &mut frame);
    sim_wire_delay(frame.len() - UP_GRAD_OFF, wire_ms_per_mib);
    tx.send_blob(frame)
}

/// Dispatch one decoded frame. Returns `Ok(false)` on a shutdown
/// frame, `Ok(true)` otherwise.
fn handle_frame(
    frame: &[u8],
    be: &mut NativeBackend,
    codec: &GradCodec,
    init: &InitMsg,
    pool: &BufPool,
    sender_tx: &Option<mpsc::SyncSender<Computed>>,
    inline_tx: &mut Option<Box<dyn BlobTx>>,
) -> Result<bool> {
    match peek_tag(frame)? {
        TAG_COMPUTE => {
            for job in decode_compute(frame)? {
                let t0 = Instant::now();
                let (out, grads) = be
                    .grad_step(&job.x, &job.y, &job.masks)
                    .context("native grad step on worker")?;
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let c = Computed {
                    micro: job.micro,
                    loss: out.loss,
                    n_correct: out.n_correct,
                    masks: job.masks,
                    grads,
                    ms,
                };
                match (sender_tx, &mut *inline_tx) {
                    (Some(stx), _) => stx
                        .send(c)
                        .map_err(|_| anyhow::anyhow!("sender thread exited early"))?,
                    (None, Some(tx)) => {
                        encode_and_send(codec, pool, init.sim_wire_ms_per_mib, tx.as_mut(), c)?
                    }
                    (None, None) => unreachable!("no uplink half"),
                }
            }
            Ok(true)
        }
        TAG_APPLY => {
            let (lr, union, off) = decode_apply(frame)?;
            let mut acc = be.zeros_like_params();
            codec
                .decode_add(&frame[off..], &union, &mut acc)
                .context("decoding reduced gradient broadcast")?;
            be.apply_grads(&acc, lr).context("applying reduced gradient")?;
            Ok(true)
        }
        TAG_DELTAS => {
            let off = decode_deltas(frame)?;
            let deltas =
                codec.decode_dense(&frame[off..]).context("decoding delta broadcast")?;
            be.apply_deltas(&deltas).context("installing deltas")?;
            Ok(true)
        }
        TAG_RESET => {
            be.reset_momentum().context("resetting momentum")?;
            Ok(true)
        }
        TAG_SHUTDOWN => Ok(false),
        tag => anyhow::bail!("worker received unexpected frame tag {tag:#x}"),
    }
}

/// Serve one aggregator over `link` until it sends a shutdown frame.
/// See the module docs; returns an error (never hangs) when the link
/// dies or a frame is malformed.
pub fn run_worker(mut link: Box<dyn Transport>, pool: Arc<BufPool>) -> Result<()> {
    let frame = link.recv_blob().context("waiting for Init")?;
    let init = decode_init(&frame)?;
    pool.give_back(frame);
    let be = NativeBackend::new(&init.spec, init.lora_rank, init.spec.micro_batch, init.seed);
    let codec = Arc::new(GradCodec::new(&be).with_precision(init.precision));
    // Replica built: release the aggregator's handshake.
    link.barrier().context("worker handshake barrier")?;
    let (tx, rx) = link.split();
    serve(be, codec, &init, rx, tx, pool)
}

/// The post-handshake serve loop (compute thread).
fn serve(
    mut be: NativeBackend,
    codec: Arc<GradCodec>,
    init: &InitMsg,
    mut rx: Box<dyn BlobRx>,
    tx: Box<dyn BlobTx>,
    pool: Arc<BufPool>,
) -> Result<()> {
    // With overlap the sender thread owns the uplink; it hands the tx
    // half back through its join handle so the compute thread can send
    // the final Bye. Without overlap the compute thread keeps it.
    let (sender_tx, sender_handle, mut inline_tx) = if init.overlap {
        let (stx, srx) = mpsc::sync_channel::<Computed>(1);
        let codec = Arc::clone(&codec);
        let pool = Arc::clone(&pool);
        let wire_ms = init.sim_wire_ms_per_mib;
        let mut tx = tx;
        let handle = thread::Builder::new()
            .name(format!("d2ft-dist-{}-tx", init.worker))
            .spawn(move || {
                while let Ok(c) = srx.recv() {
                    if encode_and_send(&codec, &pool, wire_ms, tx.as_mut(), c).is_err() {
                        // Aggregator gone: stop draining; the compute
                        // thread will notice on its own half.
                        break;
                    }
                }
                tx
            })
            .expect("spawning dist sender thread");
        (Some(stx), Some(handle), None)
    } else {
        (None, None, Some(tx))
    };

    let mut result = Ok(());
    loop {
        let frame = match rx.recv_blob() {
            Ok(f) => f,
            Err(e) => {
                result = Err(e.context("receiving job frame"));
                break;
            }
        };
        let step = handle_frame(&frame, &mut be, &codec, init, &pool, &sender_tx, &mut inline_tx);
        pool.give_back(frame);
        match step {
            Ok(true) => continue,
            Ok(false) => break,
            Err(e) => {
                result = Err(e);
                break;
            }
        }
    }

    // Rejoin the uplink half. By the time a Shutdown frame arrives the
    // aggregator has received every gradient of every batch, so the
    // sender queue is already drained.
    drop(sender_tx);
    let mut tx = match (inline_tx, sender_handle) {
        (Some(tx), None) => tx,
        (None, Some(h)) => h.join().expect("joining dist sender thread"),
        _ => unreachable!("exactly one uplink owner"),
    };
    if result.is_ok() {
        let mut bye = pool.checkout();
        encode_bye(pool.fresh_allocs(), pool.reuses(), &mut bye);
        result = tx.send_blob(bye).context("sending Bye");
    }
    result
}
