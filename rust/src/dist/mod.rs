//! Real data-parallel training runtime with masked-gradient exchange.
//!
//! Everything the simulated cluster ([`crate::cluster`]) *models* —
//! device time, straggler effects, communication volume — this module
//! *measures*: worker threads own live [`crate::backend::native::NativeBackend`]
//! replicas, execute their scheduled micro-batch gradient computations
//! for real, and exchange gradients as serialized bytes whose count is
//! the paper's communication claim made observable.
//!
//! Three pieces:
//!
//! * [`grads`] — the masked-gradient wire format. A [`grads::GradCodec`]
//!   derives, once, which parameter slices each (block, head) subnet
//!   owns; a message then ships **only** the slices the micro-batch's
//!   [`crate::schedule::MaskPair`] leaves trainable. `p_s` heads ship
//!   nothing (their gradients are identically zero), `p_o` heads ship
//!   nothing (frozen), LoRA mode ships only adapter + classifier slices.
//!   Because the schedule is known cluster-wide, messages need no index
//!   structure — both ends derive the layout, so the format is as dense
//!   as the mask allows and byte counts are exact.
//! * [`allreduce`] — deterministic aggregation: messages reduce in fixed
//!   ascending micro-batch order, so K workers produce the same bits as
//!   one (the PR 1 determinism contract extended from modeled metrics to
//!   live numerics). A parameter-server topology
//!   ([`allreduce::ExchangeMode::ParamServer`]) is the heterogeneous-
//!   cluster variant: the server owns the optimizer state and ships
//!   dense update deltas downlink — bitwise the same trajectory, more
//!   bytes, which is exactly the contrast that motivates the masked
//!   all-reduce.
//! * [`trainer`] — [`trainer::DistTrainer`]: schedule → worker execution
//!   → ordered reduce → one fused SGD-momentum update per batch. Each
//!   worker is a **pipeline**: a dedicated sender thread encodes and
//!   uploads task *i* (recycled buffers, zero steady-state allocations)
//!   while the compute thread already runs task *i+1* — the
//!   comm/compute overlap the engine models, live and measured. Its
//!   loss trajectory is bitwise identical to the serial
//!   [`crate::coordinator::Trainer`] run under
//!   [`crate::coordinator::UpdateMode::BatchAccum`] for any worker count
//!   (`tests/dist.rs` pins K ∈ {1, 2, 4}, overlap on and off, kernel
//!   threads > 1). Measured per-worker task times feed a
//!   straggler-aware micro-batch balancer, the
//!   [`crate::cluster::WorkloadTracker`], and an epoch-boundary
//!   calibration of the modeled `ExecTimeModel` — placement and
//!   modeling react to real stragglers, and (because replicas are
//!   bitwise identical) neither can change the numerics. An optional
//!   [`grads::WirePrecision::F16`] wire halves the measured bytes
//!   (lossy; replicas stay mutually bit-identical via requantized
//!   broadcast). Beyond the star topologies, the trainer speaks two
//!   collective exchanges ([`allreduce::ExchangeMode::Ring`] and
//!   [`allreduce::ExchangeMode::Hierarchical`]): workers chain-reduce
//!   their gradient blocks over negotiated worker↔worker links, so
//!   per-node traffic stops scaling with K — and the uncompressed
//!   chain fold adds the same values in the same ascending order as
//!   the star reduce, keeping it bitwise equal to serial. A
//!   [`grads::WireCompression`] layer (int8/int4 quantization with
//!   error feedback, top-k sparsification) shrinks every gradient
//!   payload further; lossy modes keep all replicas mutually
//!   bit-identical because everyone (aggregator included) applies the
//!   exact bytes that crossed the wire.
//! * [`transport`] / [`proto`] / [`worker`] — the multi-process seam.
//!   Every aggregator ↔ worker exchange is a framed message over a
//!   [`transport::Transport`] link: [`transport::ChannelTransport`]
//!   keeps workers as threads (in-process mpsc), and
//!   [`transport::TcpTransport`] runs the *same* [`worker::run_worker`]
//!   loop in separate threads, forked `repro dist-worker` subprocesses,
//!   or processes on other hosts — length-prefixed frames over
//!   `std::net`, gradient payloads in the unchanged [`grads::GradCodec`]
//!   format. Identical bytes + the fixed reduction order make training
//!   **bitwise identical across transports** (`tests/dist_tcp.rs`).
//! * [`fault`] / [`checkpoint`] — the elastic control plane. Workers
//!   `Join` with a protocol version, heartbeat `Ping`s between jobs,
//!   and are evicted when a liveness window (derived from the
//!   heartbeat interval, not a fixed receive timeout) lapses; a lost
//!   worker's unfinished micro-batches are re-executed on survivors in
//!   the same fixed reduction order, so recovery — like everything
//!   else here — cannot change the numerics. Scripted
//!   [`fault::FaultPlan`]s (`kill-after-micro=N`, `stall-ms=M@N`,
//!   `drop-uplink=N`, `rejoin-at-epoch=E`, plus the network-layer
//!   verbs `reset-after-frame=N`, `corrupt-frame=N`, `delay-ms=M@N`,
//!   `partition-ms=M@E`) inject failures deterministically in-process
//!   or over TCP; epoch-boundary [`checkpoint::Checkpoint`]s make a
//!   killed run resumable bitwise (`tests/dist_fault.rs`).
//!   The coordinator itself is a survivable component, not a single
//!   point of failure: checkpoints are written atomically (tmp +
//!   rename + fsync) and rotated, a step-granular
//!   [`checkpoint::Progress`] record tracks the last completed batch
//!   *between* epoch checkpoints, and `--resume <dir>` restarts a
//!   killed aggregator mid-epoch. Workers that outlive it keep
//!   redialing with capped exponential backoff
//!   ([`worker::run_worker_reconnecting`]) and re-`Join` carrying an
//!   incarnation token, so the restarted run converges bitwise to the
//!   uninterrupted one. Every TCP frame carries a CRC32C trailer;
//!   a corrupt arrival is NACKed for a resend, never an eviction.
//!
//! The runtime is instrumented end to end with [`crate::obs`]:
//! `DistConfig::trace_out` arms the cross-process step tracer (worker
//! buffers ship home in `TAG_TRACE` frames and merge into one
//! Perfetto-loadable timeline), and `DistConfig::metrics` publishes the
//! wire/socket/membership counters and the step-latency histogram into
//! a live [`crate::obs::Registry`]. Both are observation-only: every
//! bitwise determinism contract above holds with them on or off
//! (`tests/obs.rs`).

pub mod allreduce;
pub mod checkpoint;
pub mod fault;
pub mod grads;
pub mod proto;
pub mod trainer;
pub mod transport;
pub mod worker;

pub use allreduce::{ExchangeMode, OrderedReducer};
pub use checkpoint::{ckpt_path, latest_valid, rotate, Checkpoint, Progress};
pub use fault::{parse_worker_plans, FaultAction, FaultPlan};
pub use grads::{BufPool, GradCodec, WireCompression, WirePrecision, WireStats};
pub use trainer::{DistConfig, DistReport, DistTrainer, MembershipEvent};
pub use transport::{
    is_corrupt_frame_err, liveness_window, BlobRx, BlobTx, FlakyState, FlakyTransport, SpawnMode,
    TcpTransport, Transport, TransportKind, TransportStats,
};
pub use worker::{run_worker, run_worker_reconnecting, run_worker_with_faults, Backoff};
