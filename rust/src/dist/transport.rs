//! The transport seam: how gradient and control frames move between the
//! aggregator and its workers.
//!
//! The dist runtime's wire *format* ([`super::grads::GradCodec`], the
//! 28-byte-header masked-gradient messages) has been transport-agnostic
//! since PR 3 — but until this layer existed, the only way bytes moved
//! was an in-process mpsc channel hardcoded into the trainer. The
//! [`Transport`] trait makes the seam explicit: an opaque, ordered,
//! reliable duplex stream of *blobs* (byte frames). Two implementations:
//!
//! * [`ChannelTransport`] — the in-process path, one `mpsc` pair per
//!   direction. Zero-copy: `send_blob` moves the `Vec` straight to the
//!   peer.
//! * [`TcpTransport`] — length-prefixed frames over `std::net`
//!   loopback or a real network. The aggregator listens; K worker
//!   *processes* (or threads, or machines) connect.
//!
//! Because every implementation delivers the same blobs in the same
//! per-link order, and the [`super::allreduce::OrderedReducer`] fixes
//! the reduction order independently of arrival order, the training
//! numerics are **bitwise identical across transports** — pinned by
//! `tests/dist_tcp.rs` against the serial trainer for K ∈ {2, 4},
//! overlap on/off, f32/f16 wires.
//!
//! ## Buffer ownership
//!
//! `send_blob` consumes its buffer: the channel path delivers the `Vec`
//! itself to the peer, the TCP path writes the frame and returns the
//! buffer to the transport's [`BufPool`]. Either way the caller checks
//! out a fresh pooled buffer per message and the steady state allocates
//! nothing — the PR 4 zero-allocation encode property, now preserved
//! across a real socket.
//!
//! ## Framing (TCP)
//!
//! `[len: u32 LE][payload: len bytes][crc: u32 LE]`. A zero-length
//! frame is the barrier token (see [`Transport::barrier`]); the control
//! protocol ([`super::proto`]) never produces one. A length prefix
//! above [`MAX_FRAME`] is rejected before any allocation, so a corrupt
//! or malicious prefix surfaces as a descriptive error instead of an
//! OOM, and a peer that closes mid-frame surfaces as a truncation error
//! instead of a hang.
//!
//! ## Corruption detection (PROTO_VERSION 5)
//!
//! Every frame carries a CRC32C (Castagnoli) trailer over its payload,
//! verified and stripped on receive — on both transports, so the
//! corruption-handling paths are exercised identically in-process and
//! over a socket. A trailer mismatch is a *retryable* error, not a
//! poisoned stream: the length prefix already delimited the frame, so
//! the next frame reads cleanly and the receiver can ask the sender to
//! repeat the damaged one (the aggregator's NACK/resend path). Callers
//! distinguish it with [`is_corrupt_frame_err`]. [`BlobTx::
//! send_blob_corrupt`] deliberately seals a frame with a damaged
//! trailer — the hook [`FlakyTransport`] uses to inject wire corruption
//! deterministically.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::fault::{FaultAction, FaultPlan};
use super::grads::BufPool;
use super::proto;

/// Hard cap on one frame's payload size (256 MiB). Far above any real
/// message (a dense small-model gradient is a few MiB); its only job is
/// turning a corrupt length prefix into an error instead of a giant
/// allocation.
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

// ---------------------------------------------------------------------------
// CRC32C frame trailers
// ---------------------------------------------------------------------------

/// Reflected CRC32C (Castagnoli) lookup table, built at compile time —
/// no dependency, no runtime init.
const CRC32C_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0x82F6_3B78 } else { crc >> 1 };
            j += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC32C (Castagnoli, reflected, init/xorout `!0`) of `bytes` — the
/// checksum in every frame trailer. Software table implementation; the
/// per-frame cost is noise next to the gradient encode it protects.
pub fn crc32c(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32C_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Marker text present in every CRC-trailer failure — the contract
/// [`is_corrupt_frame_err`] keys on.
const CRC_MISMATCH: &str = "frame CRC32C mismatch";

/// True when `e` is a frame-corruption error (CRC trailer mismatch):
/// the frame boundary was intact, so the link is still framed and the
/// right response is a NACK/resend, not an eviction.
pub fn is_corrupt_frame_err(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains(CRC_MISMATCH)
}

/// Append the CRC32C trailer over the payload. `damage` flips the
/// stored checksum — the deterministic corruption injection used by
/// [`BlobTx::send_blob_corrupt`].
fn seal_crc(blob: &mut Vec<u8>, damage: bool) {
    let mut crc = crc32c(blob);
    if damage {
        crc = !crc;
    }
    blob.extend_from_slice(&crc.to_le_bytes());
}

/// Verify and strip the CRC32C trailer in place.
fn unseal_crc(blob: &mut Vec<u8>) -> Result<()> {
    anyhow::ensure!(
        blob.len() >= 4,
        "{CRC_MISMATCH}: {}-byte frame is too short to carry a trailer",
        blob.len()
    );
    let body = blob.len() - 4;
    let stored = u32::from_le_bytes(blob[body..].try_into().unwrap());
    let actual = crc32c(&blob[..body]);
    anyhow::ensure!(
        stored == actual,
        "{CRC_MISMATCH}: stored {stored:#010x}, computed {actual:#010x} \
         over {body} payload bytes"
    );
    blob.truncate(body);
    Ok(())
}

/// The send half of a transport link.
pub trait BlobTx: Send {
    /// Send one blob to the peer. Consumes the buffer: delivered as-is
    /// (channel) or written to the socket and recycled into the
    /// transport's pool (TCP). Fails when the peer is gone.
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()>;

    /// Send one blob whose CRC trailer is deliberately damaged, so the
    /// receiver's corruption detector fires. Fault-injection seam only
    /// (see [`FlakyTransport`]); the default falls back to a clean
    /// send, so wrappers that cannot reach the framing layer degrade to
    /// no-ops instead of breaking the run.
    fn send_blob_corrupt(&mut self, blob: Vec<u8>) -> Result<()> {
        self.send_blob(blob)
    }
}

/// The receive half of a transport link.
pub trait BlobRx: Send {
    /// Block until the peer's next blob arrives and return it. Fails —
    /// never hangs forever on a closed link — when the peer
    /// disconnects, with a description of what broke.
    fn recv_blob(&mut self) -> Result<Vec<u8>>;

    /// Wait up to `timeout` for the next blob. `Ok(None)` means the
    /// link stayed completely quiet — the liveness signal the control
    /// plane's failure detector is built on. A peer that *starts* a
    /// frame and then goes silent for a full window is an error (it is
    /// holding the link mid-message, not merely idle), as is a
    /// disconnect. The default implementation ignores the timeout and
    /// blocks; real transports override it.
    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        let _ = timeout;
        self.recv_blob().map(Some)
    }

    /// Human-readable peer label for error messages — the socket's
    /// remote address over TCP, `chan` in-process. Exists so a failed
    /// receive can name *which* link broke without a trace dive.
    fn peer(&self) -> String {
        "peer".to_string()
    }
}

/// The liveness deadline for a worker link, derived from the heartbeat
/// interval instead of a fixed load-independent constant: a link is
/// declared dead only after `misses` full heartbeat intervals pass with
/// no traffic at all. A slow-but-alive worker keeps pinging while it
/// computes (or stalls), so it is *reassigned*, never evicted.
pub fn liveness_window(heartbeat_ms: u64, misses: u32) -> Duration {
    Duration::from_millis(heartbeat_ms.max(1).saturating_mul(misses.max(1) as u64))
}

/// One reliable, ordered, duplex blob link between two cluster nodes.
///
/// The contract the dist runtime builds on: blobs arrive exactly once,
/// uncorrupted, in send order (per link — nothing is implied across
/// links), and a dead peer turns into an error on both halves. That is
/// all the determinism argument needs: *which* bytes flow and how they
/// reduce is fixed above this seam.
pub trait Transport: BlobTx + BlobRx {
    /// Synchronization point: both endpoints must call `barrier` at the
    /// same protocol position; each sends an empty frame and waits for
    /// the peer's. Used at handshake time (replica built, ready for
    /// jobs) where the link is quiescent.
    fn barrier(&mut self) -> Result<()> {
        self.send_blob(Vec::new())?;
        let token = self.recv_blob()?;
        anyhow::ensure!(
            token.is_empty(),
            "barrier crossed a {}-byte data frame (protocol desync)",
            token.len()
        );
        Ok(())
    }

    /// Split into independently-owned halves so uplink and downlink can
    /// live on different threads (the aggregator's reader thread, the
    /// worker's pipelined sender thread).
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>);

    /// Display label (`channel` / `tcp`).
    fn label(&self) -> &'static str;

    /// Snapshot of the bytes this link actually moved.
    fn stats(&self) -> TransportStats;
}

/// Display names of the per-frame-tag traffic classes tracked by
/// [`StatsCell`] / [`TransportStats`], indexed by the value
/// [`frame_class`] returns. One entry per control-protocol frame kind
/// (all ten `TAG_RING_*` negotiation/exchange tags fold into a single
/// `ring` class), `trace` for the observability side-channel, `job`
/// for the multi-tenant serve layer's tenant-tagged adapter hot-swap
/// frames (`TAG_JOB_ROUND` / `TAG_JOB_DONE`), plus `barrier` for the
/// empty handshake token and `other` for anything with an
/// unrecognized leading tag.
pub const FRAME_CLASSES: [&str; 19] = [
    "init", "compute", "apply", "deltas", "reset", "shutdown", "up", "bye", "ping", "pong",
    "join", "evict", "nack", "state", "ring", "trace", "job", "barrier", "other",
];

/// Number of traffic classes (length of [`FRAME_CLASSES`]).
pub const N_FRAME_CLASSES: usize = FRAME_CLASSES.len();

/// Classify a frame by peeking its leading `[tag: u32 LE]` — every
/// control-protocol frame starts with one (see [`super::proto`]), and
/// the only tagless frame the runtime produces is the empty barrier
/// token. Returns an index into [`FRAME_CLASSES`].
pub fn frame_class(blob: &[u8]) -> usize {
    if blob.is_empty() {
        return 17; // barrier
    }
    if blob.len() < 4 {
        return 18; // other
    }
    let tag = u32::from_le_bytes([blob[0], blob[1], blob[2], blob[3]]);
    match tag {
        proto::TAG_INIT => 0,
        proto::TAG_COMPUTE => 1,
        proto::TAG_APPLY => 2,
        proto::TAG_DELTAS => 3,
        proto::TAG_RESET => 4,
        proto::TAG_SHUTDOWN => 5,
        proto::TAG_UP => 6,
        proto::TAG_BYE => 7,
        proto::TAG_PING => 8,
        proto::TAG_PONG => 9,
        proto::TAG_JOIN => 10,
        proto::TAG_EVICT => 11,
        proto::TAG_NACK => 12,
        proto::TAG_STATE => 13,
        proto::TAG_RING_LISTEN
        | proto::TAG_RING_PEERS
        | proto::TAG_RING_EXEC
        | proto::TAG_RING_RESET
        | proto::TAG_RING_CASTD
        | proto::TAG_RING_ADDR
        | proto::TAG_RING_FINAL
        | proto::TAG_RING_READY
        | proto::TAG_RING_PART
        | proto::TAG_RING_CAST => 14,
        proto::TAG_TRACE => 15,
        proto::TAG_JOB_ROUND | proto::TAG_JOB_DONE => 16,
        _ => 18, // other
    }
}

/// Shared live counters of one link's traffic (both halves increment
/// the same cell after a split). Alongside the aggregate totals, each
/// frame's bytes are attributed to its [`frame_class`] so compression
/// wins show up per channel (`compute` vs `up` vs `ring` …), not just
/// in aggregate.
#[derive(Debug, Default)]
pub struct StatsCell {
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
    class_sent: [AtomicU64; N_FRAME_CLASSES],
    class_recv: [AtomicU64; N_FRAME_CLASSES],
}

impl StatsCell {
    /// `bytes` is the whole on-wire frame (payload + framing overhead);
    /// `blob` is the payload, peeked for its leading tag.
    fn record_sent(&self, bytes: usize, blob: &[u8]) {
        self.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
        self.class_sent[frame_class(blob)].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn record_recv(&self, bytes: usize, blob: &[u8]) {
        self.frames_recv.fetch_add(1, Ordering::Relaxed);
        self.bytes_recv.fetch_add(bytes as u64, Ordering::Relaxed);
        self.class_recv[frame_class(blob)].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Current totals.
    pub fn snapshot(&self) -> TransportStats {
        let mut class_sent = [0u64; N_FRAME_CLASSES];
        let mut class_recv = [0u64; N_FRAME_CLASSES];
        for (dst, src) in class_sent.iter_mut().zip(self.class_sent.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        for (dst, src) in class_recv.iter_mut().zip(self.class_recv.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        TransportStats {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            class_sent,
            class_recv,
        }
    }
}

/// Measured transport-layer traffic: whole frames including the TCP
/// length prefixes — the bytes that actually cross the socket, reported
/// next to the modeled bytes in `benches/dist_step.rs`. The `class_*`
/// arrays break the same byte totals down by frame tag (indexed per
/// [`FRAME_CLASSES`]); they always sum to the aggregate counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Frames sent.
    pub frames_sent: u64,
    /// Frames received.
    pub frames_recv: u64,
    /// Bytes sent (payload + framing overhead).
    pub bytes_sent: u64,
    /// Bytes received (payload + framing overhead).
    pub bytes_recv: u64,
    /// Bytes sent, attributed per frame class ([`FRAME_CLASSES`]).
    pub class_sent: [u64; N_FRAME_CLASSES],
    /// Bytes received, attributed per frame class.
    pub class_recv: [u64; N_FRAME_CLASSES],
}

impl TransportStats {
    /// Fold another link's totals into this one.
    pub fn merge(&mut self, other: &TransportStats) {
        self.frames_sent += other.frames_sent;
        self.frames_recv += other.frames_recv;
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
        for (dst, src) in self.class_sent.iter_mut().zip(other.class_sent.iter()) {
            *dst += src;
        }
        for (dst, src) in self.class_recv.iter_mut().zip(other.class_recv.iter()) {
            *dst += src;
        }
    }

    /// Total bytes moved in both directions.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent + self.bytes_recv
    }

    /// (sent, received) bytes for one named frame class. Unknown names
    /// report zero rather than panicking — callers probe by label.
    pub fn class_bytes(&self, name: &str) -> (u64, u64) {
        match FRAME_CLASSES.iter().position(|c| *c == name) {
            Some(i) => (self.class_sent[i], self.class_recv[i]),
            None => (0, 0),
        }
    }

    /// Iterate the non-zero classes as `(name, sent, recv)` — the shape
    /// the JSON report wants, omitting channels a run never used.
    pub fn classes(&self) -> impl Iterator<Item = (&'static str, u64, u64)> + '_ {
        FRAME_CLASSES
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.class_sent[i] != 0 || self.class_recv[i] != 0)
            .map(|(i, name)| (*name, self.class_sent[i], self.class_recv[i]))
    }
}

/// Which transport a distributed run exchanges its frames over.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels; workers are threads of this process
    /// (the PR 3/4 path, refactored behind the seam).
    Channel,
    /// Length-prefixed frames over TCP: the aggregator listens on
    /// `listen`, workers connect per `spawn`.
    Tcp {
        /// Address the aggregator binds (`host:port`; port 0 picks an
        /// ephemeral one).
        listen: String,
        /// How the K workers come to exist.
        spawn: SpawnMode,
    },
}

/// How TCP workers are launched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpawnMode {
    /// In-process threads that connect over real loopback sockets —
    /// every socket path exercised, no subprocess needed (tests,
    /// benches, examples).
    Threads,
    /// Fork `repro dist-worker --connect <addr>` subprocesses from the
    /// current executable — genuinely separate address spaces.
    Processes,
    /// Wait for externally launched workers (`repro dist-worker
    /// --connect host:port`, possibly from other machines).
    External,
}

impl TransportKind {
    /// Parse a CLI label (`channel` | `tcp`) with the default TCP
    /// launch shape (loopback ephemeral port, forked subprocesses).
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "channel" | "mpsc" => TransportKind::Channel,
            "tcp" => TransportKind::Tcp {
                listen: "127.0.0.1:0".to_string(),
                spawn: SpawnMode::Processes,
            },
            _ => anyhow::bail!("unknown transport {s:?} (channel|tcp)"),
        })
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp { .. } => "tcp",
        }
    }
}

// ---------------------------------------------------------------------------
// Channel transport (in-process)
// ---------------------------------------------------------------------------

/// In-process transport: one mpsc channel per direction. `send_blob`
/// moves the buffer to the peer without copying; recycling happens at
/// the consumer's pool (shared process-wide in channel mode, so the
/// loop still closes).
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    stats: Arc<StatsCell>,
}

/// Build a connected pair of in-process endpoints.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    let a = ChannelTransport { tx: atx, rx: arx, stats: Arc::default() };
    let b = ChannelTransport { tx: btx, rx: brx, stats: Arc::default() };
    (a, b)
}

impl ChannelTransport {
    /// The live traffic counters of this endpoint (clone before
    /// splitting or boxing — both halves keep incrementing it).
    pub fn stats_cell(&self) -> Arc<StatsCell> {
        Arc::clone(&self.stats)
    }
}

struct ChannelTx {
    tx: mpsc::Sender<Vec<u8>>,
    stats: Arc<StatsCell>,
}

struct ChannelRx {
    rx: mpsc::Receiver<Vec<u8>>,
    stats: Arc<StatsCell>,
}

/// Stats count *payload* bytes on the channel path (the CRC trailer is
/// framing overhead the in-process wire never charges for), so the
/// measured byte totals stay comparable across PRs.
fn channel_send(
    tx: &mpsc::Sender<Vec<u8>>,
    stats: &StatsCell,
    mut blob: Vec<u8>,
    damage: bool,
) -> Result<()> {
    stats.record_sent(blob.len(), &blob);
    seal_crc(&mut blob, damage);
    tx.send(blob)
        .map_err(|_| anyhow::anyhow!("channel transport: peer receiver hung up"))
}

fn channel_recv(rx: &mpsc::Receiver<Vec<u8>>, stats: &StatsCell) -> Result<Vec<u8>> {
    let mut blob = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("channel transport: peer sender hung up"))?;
    unseal_crc(&mut blob)?;
    stats.record_recv(blob.len(), &blob);
    Ok(blob)
}

fn channel_recv_timeout(
    rx: &mpsc::Receiver<Vec<u8>>,
    stats: &StatsCell,
    timeout: Duration,
) -> Result<Option<Vec<u8>>> {
    match rx.recv_timeout(timeout) {
        Ok(mut blob) => {
            unseal_crc(&mut blob)?;
            stats.record_recv(blob.len(), &blob);
            Ok(Some(blob))
        }
        Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err(anyhow::anyhow!("channel transport: peer sender hung up"))
        }
    }
}

impl BlobTx for ChannelTransport {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob, false)
    }

    fn send_blob_corrupt(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob, true)
    }
}

impl BlobRx for ChannelTransport {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        channel_recv(&self.rx, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        channel_recv_timeout(&self.rx, &self.stats, timeout)
    }

    fn peer(&self) -> String {
        "chan".to_string()
    }
}

impl Transport for ChannelTransport {
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>) {
        let ChannelTransport { tx, rx, stats } = *self;
        (
            Box::new(ChannelTx { tx, stats: Arc::clone(&stats) }),
            Box::new(ChannelRx { rx, stats }),
        )
    }

    fn label(&self) -> &'static str {
        "channel"
    }

    fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }
}

impl BlobTx for ChannelTx {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob, false)
    }

    fn send_blob_corrupt(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob, true)
    }
}

impl BlobRx for ChannelRx {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        channel_recv(&self.rx, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        channel_recv_timeout(&self.rx, &self.stats, timeout)
    }

    fn peer(&self) -> String {
        "chan".to_string()
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Length-prefixed frames over one `TcpStream`. Frame buffers come
/// from / return to the endpoint's [`BufPool`], so the steady-state
/// send *and* receive paths are allocation-free.
pub struct TcpTransport {
    reader: TcpStream,
    writer: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
    peer: String,
}

impl TcpTransport {
    /// Wrap an accepted/connected stream. Disables Nagle (the step
    /// loop is latency-sensitive and every frame is a complete
    /// message).
    pub fn from_stream(stream: TcpStream, pool: Arc<BufPool>) -> Result<TcpTransport> {
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".to_string());
        let reader = stream.try_clone().context("cloning TCP stream")?;
        Ok(TcpTransport { reader, writer: stream, pool, stats: Arc::default(), peer })
    }

    /// Connect to an aggregator, retrying until `timeout` — workers are
    /// routinely launched before the aggregator's listener is up
    /// (the two-terminal flow), and a retry loop beats asking every
    /// operator to sequence their shells.
    pub fn connect(addr: &str, timeout: Duration, pool: Arc<BufPool>) -> Result<TcpTransport> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => return TcpTransport::from_stream(stream, pool),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e)
                            .with_context(|| format!("connecting to aggregator at {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// The live traffic counters of this endpoint (clone before
    /// splitting or boxing).
    pub fn stats_cell(&self) -> Arc<StatsCell> {
        Arc::clone(&self.stats)
    }
}

/// On-wire stats count the whole frame: 4-byte length prefix + payload
/// + 4-byte CRC trailer — the bytes that actually cross the socket.
fn tcp_send(
    writer: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
    blob: Vec<u8>,
    damage: bool,
) -> Result<()> {
    anyhow::ensure!(
        blob.len() <= MAX_FRAME,
        "refusing to send a {}-byte frame (cap {MAX_FRAME})",
        blob.len()
    );
    let _sp = crate::obs::trace::span("net", "tcp_send");
    let mut crc = crc32c(&blob);
    if damage {
        crc = !crc;
    }
    let len = (blob.len() as u32).to_le_bytes();
    writer.write_all(&len).context("writing frame length prefix")?;
    writer.write_all(&blob).context("writing frame body")?;
    writer.write_all(&crc.to_le_bytes()).context("writing frame CRC trailer")?;
    stats.record_sent(8 + blob.len(), &blob);
    pool.give_back(blob);
    Ok(())
}

fn tcp_recv(reader: &mut TcpStream, pool: &BufPool, stats: &StatsCell) -> Result<Vec<u8>> {
    let _sp = crate::obs::trace::span("net", "tcp_recv");
    let mut hdr = [0u8; 4];
    reader
        .read_exact(&mut hdr)
        .context("reading frame length prefix (peer disconnected?)")?;
    let len = u32::from_le_bytes(hdr) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length prefix {len} exceeds the {MAX_FRAME}-byte cap \
         (corrupt prefix or protocol desync)"
    );
    let mut buf = pool.checkout();
    buf.resize(len, 0);
    reader
        .read_exact(&mut buf)
        .with_context(|| format!("reading {len}-byte frame body (peer closed mid-frame?)"))?;
    let mut tail = [0u8; 4];
    reader
        .read_exact(&mut tail)
        .context("reading frame CRC trailer (peer closed mid-frame?)")?;
    let stored = u32::from_le_bytes(tail);
    let actual = crc32c(&buf);
    if stored != actual {
        pool.give_back(buf);
        anyhow::bail!(
            "{CRC_MISMATCH}: stored {stored:#010x}, computed {actual:#010x} \
             over {len} payload bytes"
        );
    }
    stats.record_recv(8 + len, &buf);
    Ok(buf)
}

fn io_timed_out(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Timed receive over a TCP stream. Arms `SO_RCVTIMEO` for the read,
/// restores fully blocking mode on every return path, and tracks
/// *progress*: a window that passes with zero new bytes is a quiet
/// timeout (`Ok(None)`) only if no frame was started; once the peer has
/// sent a partial frame, the same silence is a "stalled mid-frame"
/// error, because the link is wedged, not idle.
fn tcp_recv_timeout(
    reader: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
    timeout: Duration,
) -> Result<Option<Vec<u8>>> {
    reader
        .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
        .context("arming read timeout")?;
    let result = tcp_recv_timeout_inner(reader, pool, stats);
    let restore = reader.set_read_timeout(None);
    let out = result?;
    restore.context("restoring blocking reads after a timed receive")?;
    Ok(out)
}

fn tcp_recv_timeout_inner(
    reader: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
) -> Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match reader.read(&mut hdr[got..]) {
            Ok(0) => anyhow::bail!("reading frame length prefix (peer disconnected?)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if io_timed_out(&e) => {
                if got == 0 {
                    return Ok(None);
                }
                anyhow::bail!(
                    "peer stalled mid-frame: {got} of 4 length-prefix bytes, then silence"
                );
            }
            Err(e) => return Err(e).context("reading frame length prefix"),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length prefix {len} exceeds the {MAX_FRAME}-byte cap \
         (corrupt prefix or protocol desync)"
    );
    let mut buf = pool.checkout();
    buf.resize(len, 0);
    let mut got = 0usize;
    while got < len {
        match reader.read(&mut buf[got..]) {
            Ok(0) => anyhow::bail!("reading {len}-byte frame body (peer closed mid-frame?)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if io_timed_out(&e) => {
                anyhow::bail!("peer stalled mid-frame: {got} of {len} body bytes, then silence")
            }
            Err(e) => return Err(e).context("reading frame body"),
        }
    }
    let mut tail = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match reader.read(&mut tail[got..]) {
            Ok(0) => anyhow::bail!("reading frame CRC trailer (peer closed mid-frame?)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if io_timed_out(&e) => {
                anyhow::bail!("peer stalled mid-frame: {got} of 4 CRC trailer bytes, then silence")
            }
            Err(e) => return Err(e).context("reading frame CRC trailer"),
        }
    }
    let stored = u32::from_le_bytes(tail);
    let actual = crc32c(&buf);
    if stored != actual {
        pool.give_back(buf);
        anyhow::bail!(
            "{CRC_MISMATCH}: stored {stored:#010x}, computed {actual:#010x} \
             over {len} payload bytes"
        );
    }
    stats.record_recv(8 + len, &buf);
    crate::obs::trace::instant("net", "frame_recv");
    Ok(Some(buf))
}

struct TcpTx {
    writer: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
}

struct TcpRx {
    reader: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
    peer: String,
}

impl BlobTx for TcpTransport {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob, false)
    }

    fn send_blob_corrupt(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob, true)
    }
}

impl BlobRx for TcpTransport {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.reader, &self.pool, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        tcp_recv_timeout(&mut self.reader, &self.pool, &self.stats, timeout)
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

impl Transport for TcpTransport {
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>) {
        let TcpTransport { reader, writer, pool, stats, peer } = *self;
        (
            Box::new(TcpTx { writer, pool: Arc::clone(&pool), stats: Arc::clone(&stats) }),
            Box::new(TcpRx { reader, pool, stats, peer }),
        )
    }

    fn label(&self) -> &'static str {
        "tcp"
    }

    fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }
}

impl BlobTx for TcpTx {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob, false)
    }

    fn send_blob_corrupt(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob, true)
    }
}

impl BlobRx for TcpRx {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.reader, &self.pool, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        tcp_recv_timeout(&mut self.reader, &self.pool, &self.stats, timeout)
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

// ---------------------------------------------------------------------------
// Listener helpers (aggregator side)
// ---------------------------------------------------------------------------

/// Bind the aggregator's listener and report the resolved address
/// (resolves port 0 to the ephemeral port workers must dial).
///
/// `AddrInUse` is retried for up to 30 s: a restarted aggregator
/// (`--resume` after a crash) rebinds the same fixed port its workers
/// are redialing, and the dead incarnation's connections can hold it
/// in `TIME_WAIT` for a while. Any other bind error is immediate.
pub fn listen(addr: &str) -> Result<(TcpListener, SocketAddr)> {
    let deadline = Instant::now() + Duration::from_secs(30);
    let listener = loop {
        match TcpListener::bind(addr) {
            Ok(l) => break l,
            Err(e) if e.kind() == ErrorKind::AddrInUse && Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("binding dist listener on {addr}"));
            }
        }
    };
    let local = listener.local_addr().context("resolving listener address")?;
    Ok((listener, local))
}

/// Accept exactly `n` worker connections, failing (instead of hanging
/// CI or a terminal forever) if they have not all arrived by
/// `timeout`. Accepted streams are returned in connection order, which
/// becomes the worker-id order.
pub fn accept_workers(
    listener: &TcpListener,
    n: usize,
    timeout: Duration,
) -> Result<Vec<TcpStream>> {
    listener.set_nonblocking(true).context("making listener non-blocking")?;
    let deadline = Instant::now() + timeout;
    let mut streams = Vec::with_capacity(n);
    while streams.len() < n {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The stream inherits non-blocking from the listener on
                // some platforms; frame IO requires blocking reads.
                stream.set_nonblocking(false).context("making worker stream blocking")?;
                streams.push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for dist workers: {} of {n} connected \
                     within {timeout:?} (launch the rest with `repro dist-worker \
                     --connect <addr>`)",
                    streams.len()
                );
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e).context("accepting worker connection"),
        }
    }
    Ok(streams)
}

// ---------------------------------------------------------------------------
// FlakyTransport (deterministic network-fault injection)
// ---------------------------------------------------------------------------

/// Shared script + progress of one worker's network faults. Lives in an
/// `Arc` *outside* the transport it wraps, so the script survives a
/// reconnect: a redialed link wrapped with the same state continues the
/// frame count instead of re-arming spent verbs.
pub struct FlakyState {
    inner: Mutex<FlakyScript>,
}

#[derive(Default)]
struct FlakyScript {
    /// Frames offered to `send_blob` so far (monotonic across redials).
    sent: u64,
    /// `reset-after-frame=N`: error the send at frame N, once, and arm
    /// one receive-side error so both halves observe the reset.
    reset_at: Option<u64>,
    rx_reset_pending: bool,
    /// `corrupt-frame=N`: deliver frame N with a damaged CRC trailer.
    corrupt_at: Option<u64>,
    /// `delay-ms=MS@N`: sleep MS ms before sending frame N.
    delay: Option<(u64, u64)>,
    /// `partition-ms=MS@E`: from frame E, both directions fail for MS
    /// wall-clock milliseconds, then the link heals.
    partition: Option<(u64, u64)>,
    partition_until: Option<Instant>,
}

/// What the script decided for one outbound frame.
enum SendRuling {
    Clean,
    Corrupt,
    Fail(&'static str),
}

impl FlakyState {
    /// Extract the network verbs of `plan`. `None` when the plan holds
    /// no network actions — the common case, costing nothing.
    pub fn from_plan(plan: &FaultPlan) -> Option<Arc<FlakyState>> {
        let mut script = FlakyScript::default();
        let mut any = false;
        for a in &plan.actions {
            match *a {
                FaultAction::ResetAfterFrame(n) => {
                    script.reset_at = Some(n as u64);
                    any = true;
                }
                FaultAction::CorruptFrame(n) => {
                    script.corrupt_at = Some(n as u64);
                    any = true;
                }
                FaultAction::DelayMs { ms, at } => {
                    script.delay = Some((ms, at as u64));
                    any = true;
                }
                FaultAction::PartitionMs { ms, at } => {
                    script.partition = Some((ms, at as u64));
                    any = true;
                }
                _ => {}
            }
        }
        any.then(|| Arc::new(FlakyState { inner: Mutex::new(script) }))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FlakyScript> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Consult (and advance) the script for the next outbound frame.
    /// Returns the ruling plus an optional pre-send sleep.
    fn on_send(&self) -> (SendRuling, Option<Duration>) {
        let mut s = self.lock();
        let idx = s.sent;
        s.sent += 1;
        if let Some((ms, at)) = s.partition {
            if idx >= at && s.partition_until.is_none() {
                s.partition = None;
                s.partition_until = Some(Instant::now() + Duration::from_millis(ms));
            }
        }
        if let Some(until) = s.partition_until {
            if Instant::now() < until {
                return (SendRuling::Fail("flaky transport: partitioned"), None);
            }
            s.partition_until = None;
        }
        if s.reset_at == Some(idx) {
            s.reset_at = None;
            s.rx_reset_pending = true;
            return (SendRuling::Fail("flaky transport: connection reset by script"), None);
        }
        let sleep = match s.delay {
            Some((ms, at)) if at == idx => {
                s.delay = None;
                Some(Duration::from_millis(ms))
            }
            _ => None,
        };
        if s.corrupt_at == Some(idx) {
            s.corrupt_at = None;
            return (SendRuling::Corrupt, sleep);
        }
        (SendRuling::Clean, sleep)
    }

    /// Receive-side script check, consulted *before* touching the inner
    /// transport so queued in-flight frames survive a scripted reset.
    fn on_recv(&self) -> Option<&'static str> {
        let mut s = self.lock();
        if s.rx_reset_pending {
            s.rx_reset_pending = false;
            return Some("flaky transport: connection reset by script");
        }
        if let Some(until) = s.partition_until {
            if Instant::now() < until {
                return Some("flaky transport: partitioned");
            }
            s.partition_until = None;
        }
        None
    }
}

/// A [`Transport`] wrapper that acts out the network verbs of a
/// [`FaultPlan`] — scripted resets, CRC corruption, delays, and timed
/// partitions — against a real inner transport, deterministically by
/// frame index instead of by packet luck. Wraps the *worker* side of
/// the aggregator link; the aggregator sees genuine symptoms (a dead
/// read, a CRC mismatch) through its ordinary failure detector.
pub struct FlakyTransport {
    inner: Box<dyn Transport>,
    state: Arc<FlakyState>,
}

impl FlakyTransport {
    /// Wrap `inner` under the shared fault script.
    pub fn wrap(inner: Box<dyn Transport>, state: Arc<FlakyState>) -> FlakyTransport {
        FlakyTransport { inner, state }
    }
}

fn flaky_send<T: BlobTx + ?Sized>(
    tx: &mut T,
    state: &FlakyState,
    blob: Vec<u8>,
) -> Result<()> {
    let (ruling, sleep) = state.on_send();
    if let Some(d) = sleep {
        std::thread::sleep(d);
    }
    match ruling {
        SendRuling::Clean => tx.send_blob(blob),
        SendRuling::Corrupt => tx.send_blob_corrupt(blob),
        SendRuling::Fail(why) => Err(anyhow::anyhow!(why)),
    }
}

impl BlobTx for FlakyTransport {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        flaky_send(self.inner.as_mut(), &self.state, blob)
    }
}

impl BlobRx for FlakyTransport {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        if let Some(why) = self.state.on_recv() {
            anyhow::bail!(why);
        }
        self.inner.recv_blob()
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        if let Some(why) = self.state.on_recv() {
            anyhow::bail!(why);
        }
        self.inner.recv_blob_timeout(timeout)
    }

    fn peer(&self) -> String {
        self.inner.peer()
    }
}

impl Transport for FlakyTransport {
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>) {
        let FlakyTransport { inner, state } = *self;
        let (tx, rx) = inner.split();
        (
            Box::new(FlakyTx { tx, state: Arc::clone(&state) }),
            Box::new(FlakyRx { rx, state }),
        )
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }
}

struct FlakyTx {
    tx: Box<dyn BlobTx>,
    state: Arc<FlakyState>,
}

struct FlakyRx {
    rx: Box<dyn BlobRx>,
    state: Arc<FlakyState>,
}

impl BlobTx for FlakyTx {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        flaky_send(self.tx.as_mut(), &self.state, blob)
    }
}

impl BlobRx for FlakyRx {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        if let Some(why) = self.state.on_recv() {
            anyhow::bail!(why);
        }
        self.rx.recv_blob()
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        if let Some(why) = self.state.on_recv() {
            anyhow::bail!(why);
        }
        self.rx.recv_blob_timeout(timeout)
    }

    fn peer(&self) -> String {
        self.rx.peer()
    }
}

// ---------------------------------------------------------------------------
// Ring links (worker ↔ worker)
// ---------------------------------------------------------------------------
//
// Ring exchange needs direct worker↔worker links, negotiated by the
// aggregator: each worker opens a listener, reports its address, and is
// then told which peer to dial. Over TCP the address is a real
// `host:port`; in channel mode (workers are threads of one process)
// addresses are `chan://N` tokens resolved through a process-global
// rendezvous registry, so the negotiation protocol is identical across
// transports and the trainer never special-cases the wiring.

/// Process-global rendezvous for channel-mode ring links: token →
/// queue of endpoints pushed by connectors, popped by the listener.
fn ring_registry() -> &'static Mutex<HashMap<String, Vec<ChannelTransport>>> {
    static REG: OnceLock<Mutex<HashMap<String, Vec<ChannelTransport>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Register a channel-mode ring listener and return its `chan://N`
/// address token (process-unique; concurrent tests never collide).
pub fn channel_ring_listen() -> String {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let token = format!("chan://{}", NEXT.fetch_add(1, Ordering::Relaxed));
    ring_registry()
        .lock()
        .expect("ring rendezvous registry poisoned")
        .insert(token.clone(), Vec::new());
    token
}

/// Drop a channel-mode ring listener registration (called when links
/// are torn down for renegotiation, so stale tokens do not accumulate
/// across membership changes).
pub fn channel_ring_close(addr: &str) {
    ring_registry()
        .lock()
        .expect("ring rendezvous registry poisoned")
        .remove(addr);
}

fn channel_ring_connect(addr: &str) -> Result<ChannelTransport> {
    let (ours, theirs) = channel_pair();
    let mut reg = ring_registry().lock().expect("ring rendezvous registry poisoned");
    let queue = reg
        .get_mut(addr)
        .ok_or_else(|| anyhow::anyhow!("no ring listener registered at {addr}"))?;
    queue.push(theirs);
    Ok(ours)
}

fn channel_ring_accept(addr: &str, timeout: Duration) -> Result<ChannelTransport> {
    let deadline = Instant::now() + timeout;
    loop {
        {
            let mut reg = ring_registry().lock().expect("ring rendezvous registry poisoned");
            match reg.get_mut(addr) {
                Some(queue) if !queue.is_empty() => return Ok(queue.remove(0)),
                Some(_) => {}
                None => anyhow::bail!("ring listener at {addr} was closed while accepting"),
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "timed out waiting for a ring peer to dial {addr} within {timeout:?}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A worker's listening endpoint for its incoming ring link, either
/// flavor behind one face. Created on [`proto::TAG_RING_LISTEN`]; the
/// address from [`RingListener::addr`] travels to the aggregator, which
/// forwards it to the predecessor via [`proto::TAG_RING_PEERS`].
pub enum RingListener {
    /// Real socket on an ephemeral loopback/interface port.
    Tcp(TcpListener, SocketAddr),
    /// Channel-mode rendezvous token.
    Channel(String),
}

impl RingListener {
    /// Open a listener of the requested flavor. TCP binds
    /// `127.0.0.1:0` — ring links are loopback-scoped for now, matching
    /// the multi-process CI topology.
    pub fn open(tcp: bool) -> Result<RingListener> {
        if tcp {
            let (listener, addr) = listen("127.0.0.1:0")?;
            Ok(RingListener::Tcp(listener, addr))
        } else {
            Ok(RingListener::Channel(channel_ring_listen()))
        }
    }

    /// The dialable address (`host:port` or `chan://N`).
    pub fn addr(&self) -> String {
        match self {
            RingListener::Tcp(_, addr) => addr.to_string(),
            RingListener::Channel(token) => token.clone(),
        }
    }

    /// Accept exactly one inbound ring link, failing after `timeout`.
    pub fn accept(&self, timeout: Duration, pool: Arc<BufPool>) -> Result<Box<dyn Transport>> {
        match self {
            RingListener::Tcp(listener, _) => {
                let stream = accept_workers(listener, 1, timeout)
                    .context("accepting ring predecessor link")?
                    .pop()
                    .expect("accept_workers returned n streams");
                Ok(Box::new(TcpTransport::from_stream(stream, pool)?))
            }
            RingListener::Channel(token) => {
                Ok(Box::new(channel_ring_accept(token, timeout)?))
            }
        }
    }
}

impl Drop for RingListener {
    fn drop(&mut self) {
        if let RingListener::Channel(token) = self {
            channel_ring_close(token);
        }
    }
}

/// Dial a peer worker's ring listener — `chan://N` tokens resolve
/// through the in-process rendezvous, anything else is a TCP address
/// (with the same patient retry loop as the aggregator connect, since
/// the successor's listener may be a few frames behind ours).
pub fn ring_connect(
    addr: &str,
    timeout: Duration,
    pool: Arc<BufPool>,
) -> Result<Box<dyn Transport>> {
    if addr.starts_with("chan://") {
        Ok(Box::new(channel_ring_connect(addr)?))
    } else {
        Ok(Box::new(TcpTransport::connect(addr, timeout, pool)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Arc<BufPool> {
        Arc::new(BufPool::new())
    }

    #[test]
    fn channel_round_trip_and_stats() {
        let (mut a, mut b) = channel_pair();
        a.send_blob(vec![1, 2, 3]).unwrap();
        a.send_blob(vec![4]).unwrap();
        assert_eq!(b.recv_blob().unwrap(), vec![1, 2, 3]);
        assert_eq!(b.recv_blob().unwrap(), vec![4]);
        b.send_blob(vec![9; 10]).unwrap();
        assert_eq!(a.recv_blob().unwrap(), vec![9; 10]);
        let sa = a.stats();
        let sb = b.stats();
        assert_eq!(sa.frames_sent, 2);
        assert_eq!(sa.bytes_sent, 4);
        assert_eq!(sa.frames_recv, 1);
        assert_eq!(sa.bytes_recv, 10);
        assert_eq!(sb.bytes_recv, 4);
        // Dead peer surfaces as an error, not a hang.
        drop(b);
        assert!(a.send_blob(vec![0]).is_err());
        assert!(a.recv_blob().is_err());
    }

    #[test]
    fn channel_barrier_and_split() {
        let (a, b) = channel_pair();
        let (mut a, mut b) = (Box::new(a) as Box<dyn Transport>, Box::new(b));
        let h = std::thread::spawn(move || {
            b.barrier().unwrap();
            b.send_blob(vec![7]).unwrap();
            b
        });
        a.barrier().unwrap();
        let b = h.join().unwrap();
        let (_btx, _brx) = (b as Box<dyn Transport>).split();
        let (_atx, mut arx) = a.split();
        assert_eq!(arx.recv_blob().unwrap(), vec![7]);
    }

    #[test]
    fn tcp_round_trip_recycles_buffers() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let pa = pool();
        let pb = pool();
        let pb2 = Arc::clone(&pb);
        let h = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut t = TcpTransport::from_stream(stream, pb2).unwrap();
            for i in 0..4u8 {
                let mut buf = t.recv_blob().unwrap();
                assert_eq!(buf, vec![i; 3 + i as usize]);
                // Echo back through the pool.
                buf.push(0xEE);
                t.send_blob(buf).unwrap();
            }
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, Arc::clone(&pa)).unwrap();
        for i in 0..4u8 {
            let mut buf = pa.checkout();
            buf.resize(3 + i as usize, i);
            t.send_blob(buf).unwrap();
            let echoed = t.recv_blob().unwrap();
            assert_eq!(*echoed.last().unwrap(), 0xEE);
            pa.give_back(echoed);
        }
        h.join().unwrap();
        // Steady state: buffers recycled after warmup on both paths
        // (sent buffers return on send, received ones on give_back).
        assert!(pa.reuses() > 0, "sender-side pool must recycle");
        let s = t.stats();
        assert_eq!(s.frames_sent, 4);
        assert_eq!(s.frames_recv, 4);
        // Framing overhead is counted: 4-byte prefix + 4-byte CRC
        // trailer per frame.
        assert_eq!(s.bytes_sent, 8 * 4 + (3 + 4 + 5 + 6));
    }

    #[test]
    fn tcp_barrier_round_trip() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            t.barrier().unwrap();
            t.send_blob(b"after".to_vec()).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        t.barrier().unwrap();
        assert_eq!(t.recv_blob().unwrap(), b"after".to_vec());
        h.join().unwrap();
    }

    #[test]
    fn tcp_rejects_oversized_length_prefix() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // A malicious/corrupt prefix claiming ~4 GiB.
            raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
            raw
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = t.recv_blob().unwrap_err().to_string();
        assert!(err.contains("exceeds"), "descriptive cap error, got: {err}");
        drop(h.join().unwrap());
    }

    #[test]
    fn tcp_truncated_frame_is_an_error_not_a_hang() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // Claim 100 bytes, deliver 10, vanish.
            raw.write_all(&100u32.to_le_bytes()).unwrap();
            raw.write_all(&[0xAB; 10]).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = format!("{:#}", t.recv_blob().unwrap_err());
        assert!(
            err.contains("frame body"),
            "truncation must name the frame body read, got: {err}"
        );
        h.join().unwrap();
    }

    #[test]
    fn accept_workers_times_out_cleanly() {
        let (listener, _addr) = listen("127.0.0.1:0").unwrap();
        let err = accept_workers(&listener, 2, Duration::from_millis(80)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "got: {err}");
    }

    #[test]
    fn liveness_window_tracks_heartbeat_interval() {
        // The deadline scales with the configured heartbeat, not a
        // fixed constant: 4 missed 100ms beats = 400ms.
        assert_eq!(liveness_window(100, 4), Duration::from_millis(400));
        assert_eq!(liveness_window(250, 2), Duration::from_millis(500));
        // Boundary: zero heartbeat / zero misses degrade to a minimal
        // but non-zero window instead of an instant eviction.
        assert_eq!(liveness_window(0, 0), Duration::from_millis(1));
        // Monotone in both knobs.
        assert!(liveness_window(200, 4) > liveness_window(100, 4));
        assert!(liveness_window(100, 8) > liveness_window(100, 4));
    }

    #[test]
    fn channel_timed_recv_distinguishes_quiet_from_dead() {
        let (mut a, mut b) = channel_pair();
        // Quiet peer: timeout, not an error.
        assert!(a.recv_blob_timeout(Duration::from_millis(30)).unwrap().is_none());
        // Delivery within the window.
        b.send_blob(vec![5, 6]).unwrap();
        assert_eq!(
            a.recv_blob_timeout(Duration::from_secs(5)).unwrap().unwrap(),
            vec![5, 6]
        );
        // Dead peer: an error, not a quiet timeout.
        drop(b);
        assert!(a.recv_blob_timeout(Duration::from_millis(30)).is_err());
    }

    #[test]
    fn tcp_timed_recv_quiet_then_delivers_then_blocks_again() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            // Stay quiet long enough for one timed window to expire.
            std::thread::sleep(Duration::from_millis(150));
            t.send_blob(b"late".to_vec()).unwrap();
            t.send_blob(b"after".to_vec()).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        // Window 1: nothing on the wire yet.
        assert!(t.recv_blob_timeout(Duration::from_millis(40)).unwrap().is_none());
        // Patience: the frame eventually lands inside a window.
        let mut got = None;
        for _ in 0..200 {
            if let Some(b) = t.recv_blob_timeout(Duration::from_millis(50)).unwrap() {
                got = Some(b);
                break;
            }
        }
        assert_eq!(got.unwrap(), b"late".to_vec());
        // Blocking mode was restored: a plain recv still works.
        assert_eq!(t.recv_blob().unwrap(), b"after".to_vec());
        h.join().unwrap();
    }

    #[test]
    fn tcp_partial_frame_then_silence_is_a_stall_error() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // Start a frame (2 of 4 prefix bytes), then go silent —
            // the link is wedged mid-message, not idle.
            raw.write_all(&[9, 0]).unwrap();
            std::thread::sleep(Duration::from_millis(400));
            raw
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = loop {
            // The first windows may be fully quiet depending on thread
            // scheduling; once the partial prefix lands, silence inside
            // a window must surface as a stall.
            match t.recv_blob_timeout(Duration::from_millis(60)) {
                Ok(Some(b)) => panic!("no full frame was ever sent, got {b:?}"),
                Ok(None) => continue,
                Err(e) => break format!("{e:#}"),
            }
        };
        assert!(err.contains("stalled mid-frame"), "got: {err}");
        drop(h.join().unwrap());
    }

    #[test]
    fn frame_bytes_are_attributed_per_tag_class() {
        let (mut a, mut b) = channel_pair();
        // A compute-tagged frame, an up-tagged frame, a barrier token,
        // and one unrecognized tag.
        let mut compute = proto::TAG_COMPUTE.to_le_bytes().to_vec();
        compute.extend_from_slice(&[0u8; 8]);
        a.send_blob(compute).unwrap();
        a.send_blob(Vec::new()).unwrap();
        a.send_blob(0xDEAD_BEEFu32.to_le_bytes().to_vec()).unwrap();
        let mut up = proto::TAG_UP.to_le_bytes().to_vec();
        up.extend_from_slice(&[0u8; 16]);
        b.send_blob(up).unwrap();
        for _ in 0..3 {
            b.recv_blob().unwrap();
        }
        a.recv_blob().unwrap();
        let sa = a.stats();
        assert_eq!(sa.class_bytes("compute"), (12, 0));
        // A channel-mode barrier token is zero payload bytes, so it
        // only moves the frame counter, never the class bytes.
        assert_eq!(sa.class_bytes("barrier"), (0, 0));
        assert_eq!(sa.class_bytes("other"), (4, 0));
        assert_eq!(sa.class_bytes("up"), (0, 20));
        assert_eq!(sa.class_bytes("no-such-class"), (0, 0));
        // The breakdown always sums back to the aggregate counters.
        assert_eq!(sa.class_sent.iter().sum::<u64>(), sa.bytes_sent);
        assert_eq!(sa.class_recv.iter().sum::<u64>(), sa.bytes_recv);
        // Receiver sees the mirror image.
        let sb = b.stats();
        assert_eq!(sb.class_bytes("compute"), (0, 12));
        assert_eq!(sb.class_bytes("up"), (20, 0));
        // Non-zero-class iterator skips unused channels.
        let used: Vec<&str> = sb.classes().map(|(name, _, _)| name).collect();
        assert!(used.contains(&"compute") && used.contains(&"up"));
        assert!(!used.contains(&"deltas"));
    }

    #[test]
    fn frame_class_covers_ring_tags_and_short_frames() {
        let barrier = frame_class(&[]);
        assert_eq!(FRAME_CLASSES[barrier], "barrier");
        // Shorter than a tag: unclassifiable, not a panic.
        assert_eq!(FRAME_CLASSES[frame_class(&[1, 2])], "other");
        for tag in [
            proto::TAG_RING_LISTEN,
            proto::TAG_RING_PEERS,
            proto::TAG_RING_EXEC,
            proto::TAG_RING_RESET,
            proto::TAG_RING_CASTD,
            proto::TAG_RING_ADDR,
            proto::TAG_RING_FINAL,
            proto::TAG_RING_READY,
            proto::TAG_RING_PART,
            proto::TAG_RING_CAST,
        ] {
            assert_eq!(FRAME_CLASSES[frame_class(&tag.to_le_bytes())], "ring");
        }
        assert_eq!(FRAME_CLASSES[frame_class(&proto::TAG_STATE.to_le_bytes())], "state");
        assert_eq!(FRAME_CLASSES[frame_class(&proto::TAG_PING.to_le_bytes())], "ping");
        assert_eq!(FRAME_CLASSES[frame_class(&proto::TAG_TRACE.to_le_bytes())], "trace");
        assert_eq!(FRAME_CLASSES[frame_class(&proto::TAG_JOB_ROUND.to_le_bytes())], "job");
        assert_eq!(FRAME_CLASSES[frame_class(&proto::TAG_JOB_DONE.to_le_bytes())], "job");
    }

    #[test]
    fn channel_ring_rendezvous_connects_listener_to_dialer() {
        let listener = RingListener::open(false).unwrap();
        let addr = listener.addr();
        assert!(addr.starts_with("chan://"), "got {addr}");
        let dialer_addr = addr.clone();
        let h = std::thread::spawn(move || {
            let mut link = ring_connect(&dialer_addr, Duration::from_secs(5), pool()).unwrap();
            link.send_blob(vec![0xAA, 0xBB]).unwrap();
            link.recv_blob().unwrap()
        });
        let mut accepted = listener.accept(Duration::from_secs(5), pool()).unwrap();
        assert_eq!(accepted.recv_blob().unwrap(), vec![0xAA, 0xBB]);
        accepted.send_blob(vec![0xCC]).unwrap();
        assert_eq!(h.join().unwrap(), vec![0xCC]);
    }

    #[test]
    fn channel_ring_rendezvous_rejects_unknown_and_times_out() {
        // Dialing a token nobody registered is an immediate error.
        assert!(ring_connect("chan://no-such-token", Duration::from_secs(1), pool()).is_err());
        // A listener nobody dials times out instead of hanging.
        let listener = RingListener::open(false).unwrap();
        let err = listener
            .accept(Duration::from_millis(60), pool())
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("timed out"), "got: {err}");
        // Dropping the listener releases its token: dialing now fails.
        let addr = listener.addr();
        drop(listener);
        assert!(ring_connect(&addr, Duration::from_secs(1), pool()).is_err());
    }

    #[test]
    fn tcp_ring_listener_round_trips() {
        let listener = RingListener::open(true).unwrap();
        let addr = listener.addr();
        let h = std::thread::spawn(move || {
            let mut link = ring_connect(&addr, Duration::from_secs(10), pool()).unwrap();
            link.send_blob(b"ring".to_vec()).unwrap();
        });
        let mut accepted = listener.accept(Duration::from_secs(10), pool()).unwrap();
        assert_eq!(accepted.recv_blob().unwrap(), b"ring".to_vec());
        h.join().unwrap();
    }

    #[test]
    fn crc32c_matches_the_reference_vector() {
        // RFC 3720 test vector for CRC32C (Castagnoli).
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(&[]), 0);
        // Sensitive to single-bit flips.
        assert_ne!(crc32c(b"133456789"), crc32c(b"123456789"));
    }

    #[test]
    fn corrupt_channel_frame_is_retryable_not_poisonous() {
        let (mut a, mut b) = channel_pair();
        a.send_blob_corrupt(vec![1, 2, 3]).unwrap();
        a.send_blob(vec![4, 5]).unwrap();
        let err = b.recv_blob().unwrap_err();
        assert!(is_corrupt_frame_err(&err), "got: {err:#}");
        // The frame boundary held: the next frame reads cleanly.
        assert_eq!(b.recv_blob().unwrap(), vec![4, 5]);
    }

    #[test]
    fn corrupt_tcp_frame_is_retryable_not_poisonous() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            t.send_blob_corrupt(b"damaged".to_vec()).unwrap();
            t.send_blob(b"clean".to_vec()).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = t.recv_blob().unwrap_err();
        assert!(is_corrupt_frame_err(&err), "got: {err:#}");
        // The length prefix delimited the bad frame; the stream is
        // still framed and the next frame arrives intact — corruption
        // is a resend, not a desync. Both timed and blocking reads.
        assert_eq!(t.recv_blob().unwrap(), b"clean".to_vec());
        h.join().unwrap();
        // Non-CRC errors are not classified as corruption.
        assert!(!is_corrupt_frame_err(&anyhow::anyhow!("peer disconnected")));
    }

    #[test]
    fn tcp_timed_recv_detects_corruption_too() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            t.send_blob_corrupt(vec![7; 32]).unwrap();
            t.send_blob(vec![8; 5]).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = loop {
            match t.recv_blob_timeout(Duration::from_millis(100)) {
                Ok(None) => continue,
                Ok(Some(b)) => panic!("corrupt frame decoded cleanly: {b:?}"),
                Err(e) => break e,
            }
        };
        assert!(is_corrupt_frame_err(&err), "got: {err:#}");
        let next = loop {
            if let Some(b) = t.recv_blob_timeout(Duration::from_millis(100)).unwrap() {
                break b;
            }
        };
        assert_eq!(next, vec![8; 5]);
        h.join().unwrap();
    }

    #[test]
    fn tcp_peer_labels_name_the_remote_address() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let t = TcpTransport::connect(&addr.to_string(), Duration::from_secs(10), pool())
                .unwrap();
            assert!(t.peer().starts_with("127.0.0.1:"), "got {}", t.peer());
            // Keep the link open until the main thread is done probing.
            std::thread::sleep(Duration::from_millis(100));
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let t = TcpTransport::from_stream(stream, pool()).unwrap();
        assert!(t.peer().starts_with("127.0.0.1:"), "got {}", t.peer());
        let (_tx, rx) = (Box::new(t) as Box<dyn Transport>).split();
        assert!(rx.peer().starts_with("127.0.0.1:"), "got {}", rx.peer());
        let (a, _b) = channel_pair();
        assert_eq!((Box::new(a) as Box<dyn Transport>).split().1.peer(), "chan");
        h.join().unwrap();
    }

    #[test]
    fn flaky_reset_fires_once_on_both_halves_then_heals() {
        let plan = FaultPlan::parse("reset-after-frame=1").unwrap();
        let state = FlakyState::from_plan(&plan).unwrap();
        let (a, mut b) = channel_pair();
        let mut f = FlakyTransport::wrap(Box::new(a), Arc::clone(&state));
        f.send_blob(vec![0]).unwrap(); // frame 0: clean
        let err = f.send_blob(vec![1]).unwrap_err(); // frame 1: reset
        assert!(err.to_string().contains("reset"), "got: {err}");
        // The receive half observes the same reset exactly once...
        assert!(f.recv_blob_timeout(Duration::from_millis(10)).is_err());
        // ...then the link heals: frame 0 is still queued at the peer,
        // and new sends flow again.
        assert_eq!(b.recv_blob().unwrap(), vec![0]);
        f.send_blob(vec![2]).unwrap();
        assert_eq!(b.recv_blob().unwrap(), vec![2]);
    }

    #[test]
    fn flaky_corrupt_and_delay_route_by_frame_index() {
        let plan = FaultPlan::parse("corrupt-frame=1;delay-ms=30@2").unwrap();
        let state = FlakyState::from_plan(&plan).unwrap();
        let (a, mut b) = channel_pair();
        let mut f = FlakyTransport::wrap(Box::new(a), state);
        f.send_blob(vec![0]).unwrap();
        f.send_blob(vec![1]).unwrap(); // scripted CRC damage
        let t0 = Instant::now();
        f.send_blob(vec![2]).unwrap(); // scripted 30ms delay
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(b.recv_blob().unwrap(), vec![0]);
        let err = b.recv_blob().unwrap_err();
        assert!(is_corrupt_frame_err(&err), "got: {err:#}");
        assert_eq!(b.recv_blob().unwrap(), vec![2]);
    }

    #[test]
    fn flaky_partition_blocks_both_ways_then_expires() {
        let plan = FaultPlan::parse("partition-ms=60@1").unwrap();
        let state = FlakyState::from_plan(&plan).unwrap();
        let (a, mut b) = channel_pair();
        let mut f = FlakyTransport::wrap(Box::new(a), state);
        f.send_blob(vec![0]).unwrap();
        // Frame 1 opens the partition window: both directions fail.
        assert!(f.send_blob(vec![1]).is_err());
        assert!(f.recv_blob_timeout(Duration::from_millis(5)).is_err());
        std::thread::sleep(Duration::from_millis(80));
        // Healed: traffic flows both ways again.
        f.send_blob(vec![2]).unwrap();
        b.send_blob(vec![9]).unwrap();
        assert_eq!(b.recv_blob().unwrap(), vec![0]);
        assert_eq!(b.recv_blob().unwrap(), vec![2]);
        assert_eq!(f.recv_blob().unwrap(), vec![9]);
    }

    #[test]
    fn flaky_state_only_arms_on_network_verbs() {
        assert!(FlakyState::from_plan(&FaultPlan::default()).is_none());
        let compute_only = FaultPlan::parse("kill-after-micro=2;stall-ms=10@1").unwrap();
        assert!(FlakyState::from_plan(&compute_only).is_none());
        let mixed = FaultPlan::parse("kill-after-micro=9;corrupt-frame=3").unwrap();
        assert!(FlakyState::from_plan(&mixed).is_some());
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("channel").unwrap(), TransportKind::Channel);
        match TransportKind::parse("TCP").unwrap() {
            TransportKind::Tcp { listen, spawn } => {
                assert_eq!(listen, "127.0.0.1:0");
                assert_eq!(spawn, SpawnMode::Processes);
            }
            other => panic!("expected tcp, got {other:?}"),
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::Channel.label(), "channel");
    }
}
