//! The transport seam: how gradient and control frames move between the
//! aggregator and its workers.
//!
//! The dist runtime's wire *format* ([`super::grads::GradCodec`], the
//! 28-byte-header masked-gradient messages) has been transport-agnostic
//! since PR 3 — but until this layer existed, the only way bytes moved
//! was an in-process mpsc channel hardcoded into the trainer. The
//! [`Transport`] trait makes the seam explicit: an opaque, ordered,
//! reliable duplex stream of *blobs* (byte frames). Two implementations:
//!
//! * [`ChannelTransport`] — the in-process path, one `mpsc` pair per
//!   direction. Zero-copy: `send_blob` moves the `Vec` straight to the
//!   peer.
//! * [`TcpTransport`] — length-prefixed frames over `std::net`
//!   loopback or a real network. The aggregator listens; K worker
//!   *processes* (or threads, or machines) connect.
//!
//! Because every implementation delivers the same blobs in the same
//! per-link order, and the [`super::allreduce::OrderedReducer`] fixes
//! the reduction order independently of arrival order, the training
//! numerics are **bitwise identical across transports** — pinned by
//! `tests/dist_tcp.rs` against the serial trainer for K ∈ {2, 4},
//! overlap on/off, f32/f16 wires.
//!
//! ## Buffer ownership
//!
//! `send_blob` consumes its buffer: the channel path delivers the `Vec`
//! itself to the peer, the TCP path writes the frame and returns the
//! buffer to the transport's [`BufPool`]. Either way the caller checks
//! out a fresh pooled buffer per message and the steady state allocates
//! nothing — the PR 4 zero-allocation encode property, now preserved
//! across a real socket.
//!
//! ## Framing (TCP)
//!
//! `[len: u32 LE][payload: len bytes]`. A zero-length frame is the
//! barrier token (see [`Transport::barrier`]); the control protocol
//! ([`super::proto`]) never produces one. A length prefix above
//! [`MAX_FRAME`] is rejected before any allocation, so a corrupt or
//! malicious prefix surfaces as a descriptive error instead of an OOM,
//! and a peer that closes mid-frame surfaces as a truncation error
//! instead of a hang.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::grads::BufPool;

/// Hard cap on one frame's payload size (256 MiB). Far above any real
/// message (a dense small-model gradient is a few MiB); its only job is
/// turning a corrupt length prefix into an error instead of a giant
/// allocation.
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// The send half of a transport link.
pub trait BlobTx: Send {
    /// Send one blob to the peer. Consumes the buffer: delivered as-is
    /// (channel) or written to the socket and recycled into the
    /// transport's pool (TCP). Fails when the peer is gone.
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()>;
}

/// The receive half of a transport link.
pub trait BlobRx: Send {
    /// Block until the peer's next blob arrives and return it. Fails —
    /// never hangs forever on a closed link — when the peer
    /// disconnects, with a description of what broke.
    fn recv_blob(&mut self) -> Result<Vec<u8>>;

    /// Wait up to `timeout` for the next blob. `Ok(None)` means the
    /// link stayed completely quiet — the liveness signal the control
    /// plane's failure detector is built on. A peer that *starts* a
    /// frame and then goes silent for a full window is an error (it is
    /// holding the link mid-message, not merely idle), as is a
    /// disconnect. The default implementation ignores the timeout and
    /// blocks; real transports override it.
    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        let _ = timeout;
        self.recv_blob().map(Some)
    }
}

/// The liveness deadline for a worker link, derived from the heartbeat
/// interval instead of a fixed load-independent constant: a link is
/// declared dead only after `misses` full heartbeat intervals pass with
/// no traffic at all. A slow-but-alive worker keeps pinging while it
/// computes (or stalls), so it is *reassigned*, never evicted.
pub fn liveness_window(heartbeat_ms: u64, misses: u32) -> Duration {
    Duration::from_millis(heartbeat_ms.max(1).saturating_mul(misses.max(1) as u64))
}

/// One reliable, ordered, duplex blob link between two cluster nodes.
///
/// The contract the dist runtime builds on: blobs arrive exactly once,
/// uncorrupted, in send order (per link — nothing is implied across
/// links), and a dead peer turns into an error on both halves. That is
/// all the determinism argument needs: *which* bytes flow and how they
/// reduce is fixed above this seam.
pub trait Transport: BlobTx + BlobRx {
    /// Synchronization point: both endpoints must call `barrier` at the
    /// same protocol position; each sends an empty frame and waits for
    /// the peer's. Used at handshake time (replica built, ready for
    /// jobs) where the link is quiescent.
    fn barrier(&mut self) -> Result<()> {
        self.send_blob(Vec::new())?;
        let token = self.recv_blob()?;
        anyhow::ensure!(
            token.is_empty(),
            "barrier crossed a {}-byte data frame (protocol desync)",
            token.len()
        );
        Ok(())
    }

    /// Split into independently-owned halves so uplink and downlink can
    /// live on different threads (the aggregator's reader thread, the
    /// worker's pipelined sender thread).
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>);

    /// Display label (`channel` / `tcp`).
    fn label(&self) -> &'static str;

    /// Snapshot of the bytes this link actually moved.
    fn stats(&self) -> TransportStats;
}

/// Shared live counters of one link's traffic (both halves increment
/// the same cell after a split).
#[derive(Debug, Default)]
pub struct StatsCell {
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_recv: AtomicU64,
}

impl StatsCell {
    fn record_sent(&self, bytes: usize) {
        self.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn record_recv(&self, bytes: usize) {
        self.frames_recv.fetch_add(1, Ordering::Relaxed);
        self.bytes_recv.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Current totals.
    pub fn snapshot(&self) -> TransportStats {
        TransportStats {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
        }
    }
}

/// Measured transport-layer traffic: whole frames including the TCP
/// length prefixes — the bytes that actually cross the socket, reported
/// next to the modeled bytes in `benches/dist_step.rs`.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Frames sent.
    pub frames_sent: u64,
    /// Frames received.
    pub frames_recv: u64,
    /// Bytes sent (payload + framing overhead).
    pub bytes_sent: u64,
    /// Bytes received (payload + framing overhead).
    pub bytes_recv: u64,
}

impl TransportStats {
    /// Fold another link's totals into this one.
    pub fn merge(&mut self, other: &TransportStats) {
        self.frames_sent += other.frames_sent;
        self.frames_recv += other.frames_recv;
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
    }

    /// Total bytes moved in both directions.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent + self.bytes_recv
    }
}

/// Which transport a distributed run exchanges its frames over.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels; workers are threads of this process
    /// (the PR 3/4 path, refactored behind the seam).
    Channel,
    /// Length-prefixed frames over TCP: the aggregator listens on
    /// `listen`, workers connect per `spawn`.
    Tcp {
        /// Address the aggregator binds (`host:port`; port 0 picks an
        /// ephemeral one).
        listen: String,
        /// How the K workers come to exist.
        spawn: SpawnMode,
    },
}

/// How TCP workers are launched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpawnMode {
    /// In-process threads that connect over real loopback sockets —
    /// every socket path exercised, no subprocess needed (tests,
    /// benches, examples).
    Threads,
    /// Fork `repro dist-worker --connect <addr>` subprocesses from the
    /// current executable — genuinely separate address spaces.
    Processes,
    /// Wait for externally launched workers (`repro dist-worker
    /// --connect host:port`, possibly from other machines).
    External,
}

impl TransportKind {
    /// Parse a CLI label (`channel` | `tcp`) with the default TCP
    /// launch shape (loopback ephemeral port, forked subprocesses).
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "channel" | "mpsc" => TransportKind::Channel,
            "tcp" => TransportKind::Tcp {
                listen: "127.0.0.1:0".to_string(),
                spawn: SpawnMode::Processes,
            },
            _ => anyhow::bail!("unknown transport {s:?} (channel|tcp)"),
        })
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp { .. } => "tcp",
        }
    }
}

// ---------------------------------------------------------------------------
// Channel transport (in-process)
// ---------------------------------------------------------------------------

/// In-process transport: one mpsc channel per direction. `send_blob`
/// moves the buffer to the peer without copying; recycling happens at
/// the consumer's pool (shared process-wide in channel mode, so the
/// loop still closes).
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    stats: Arc<StatsCell>,
}

/// Build a connected pair of in-process endpoints.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (atx, brx) = mpsc::channel();
    let (btx, arx) = mpsc::channel();
    let a = ChannelTransport { tx: atx, rx: arx, stats: Arc::default() };
    let b = ChannelTransport { tx: btx, rx: brx, stats: Arc::default() };
    (a, b)
}

impl ChannelTransport {
    /// The live traffic counters of this endpoint (clone before
    /// splitting or boxing — both halves keep incrementing it).
    pub fn stats_cell(&self) -> Arc<StatsCell> {
        Arc::clone(&self.stats)
    }
}

struct ChannelTx {
    tx: mpsc::Sender<Vec<u8>>,
    stats: Arc<StatsCell>,
}

struct ChannelRx {
    rx: mpsc::Receiver<Vec<u8>>,
    stats: Arc<StatsCell>,
}

fn channel_send(tx: &mpsc::Sender<Vec<u8>>, stats: &StatsCell, blob: Vec<u8>) -> Result<()> {
    stats.record_sent(blob.len());
    tx.send(blob)
        .map_err(|_| anyhow::anyhow!("channel transport: peer receiver hung up"))
}

fn channel_recv(rx: &mpsc::Receiver<Vec<u8>>, stats: &StatsCell) -> Result<Vec<u8>> {
    let blob = rx
        .recv()
        .map_err(|_| anyhow::anyhow!("channel transport: peer sender hung up"))?;
    stats.record_recv(blob.len());
    Ok(blob)
}

fn channel_recv_timeout(
    rx: &mpsc::Receiver<Vec<u8>>,
    stats: &StatsCell,
    timeout: Duration,
) -> Result<Option<Vec<u8>>> {
    match rx.recv_timeout(timeout) {
        Ok(blob) => {
            stats.record_recv(blob.len());
            Ok(Some(blob))
        }
        Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err(anyhow::anyhow!("channel transport: peer sender hung up"))
        }
    }
}

impl BlobTx for ChannelTransport {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob)
    }
}

impl BlobRx for ChannelTransport {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        channel_recv(&self.rx, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        channel_recv_timeout(&self.rx, &self.stats, timeout)
    }
}

impl Transport for ChannelTransport {
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>) {
        let ChannelTransport { tx, rx, stats } = *self;
        (
            Box::new(ChannelTx { tx, stats: Arc::clone(&stats) }),
            Box::new(ChannelRx { rx, stats }),
        )
    }

    fn label(&self) -> &'static str {
        "channel"
    }

    fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }
}

impl BlobTx for ChannelTx {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        channel_send(&self.tx, &self.stats, blob)
    }
}

impl BlobRx for ChannelRx {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        channel_recv(&self.rx, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        channel_recv_timeout(&self.rx, &self.stats, timeout)
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Length-prefixed frames over one `TcpStream`. Frame buffers come
/// from / return to the endpoint's [`BufPool`], so the steady-state
/// send *and* receive paths are allocation-free.
pub struct TcpTransport {
    reader: TcpStream,
    writer: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
}

impl TcpTransport {
    /// Wrap an accepted/connected stream. Disables Nagle (the step
    /// loop is latency-sensitive and every frame is a complete
    /// message).
    pub fn from_stream(stream: TcpStream, pool: Arc<BufPool>) -> Result<TcpTransport> {
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        let reader = stream.try_clone().context("cloning TCP stream")?;
        Ok(TcpTransport { reader, writer: stream, pool, stats: Arc::default() })
    }

    /// Connect to an aggregator, retrying until `timeout` — workers are
    /// routinely launched before the aggregator's listener is up
    /// (the two-terminal flow), and a retry loop beats asking every
    /// operator to sequence their shells.
    pub fn connect(addr: &str, timeout: Duration, pool: Arc<BufPool>) -> Result<TcpTransport> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => return TcpTransport::from_stream(stream, pool),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e)
                            .with_context(|| format!("connecting to aggregator at {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// The live traffic counters of this endpoint (clone before
    /// splitting or boxing).
    pub fn stats_cell(&self) -> Arc<StatsCell> {
        Arc::clone(&self.stats)
    }
}

fn tcp_send(
    writer: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
    blob: Vec<u8>,
) -> Result<()> {
    anyhow::ensure!(
        blob.len() <= MAX_FRAME,
        "refusing to send a {}-byte frame (cap {MAX_FRAME})",
        blob.len()
    );
    let len = (blob.len() as u32).to_le_bytes();
    writer.write_all(&len).context("writing frame length prefix")?;
    writer.write_all(&blob).context("writing frame body")?;
    stats.record_sent(4 + blob.len());
    pool.give_back(blob);
    Ok(())
}

fn tcp_recv(reader: &mut TcpStream, pool: &BufPool, stats: &StatsCell) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    reader
        .read_exact(&mut hdr)
        .context("reading frame length prefix (peer disconnected?)")?;
    let len = u32::from_le_bytes(hdr) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length prefix {len} exceeds the {MAX_FRAME}-byte cap \
         (corrupt prefix or protocol desync)"
    );
    let mut buf = pool.checkout();
    buf.resize(len, 0);
    reader
        .read_exact(&mut buf)
        .with_context(|| format!("reading {len}-byte frame body (peer closed mid-frame?)"))?;
    stats.record_recv(4 + len);
    Ok(buf)
}

fn io_timed_out(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Timed receive over a TCP stream. Arms `SO_RCVTIMEO` for the read,
/// restores fully blocking mode on every return path, and tracks
/// *progress*: a window that passes with zero new bytes is a quiet
/// timeout (`Ok(None)`) only if no frame was started; once the peer has
/// sent a partial frame, the same silence is a "stalled mid-frame"
/// error, because the link is wedged, not idle.
fn tcp_recv_timeout(
    reader: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
    timeout: Duration,
) -> Result<Option<Vec<u8>>> {
    reader
        .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
        .context("arming read timeout")?;
    let result = tcp_recv_timeout_inner(reader, pool, stats);
    let restore = reader.set_read_timeout(None);
    let out = result?;
    restore.context("restoring blocking reads after a timed receive")?;
    Ok(out)
}

fn tcp_recv_timeout_inner(
    reader: &mut TcpStream,
    pool: &BufPool,
    stats: &StatsCell,
) -> Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match reader.read(&mut hdr[got..]) {
            Ok(0) => anyhow::bail!("reading frame length prefix (peer disconnected?)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if io_timed_out(&e) => {
                if got == 0 {
                    return Ok(None);
                }
                anyhow::bail!(
                    "peer stalled mid-frame: {got} of 4 length-prefix bytes, then silence"
                );
            }
            Err(e) => return Err(e).context("reading frame length prefix"),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame length prefix {len} exceeds the {MAX_FRAME}-byte cap \
         (corrupt prefix or protocol desync)"
    );
    let mut buf = pool.checkout();
    buf.resize(len, 0);
    let mut got = 0usize;
    while got < len {
        match reader.read(&mut buf[got..]) {
            Ok(0) => anyhow::bail!("reading {len}-byte frame body (peer closed mid-frame?)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if io_timed_out(&e) => {
                anyhow::bail!("peer stalled mid-frame: {got} of {len} body bytes, then silence")
            }
            Err(e) => return Err(e).context("reading frame body"),
        }
    }
    stats.record_recv(4 + len);
    Ok(Some(buf))
}

struct TcpTx {
    writer: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
}

struct TcpRx {
    reader: TcpStream,
    pool: Arc<BufPool>,
    stats: Arc<StatsCell>,
}

impl BlobTx for TcpTransport {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob)
    }
}

impl BlobRx for TcpTransport {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.reader, &self.pool, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        tcp_recv_timeout(&mut self.reader, &self.pool, &self.stats, timeout)
    }
}

impl Transport for TcpTransport {
    fn split(self: Box<Self>) -> (Box<dyn BlobTx>, Box<dyn BlobRx>) {
        let TcpTransport { reader, writer, pool, stats } = *self;
        (
            Box::new(TcpTx { writer, pool: Arc::clone(&pool), stats: Arc::clone(&stats) }),
            Box::new(TcpRx { reader, pool, stats }),
        )
    }

    fn label(&self) -> &'static str {
        "tcp"
    }

    fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }
}

impl BlobTx for TcpTx {
    fn send_blob(&mut self, blob: Vec<u8>) -> Result<()> {
        tcp_send(&mut self.writer, &self.pool, &self.stats, blob)
    }
}

impl BlobRx for TcpRx {
    fn recv_blob(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.reader, &self.pool, &self.stats)
    }

    fn recv_blob_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        tcp_recv_timeout(&mut self.reader, &self.pool, &self.stats, timeout)
    }
}

// ---------------------------------------------------------------------------
// Listener helpers (aggregator side)
// ---------------------------------------------------------------------------

/// Bind the aggregator's listener and report the resolved address
/// (resolves port 0 to the ephemeral port workers must dial).
pub fn listen(addr: &str) -> Result<(TcpListener, SocketAddr)> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding dist listener on {addr}"))?;
    let local = listener.local_addr().context("resolving listener address")?;
    Ok((listener, local))
}

/// Accept exactly `n` worker connections, failing (instead of hanging
/// CI or a terminal forever) if they have not all arrived by
/// `timeout`. Accepted streams are returned in connection order, which
/// becomes the worker-id order.
pub fn accept_workers(
    listener: &TcpListener,
    n: usize,
    timeout: Duration,
) -> Result<Vec<TcpStream>> {
    listener.set_nonblocking(true).context("making listener non-blocking")?;
    let deadline = Instant::now() + timeout;
    let mut streams = Vec::with_capacity(n);
    while streams.len() < n {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The stream inherits non-blocking from the listener on
                // some platforms; frame IO requires blocking reads.
                stream.set_nonblocking(false).context("making worker stream blocking")?;
                streams.push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for dist workers: {} of {n} connected \
                     within {timeout:?} (launch the rest with `repro dist-worker \
                     --connect <addr>`)",
                    streams.len()
                );
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e).context("accepting worker connection"),
        }
    }
    Ok(streams)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Arc<BufPool> {
        Arc::new(BufPool::new())
    }

    #[test]
    fn channel_round_trip_and_stats() {
        let (mut a, mut b) = channel_pair();
        a.send_blob(vec![1, 2, 3]).unwrap();
        a.send_blob(vec![4]).unwrap();
        assert_eq!(b.recv_blob().unwrap(), vec![1, 2, 3]);
        assert_eq!(b.recv_blob().unwrap(), vec![4]);
        b.send_blob(vec![9; 10]).unwrap();
        assert_eq!(a.recv_blob().unwrap(), vec![9; 10]);
        let sa = a.stats();
        let sb = b.stats();
        assert_eq!(sa.frames_sent, 2);
        assert_eq!(sa.bytes_sent, 4);
        assert_eq!(sa.frames_recv, 1);
        assert_eq!(sa.bytes_recv, 10);
        assert_eq!(sb.bytes_recv, 4);
        // Dead peer surfaces as an error, not a hang.
        drop(b);
        assert!(a.send_blob(vec![0]).is_err());
        assert!(a.recv_blob().is_err());
    }

    #[test]
    fn channel_barrier_and_split() {
        let (a, b) = channel_pair();
        let (mut a, mut b) = (Box::new(a) as Box<dyn Transport>, Box::new(b));
        let h = std::thread::spawn(move || {
            b.barrier().unwrap();
            b.send_blob(vec![7]).unwrap();
            b
        });
        a.barrier().unwrap();
        let b = h.join().unwrap();
        let (_btx, _brx) = (b as Box<dyn Transport>).split();
        let (_atx, mut arx) = a.split();
        assert_eq!(arx.recv_blob().unwrap(), vec![7]);
    }

    #[test]
    fn tcp_round_trip_recycles_buffers() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let pa = pool();
        let pb = pool();
        let pb2 = Arc::clone(&pb);
        let h = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut t = TcpTransport::from_stream(stream, pb2).unwrap();
            for i in 0..4u8 {
                let mut buf = t.recv_blob().unwrap();
                assert_eq!(buf, vec![i; 3 + i as usize]);
                // Echo back through the pool.
                buf.push(0xEE);
                t.send_blob(buf).unwrap();
            }
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, Arc::clone(&pa)).unwrap();
        for i in 0..4u8 {
            let mut buf = pa.checkout();
            buf.resize(3 + i as usize, i);
            t.send_blob(buf).unwrap();
            let echoed = t.recv_blob().unwrap();
            assert_eq!(*echoed.last().unwrap(), 0xEE);
            pa.give_back(echoed);
        }
        h.join().unwrap();
        // Steady state: buffers recycled after warmup on both paths
        // (sent buffers return on send, received ones on give_back).
        assert!(pa.reuses() > 0, "sender-side pool must recycle");
        let s = t.stats();
        assert_eq!(s.frames_sent, 4);
        assert_eq!(s.frames_recv, 4);
        // Framing overhead is counted: 4-byte prefix per frame.
        assert_eq!(s.bytes_sent, 4 * 4 + (3 + 4 + 5 + 6));
    }

    #[test]
    fn tcp_barrier_round_trip() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            t.barrier().unwrap();
            t.send_blob(b"after".to_vec()).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        t.barrier().unwrap();
        assert_eq!(t.recv_blob().unwrap(), b"after".to_vec());
        h.join().unwrap();
    }

    #[test]
    fn tcp_rejects_oversized_length_prefix() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // A malicious/corrupt prefix claiming ~4 GiB.
            raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
            raw
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = t.recv_blob().unwrap_err().to_string();
        assert!(err.contains("exceeds"), "descriptive cap error, got: {err}");
        drop(h.join().unwrap());
    }

    #[test]
    fn tcp_truncated_frame_is_an_error_not_a_hang() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // Claim 100 bytes, deliver 10, vanish.
            raw.write_all(&100u32.to_le_bytes()).unwrap();
            raw.write_all(&[0xAB; 10]).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = format!("{:#}", t.recv_blob().unwrap_err());
        assert!(
            err.contains("frame body"),
            "truncation must name the frame body read, got: {err}"
        );
        h.join().unwrap();
    }

    #[test]
    fn accept_workers_times_out_cleanly() {
        let (listener, _addr) = listen("127.0.0.1:0").unwrap();
        let err = accept_workers(&listener, 2, Duration::from_millis(80)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "got: {err}");
    }

    #[test]
    fn liveness_window_tracks_heartbeat_interval() {
        // The deadline scales with the configured heartbeat, not a
        // fixed constant: 4 missed 100ms beats = 400ms.
        assert_eq!(liveness_window(100, 4), Duration::from_millis(400));
        assert_eq!(liveness_window(250, 2), Duration::from_millis(500));
        // Boundary: zero heartbeat / zero misses degrade to a minimal
        // but non-zero window instead of an instant eviction.
        assert_eq!(liveness_window(0, 0), Duration::from_millis(1));
        // Monotone in both knobs.
        assert!(liveness_window(200, 4) > liveness_window(100, 4));
        assert!(liveness_window(100, 8) > liveness_window(100, 4));
    }

    #[test]
    fn channel_timed_recv_distinguishes_quiet_from_dead() {
        let (mut a, mut b) = channel_pair();
        // Quiet peer: timeout, not an error.
        assert!(a.recv_blob_timeout(Duration::from_millis(30)).unwrap().is_none());
        // Delivery within the window.
        b.send_blob(vec![5, 6]).unwrap();
        assert_eq!(
            a.recv_blob_timeout(Duration::from_secs(5)).unwrap().unwrap(),
            vec![5, 6]
        );
        // Dead peer: an error, not a quiet timeout.
        drop(b);
        assert!(a.recv_blob_timeout(Duration::from_millis(30)).is_err());
    }

    #[test]
    fn tcp_timed_recv_quiet_then_delivers_then_blocks_again() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(
                &addr.to_string(),
                Duration::from_secs(10),
                pool(),
            )
            .unwrap();
            // Stay quiet long enough for one timed window to expire.
            std::thread::sleep(Duration::from_millis(150));
            t.send_blob(b"late".to_vec()).unwrap();
            t.send_blob(b"after".to_vec()).unwrap();
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        // Window 1: nothing on the wire yet.
        assert!(t.recv_blob_timeout(Duration::from_millis(40)).unwrap().is_none());
        // Patience: the frame eventually lands inside a window.
        let mut got = None;
        for _ in 0..200 {
            if let Some(b) = t.recv_blob_timeout(Duration::from_millis(50)).unwrap() {
                got = Some(b);
                break;
            }
        }
        assert_eq!(got.unwrap(), b"late".to_vec());
        // Blocking mode was restored: a plain recv still works.
        assert_eq!(t.recv_blob().unwrap(), b"after".to_vec());
        h.join().unwrap();
    }

    #[test]
    fn tcp_partial_frame_then_silence_is_a_stall_error() {
        let (listener, addr) = listen("127.0.0.1:0").unwrap();
        let h = std::thread::spawn(move || {
            let mut raw = TcpStream::connect(addr).unwrap();
            // Start a frame (2 of 4 prefix bytes), then go silent —
            // the link is wedged mid-message, not idle.
            raw.write_all(&[9, 0]).unwrap();
            std::thread::sleep(Duration::from_millis(400));
            raw
        });
        let stream = accept_workers(&listener, 1, Duration::from_secs(10))
            .unwrap()
            .pop()
            .unwrap();
        let mut t = TcpTransport::from_stream(stream, pool()).unwrap();
        let err = loop {
            // The first windows may be fully quiet depending on thread
            // scheduling; once the partial prefix lands, silence inside
            // a window must surface as a stall.
            match t.recv_blob_timeout(Duration::from_millis(60)) {
                Ok(Some(b)) => panic!("no full frame was ever sent, got {b:?}"),
                Ok(None) => continue,
                Err(e) => break format!("{e:#}"),
            }
        };
        assert!(err.contains("stalled mid-frame"), "got: {err}");
        drop(h.join().unwrap());
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("channel").unwrap(), TransportKind::Channel);
        match TransportKind::parse("TCP").unwrap() {
            TransportKind::Tcp { listen, spawn } => {
                assert_eq!(listen, "127.0.0.1:0");
                assert_eq!(spawn, SpawnMode::Processes);
            }
            other => panic!("expected tcp, got {other:?}"),
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::Channel.label(), "channel");
    }
}
