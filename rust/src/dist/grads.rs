//! Masked-gradient wire format: serialize exactly the parameter slices
//! a schedule leaves trainable, nothing else.
//!
//! ## Why no index structure
//!
//! D2FT's schedule is computed centrally and known to every node before
//! the batch runs, so sender and receiver can both derive the payload
//! layout from `(model structure, MaskPair)`. A message is therefore a
//! 28-byte header (magic, precision flags, micro, mask fingerprint,
//! element count) plus raw little-endian payload elements in canonical
//! order — f32 by default, IEEE binary16 under [`WirePrecision::F16`]
//! — the densest encoding the mask admits, which makes the byte
//! accounting an honest measurement of the paper's communication claim
//! rather than a property of a clever container format. The mask
//! fingerprint catches sender/receiver schedule divergence; the flags
//! catch a precision mismatch.
//!
//! ## What ships
//!
//! Per parameter tensor (canonical sorted-name order):
//!
//! * non-trainable tensors (LoRA-frozen base weights) — never ship;
//! * *shared* elements (embeddings, layer norms, classifier — owned by
//!   no head) — always ship;
//! * elements owned by subnet (block `l`, head `h`) — ship iff the
//!   backward mask is 1 for that head (`p_f`). `p_o` and `p_s` heads
//!   ship nothing: the backend's freeze contract guarantees those
//!   gradient slices are exactly zero, so dropping them is lossless —
//!   [`GradCodec::decode_add`] of an encoded message reconstructs the
//!   dense gradient bit-for-bit (`tests/dist.rs` pins this property).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

/// Message magic: "D2FG" (masked gradient payload).
const MAGIC_GRAD: u32 = 0x4432_4647;
/// Message magic: "D2FD" (dense delta payload, parameter-server mode).
const MAGIC_DELTA: u32 = 0x4432_4644;
/// Header: magic u32, flags u32 (wire precision), micro u32, mask
/// fingerprint u64, payload elems u64.
const HEADER_BYTES: usize = 28;
/// Header flags bit 0: payload elements are IEEE binary16 (2 bytes)
/// instead of the default f32.
const FLAG_F16: u32 = 1;

/// Element precision of gradient payloads on the wire.
///
/// `F32` (the default) is lossless by the freeze contract — the bitwise
/// serial ≡ distributed guarantee holds. `F16` halves every payload
/// byte ([`WireStats`] measures it on the actual messages) at binary16
/// precision (~3 decimal digits); the aggregator then applies the
/// *requantized* reduced gradient so every replica — aggregator
/// included — still sees identical bits, but the trajectory is no
/// longer bit-equal to the serial trainer's.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePrecision {
    /// 4-byte little-endian f32 payload elements (lossless; default).
    #[default]
    F32,
    /// 2-byte IEEE binary16 payload elements (half the bytes, lossy).
    F16,
}

impl WirePrecision {
    /// Parse a CLI label (`f32` | `f16`).
    pub fn parse(s: &str) -> Result<WirePrecision> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => WirePrecision::F32,
            "f16" | "fp16" | "half" => WirePrecision::F16,
            _ => anyhow::bail!("unknown wire precision {s:?} (f32|f16)"),
        })
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::F16 => "f16",
        }
    }

    /// Bytes per payload element.
    fn elem_bytes(self) -> usize {
        match self {
            WirePrecision::F32 => 4,
            WirePrecision::F16 => 2,
        }
    }

    /// Header flag bits for this precision.
    fn flags(self) -> u32 {
        match self {
            WirePrecision::F32 => 0,
            WirePrecision::F16 => FLAG_F16,
        }
    }
}

/// f32 -> IEEE binary16 bits with round-to-nearest-even (overflow to
/// ±inf, underflow through subnormals to ±0; NaN payload preserved as a
/// quiet NaN).
fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let absx = b & 0x7FFF_FFFF;
    if absx >= 0x7F80_0000 {
        // Inf / NaN.
        return sign | 0x7C00 | if absx > 0x7F80_0000 { 0x0200 } else { 0 };
    }
    let exp = (absx >> 23) as i32 - 127;
    if exp > 15 {
        return sign | 0x7C00; // overflow -> ±inf
    }
    if exp < -25 {
        return sign; // below half the smallest subnormal -> ±0
    }
    let mant = (absx & 0x007F_FFFF) | 0x0080_0000; // 24-bit significand
    // Normals drop 13 mantissa bits; subnormals drop more as the
    // exponent sinks below -14.
    let shift: u32 = if exp >= -14 { 13 } else { (13 - 14 - exp) as u32 };
    let base = mant >> shift;
    let rem = mant & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let rounded = if rem > half || (rem == half && base & 1 == 1) { base + 1 } else { base };
    let h = if exp >= -14 {
        // `rounded` carries the implicit bit at 1 << 10; a round-up to
        // 1 << 11 correctly bumps the exponent (and 30 -> 31 is inf).
        ((((exp + 15) as u32) << 10) + (rounded - (1 << 10))) as u16
    } else {
        // Subnormal: no implicit bit; a carry to 1 << 10 lands exactly
        // on the smallest normal.
        rounded as u16
    };
    sign | h
}

/// IEEE binary16 bits -> f32 (exact; every f16 value is representable).
fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 31 {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize into an f32 exponent.
            let mut e = 113u32; // biased exponent of 2^-14
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Append one payload value at the codec's wire precision.
#[inline]
fn write_vals(out: &mut Vec<u8>, vals: &[f32], prec: WirePrecision) {
    match prec {
        WirePrecision::F32 => {
            for &v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        WirePrecision::F16 => {
            for &v in vals {
                out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
    }
}

/// Decode payload values starting at `off`, adding into `dst`; returns
/// the advanced offset.
#[inline]
fn add_vals(dst: &mut [f32], bytes: &[u8], mut off: usize, prec: WirePrecision) -> usize {
    match prec {
        WirePrecision::F32 => {
            for x in dst {
                *x += f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                off += 4;
            }
        }
        WirePrecision::F16 => {
            for x in dst {
                let h = u16::from_le_bytes(bytes[off..off + 2].try_into().unwrap());
                *x += f16_bits_to_f32(h);
                off += 2;
            }
        }
    }
    off
}

/// Owner tag for elements belonging to no head.
const SHARED: u32 = u32::MAX;

/// A contiguous `[lo, hi)` element range within one parameter tensor.
type Range = (usize, usize);

#[derive(Clone, Debug)]
struct ParamLayout {
    /// False for LoRA-frozen base weights — never on the wire.
    trainable: bool,
    /// Total element count of the tensor.
    len: usize,
    /// Maximal runs owned by no head (ship whenever trainable).
    shared: Vec<Range>,
    /// Maximal runs owned by subnet `l * heads + h`.
    per_head: Vec<Vec<Range>>,
}

/// Encoder/decoder for masked gradient messages, specialized to one
/// model instance. Construction walks the backend's per-head parameter
/// ownership map once; encode/decode are then pure range copies.
#[derive(Clone, Debug)]
pub struct GradCodec {
    depth: usize,
    heads: usize,
    params: Vec<ParamLayout>,
    /// Total trainable elements (the dense message payload).
    dense_elems: usize,
    /// Payload element precision on the wire (f32 default).
    precision: WirePrecision,
}

impl GradCodec {
    /// Build the codec for `be`'s exact parameter layout (LoRA rank,
    /// depth, heads). Replicas built from the same spec share a layout,
    /// so one codec serves a whole cluster.
    pub fn new(be: &NativeBackend) -> GradCodec {
        let cfg = be.config();
        let (depth, heads) = (cfg.depth, cfg.heads);
        let n = be.n_param_tensors();
        let mut owner: Vec<Vec<u32>> =
            (0..n).map(|i| vec![SHARED; be.param_elems(i)]).collect();
        for l in 0..depth {
            for h in 0..heads {
                let tag = (l * heads + h) as u32;
                be.visit_head_elems(l, h, &mut |pi, ei| {
                    debug_assert_eq!(owner[pi][ei], SHARED, "element owned twice");
                    owner[pi][ei] = tag;
                });
            }
        }
        let trainable = be.trainable_flags();
        let mut params = Vec::with_capacity(n);
        let mut dense_elems = 0usize;
        for (pi, own) in owner.iter().enumerate() {
            let mut shared = Vec::new();
            let mut per_head: Vec<Vec<Range>> = vec![Vec::new(); depth * heads];
            let mut i = 0;
            while i < own.len() {
                let tag = own[i];
                let mut j = i + 1;
                while j < own.len() && own[j] == tag {
                    j += 1;
                }
                if tag == SHARED {
                    shared.push((i, j));
                } else {
                    per_head[tag as usize].push((i, j));
                }
                i = j;
            }
            if trainable[pi] {
                dense_elems += own.len();
            }
            params.push(ParamLayout {
                trainable: trainable[pi],
                len: own.len(),
                shared,
                per_head,
            });
        }
        GradCodec { depth, heads, params, dense_elems, precision: WirePrecision::F32 }
    }

    /// Same layout, different wire precision (builder style). All
    /// cluster nodes must agree — the header flags catch a mismatch at
    /// decode time.
    pub fn with_precision(mut self, precision: WirePrecision) -> GradCodec {
        self.precision = precision;
        self
    }

    /// The payload element precision this codec reads and writes.
    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Which subnets ship under `masks`: a head's slices travel iff its
    /// backward mask is 1 (only `p_f` produces nonzero gradients there).
    fn active(&self, masks: &MaskPair) -> Vec<bool> {
        assert_eq!(
            masks.bwd.shape(),
            &[self.depth, self.heads],
            "mask shape vs codec model"
        );
        let mut v = vec![false; self.depth * self.heads];
        for l in 0..self.depth {
            for h in 0..self.heads {
                v[l * self.heads + h] = masks.bwd.at(&[l, h]) >= 0.5;
            }
        }
        v
    }

    /// Payload element count for a precomputed activity vector.
    fn payload_elems_with(&self, act: &[bool]) -> usize {
        let mut n = 0usize;
        for p in &self.params {
            if !p.trainable {
                continue;
            }
            n += p.shared.iter().map(|r| r.1 - r.0).sum::<usize>();
            for (t, ranges) in p.per_head.iter().enumerate() {
                if act[t] {
                    n += ranges.iter().map(|r| r.1 - r.0).sum::<usize>();
                }
            }
        }
        n
    }

    /// Payload element count of one message under `masks`.
    pub fn payload_elems(&self, masks: &MaskPair) -> usize {
        self.payload_elems_with(&self.active(masks))
    }

    /// Encoded byte size of one message under `masks`.
    pub fn encoded_len(&self, masks: &MaskPair) -> usize {
        HEADER_BYTES + self.precision.elem_bytes() * self.payload_elems(masks)
    }

    /// Byte size of a dense (every head active) message — what one
    /// micro-batch of the full, unmasked schedule ships.
    pub fn dense_len(&self) -> usize {
        HEADER_BYTES + self.precision.elem_bytes() * self.dense_elems
    }

    /// Serialize the gradient slices `masks` leaves trainable. `grads`
    /// must be the backend's dense gradients in canonical order (one
    /// tensor per parameter). Allocates a fresh buffer; the hot loop
    /// uses [`GradCodec::encode_into`] with a recycled one.
    pub fn encode(&self, micro: usize, masks: &MaskPair, grads: &[Tensor]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(micro, masks, grads, &mut out);
        out
    }

    /// [`GradCodec::encode`] into a caller-provided scratch buffer: the
    /// buffer is cleared and refilled, so a recycled buffer (see
    /// [`BufPool`]) makes the steady-state encode path allocation-free
    /// once its capacity has grown to the largest message.
    pub fn encode_into(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        out: &mut Vec<u8>,
    ) {
        out.clear();
        self.encode_append(micro, masks, grads, out);
    }

    /// [`GradCodec::encode_into`] without the clear: the message is
    /// appended after whatever `out` already holds. This is how a
    /// transport frame embeds a gradient message as its tail
    /// (`dist::proto`) with zero copies — the codec writes straight
    /// into the frame buffer after the frame's own header.
    pub fn encode_append(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        out: &mut Vec<u8>,
    ) {
        assert_eq!(grads.len(), self.params.len(), "grad tensor count");
        let base = out.len();
        // One layout walk serves capacity, header, and body.
        let act = self.active(masks);
        let n_elems = self.payload_elems_with(&act);
        out.reserve(HEADER_BYTES + self.precision.elem_bytes() * n_elems);
        out.extend_from_slice(&MAGIC_GRAD.to_le_bytes());
        out.extend_from_slice(&self.precision.flags().to_le_bytes());
        out.extend_from_slice(&(micro as u32).to_le_bytes());
        out.extend_from_slice(&masks.fingerprint().to_le_bytes());
        out.extend_from_slice(&(n_elems as u64).to_le_bytes());
        for (p, g) in self.params.iter().zip(grads) {
            if !p.trainable {
                continue;
            }
            debug_assert_eq!(g.len(), p.len, "grad shape vs layout");
            let gd = g.data();
            for &(lo, hi) in &p.shared {
                write_vals(out, &gd[lo..hi], self.precision);
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    write_vals(out, &gd[lo..hi], self.precision);
                }
            }
        }
        debug_assert_eq!(
            out.len() - base,
            HEADER_BYTES + self.precision.elem_bytes() * n_elems,
            "encoded length disagrees with the layout walk"
        );
    }

    /// Decode a message and **add** its payload into dense accumulators
    /// (canonical order, e.g. from
    /// [`NativeBackend::zeros_like_params`]). Elements the mask excluded
    /// are untouched — with a zeroed accumulator this reconstructs the
    /// sender's dense gradient exactly, because excluded slices were
    /// exactly zero. Returns the message's micro-batch index.
    pub fn decode_add(
        &self,
        bytes: &[u8],
        masks: &MaskPair,
        acc: &mut [Tensor],
    ) -> Result<usize> {
        anyhow::ensure!(acc.len() == self.params.len(), "accumulator count");
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let word = |lo: usize| -> [u8; 4] { bytes[lo..lo + 4].try_into().unwrap() };
        let magic = u32::from_le_bytes(word(0));
        anyhow::ensure!(magic == MAGIC_GRAD, "bad gradient-message magic {magic:#x}");
        let flags = u32::from_le_bytes(word(4));
        anyhow::ensure!(
            flags == self.precision.flags(),
            "wire precision mismatch: message flags {flags:#x}, codec is {}",
            self.precision.label()
        );
        let micro = u32::from_le_bytes(word(8)) as usize;
        let fp = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        anyhow::ensure!(
            fp == masks.fingerprint(),
            "mask fingerprint mismatch: sender and receiver disagree on the schedule"
        );
        let act = self.active(masks);
        let expect = self.payload_elems_with(&act);
        let n_elems = u64::from_le_bytes(bytes[20..28].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == expect,
            "payload {n_elems} elems, layout expects {expect}"
        );
        anyhow::ensure!(
            bytes.len() == HEADER_BYTES + self.precision.elem_bytes() * n_elems,
            "message length {} vs declared payload {}",
            bytes.len(),
            n_elems
        );
        let mut off = HEADER_BYTES;
        for (p, a) in self.params.iter().zip(acc.iter_mut()) {
            if !p.trainable {
                continue;
            }
            let ad = a.data_mut();
            for &(lo, hi) in &p.shared {
                off = add_vals(&mut ad[lo..hi], bytes, off, self.precision);
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    off = add_vals(&mut ad[lo..hi], bytes, off, self.precision);
                }
            }
        }
        Ok(micro)
    }

    /// Serialize dense per-parameter values for every trainable tensor —
    /// the parameter-server downlink (update deltas). `vals[i]` must
    /// have the parameter's full element count for trainable `i`
    /// (non-trainable entries are ignored).
    pub fn encode_dense(&self, vals: &[Tensor]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_dense_into(vals, &mut out);
        out
    }

    /// [`GradCodec::encode_dense`] into a caller-provided scratch buffer
    /// (cleared and refilled; reuse makes the steady state
    /// allocation-free).
    pub fn encode_dense_into(&self, vals: &[Tensor], out: &mut Vec<u8>) {
        out.clear();
        self.encode_dense_append(vals, out);
    }

    /// [`GradCodec::encode_dense_into`] without the clear (appended as
    /// a transport frame's tail, like [`GradCodec::encode_append`]).
    pub fn encode_dense_append(&self, vals: &[Tensor], out: &mut Vec<u8>) {
        assert_eq!(vals.len(), self.params.len(), "value tensor count");
        out.reserve(HEADER_BYTES + self.precision.elem_bytes() * self.dense_elems);
        out.extend_from_slice(&MAGIC_DELTA.to_le_bytes());
        out.extend_from_slice(&self.precision.flags().to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&(self.dense_elems as u64).to_le_bytes());
        for (p, v) in self.params.iter().zip(vals) {
            if !p.trainable {
                continue;
            }
            assert_eq!(v.len(), p.len, "dense payload size");
            write_vals(out, v.data(), self.precision);
        }
    }

    /// Decode a dense payload into per-parameter tensors (1-D; zero
    /// length for non-trainable entries, mirroring
    /// [`NativeBackend::update_capture`]).
    pub fn decode_dense(&self, bytes: &[u8]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC_DELTA, "bad delta-message magic {magic:#x}");
        let flags = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(
            flags == self.precision.flags(),
            "wire precision mismatch: message flags {flags:#x}, codec is {}",
            self.precision.label()
        );
        let n_elems = u64::from_le_bytes(bytes[20..28].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == self.dense_elems
                && bytes.len() == HEADER_BYTES + self.precision.elem_bytes() * n_elems,
            "dense payload size mismatch"
        );
        let mut off = HEADER_BYTES;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            if !p.trainable {
                out.push(Tensor::zeros(&[0]));
                continue;
            }
            let mut v = vec![0.0f32; p.len];
            off = add_vals(&mut v, bytes, off, self.precision);
            out.push(Tensor::from_vec(&[p.len], v));
        }
        Ok(out)
    }
}

/// A recycling pool of encode buffers: the dist hot loop checks a
/// buffer out, [`GradCodec::encode_into`] refills it in place, the
/// aggregator gives it back after the reduction consumed the bytes. In
/// steady state (after the first batch grew each buffer's capacity to
/// the largest message) the per-task encode path performs **zero heap
/// allocations** — [`BufPool::fresh_allocs`] stops moving, which
/// `dist::trainer` tests pin.
#[derive(Debug, Default)]
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    fresh: AtomicU64,
    reused: AtomicU64,
}

/// Cap on parked buffers: enough for every micro-batch of a batch to be
/// in flight at once plus slack; beyond this, returned buffers are
/// dropped rather than hoarded.
const BUF_POOL_CAP: usize = 64;

impl BufPool {
    /// Fresh, empty pool.
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// Take a cleared buffer — recycled when one is parked, freshly
    /// allocated otherwise.
    pub fn checkout(&self) -> Vec<u8> {
        if let Some(b) = self.free.lock().expect("buf pool lock").pop() {
            debug_assert!(b.is_empty(), "recycled buffer must come back cleared");
            debug_assert!(b.capacity() > 0, "recycled buffer lost its capacity");
            self.reused.fetch_add(1, Ordering::Relaxed);
            b
        } else {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        }
    }

    /// Return a buffer for reuse (cleared here; capacity kept). A
    /// buffer that never grew (e.g. a transport barrier token) is
    /// dropped instead of parked — recycling it buys nothing.
    pub fn give_back(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        b.clear();
        let mut free = self.free.lock().expect("buf pool lock");
        if free.len() < BUF_POOL_CAP {
            free.push(b);
        }
    }

    /// Buffers allocated fresh (steady state: stops growing).
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh.load(Ordering::Relaxed)
    }

    /// Checkouts served by recycling.
    pub fn reuses(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }
}

/// Running bytes-on-the-wire accounting for one distributed run.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Uplink gradient messages (worker -> aggregator).
    pub up_msgs: u64,
    /// Uplink bytes actually serialized.
    pub up_bytes: u64,
    /// What the same messages would have cost unmasked (dense).
    pub dense_up_bytes: u64,
    /// Downlink broadcasts (aggregator -> worker).
    pub down_msgs: u64,
    /// Downlink bytes actually serialized.
    pub down_bytes: u64,
}

impl WireStats {
    /// Record one uplink gradient message of `bytes` against a dense
    /// baseline of `dense` bytes.
    pub fn record_up(&mut self, bytes: usize, dense: usize) {
        self.up_msgs += 1;
        self.up_bytes += bytes as u64;
        self.dense_up_bytes += dense as u64;
    }

    /// Record one downlink broadcast message.
    pub fn record_down(&mut self, bytes: usize) {
        self.down_msgs += 1;
        self.down_bytes += bytes as u64;
    }

    /// Fraction of uplink gradient bytes saved vs the unmasked schedule
    /// (the paper's communication-reduction claim, measured).
    pub fn grad_savings(&self) -> f64 {
        if self.dense_up_bytes == 0 {
            return 0.0;
        }
        1.0 - self.up_bytes as f64 / self.dense_up_bytes as f64
    }

    /// Total bytes moved (uplink + downlink).
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, NativeSpec};
    use crate::data::{DatasetSpec, SyntheticKind};
    use crate::runtime::ModelConfig;

    fn spec() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xFEED,
            threads: 1,
        }
    }

    fn masks_with(bwd_off: &[(usize, usize)], fwd_off: &[(usize, usize)]) -> MaskPair {
        let mut m = MaskPair::ones(2, 2);
        for &(l, h) in bwd_off {
            m.bwd.set(&[l, h], 0.0);
        }
        for &(l, h) in fwd_off {
            m.fwd.set(&[l, h], 0.0);
            m.bwd.set(&[l, h], 0.0);
        }
        m
    }

    #[test]
    fn masked_message_is_smaller_and_lossless() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        // One p_o head and one p_s head -> two heads' slices off-wire.
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let msg = codec.encode(3, &masks, &grads);
        assert_eq!(msg.len(), codec.encoded_len(&masks));
        assert!(codec.encoded_len(&masks) < codec.dense_len(), "mask must shrink the wire");
        // Decode into zeros reconstructs the dense gradient bit-for-bit.
        let mut acc = be.zeros_like_params();
        let micro = codec.decode_add(&msg, &masks, &mut acc).unwrap();
        assert_eq!(micro, 3);
        for (i, (a, g)) in acc.iter().zip(&grads).enumerate() {
            assert_eq!(a.data(), g.data(), "param {i} reconstruction");
        }
        // Fingerprint mismatch is rejected.
        let other = MaskPair::ones(2, 2);
        assert!(codec.decode_add(&msg, &other, &mut acc).is_err());
    }

    #[test]
    fn dense_and_all_ones_agree() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let ones = MaskPair::ones(2, 2);
        assert_eq!(codec.encoded_len(&ones), codec.dense_len());
        // Fully-masked batch ships only the shared (non-head) slices.
        let none = masks_with(&[], &[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(codec.encoded_len(&none) < codec.dense_len());
        assert!(codec.payload_elems(&none) > 0, "embeddings/classifier still ship");
    }

    #[test]
    fn lora_codec_ships_only_adapters_and_head() {
        let be = NativeBackend::new(&spec(), 2, 2, 3);
        let codec = GradCodec::new(&be);
        let dense = codec.dense_len();
        let full_ft = GradCodec::new(&NativeBackend::new(&spec(), 0, 2, 3)).dense_len();
        assert!(
            dense < full_ft,
            "LoRA wire ({dense}B) must be far below full fine-tuning ({full_ft}B)"
        );
    }

    #[test]
    fn dense_delta_round_trip() {
        let mut be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let deltas = be.update_capture(&grads, 0.05);
        let blob = codec.encode_dense(&deltas);
        let back = codec.decode_dense(&blob).unwrap();
        for (d, b) in deltas.iter().zip(&back) {
            assert_eq!(d.data(), b.data());
        }
    }

    #[test]
    fn f16_conversion_round_trips_and_rounds_to_nearest() {
        // Exactly-representable values survive bit-perfect.
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.5, 1024.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "exact {v}");
        }
        // General values: relative error bounded by half an ulp (2^-11).
        for v in [0.333f32, -7.123, 1e-3, 123.456, -0.9999, 3.146] {
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!(
                (r - v).abs() <= v.abs() * 4.9e-4 + 1e-7,
                "f16 round trip of {v} gave {r}"
            );
        }
        // Overflow saturates to inf; tiny values flush through
        // subnormals to zero; NaN stays NaN; signs survive.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e6)), f32::NEG_INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        let sub = f16_bits_to_f32(f32_to_f16_bits(3e-6));
        assert!(sub > 0.0 && (sub - 3e-6).abs() < 6e-8, "subnormal {sub}");
        // Round-to-nearest-even at the half-ulp boundary: 1 + 2^-11 is
        // exactly between 1.0 and the next f16 (1 + 2^-10) — ties to
        // the even mantissa, i.e. 1.0.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 4.8828125e-4)), 1.0);
        // 1 + 3 * 2^-11 ties upward (odd neighbor below, even above).
        assert_eq!(
            f16_bits_to_f32(f32_to_f16_bits(1.0 + 3.0 * 4.8828125e-4)),
            1.0 + 2.0 * 9.765625e-4
        );
    }

    #[test]
    fn f16_wire_halves_bytes_and_decodes_within_tolerance() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let f32c = GradCodec::new(&be);
        let f16c = GradCodec::new(&be).with_precision(WirePrecision::F16);
        assert_eq!(f16c.precision(), WirePrecision::F16);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let m32 = f32c.encode(2, &masks, &grads);
        let m16 = f16c.encode(2, &masks, &grads);
        // Byte halving, measured on the real messages via WireStats.
        let elems = f32c.payload_elems(&masks);
        assert_eq!(m32.len(), HEADER_BYTES + 4 * elems);
        assert_eq!(m16.len(), HEADER_BYTES + 2 * elems);
        let mut s32 = WireStats::default();
        let mut s16 = WireStats::default();
        s32.record_up(m32.len(), f32c.dense_len());
        s16.record_up(m16.len(), f16c.dense_len());
        assert!(
            s16.up_bytes < s32.up_bytes && (s16.up_bytes as f64) < 0.51 * s32.up_bytes as f64,
            "f16 must roughly halve the uplink: {} vs {}",
            s16.up_bytes,
            s32.up_bytes
        );
        // Round trip within binary16 tolerance.
        let mut acc = be.zeros_like_params();
        let micro = f16c.decode_add(&m16, &masks, &mut acc).unwrap();
        assert_eq!(micro, 2);
        for (a, g) in acc.iter().zip(&grads) {
            for (&va, &vg) in a.data().iter().zip(g.data()) {
                assert!(
                    (va - vg).abs() <= vg.abs() * 1e-3 + 1e-6,
                    "f16 decode {va} vs {vg}"
                );
            }
        }
        // Precision mismatch is caught by the header flags, both ways.
        assert!(f32c.decode_add(&m16, &masks, &mut acc).is_err());
        assert!(f16c.decode_add(&m32, &masks, &mut acc).is_err());
        // Dense delta path honors precision too.
        let deltas = f16c.decode_dense(&f16c.encode_dense(&be.zeros_like_params())).unwrap();
        assert_eq!(deltas.len(), be.n_param_tensors());
        assert!(f32c.decode_dense(&f16c.encode_dense(&be.zeros_like_params())).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer_capacity() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let mut buf = Vec::new();
        codec.encode_into(0, &masks, &grads, &mut buf);
        let first = buf.clone();
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        // Re-encoding into the same buffer must not reallocate (same
        // capacity, same backing pointer) and must produce the bytes
        // `encode` would.
        codec.encode_into(0, &masks, &grads, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap, "steady-state encode must not grow");
        assert_eq!(buf.as_ptr(), ptr, "steady-state encode must not reallocate");
        assert_eq!(buf, codec.encode(0, &masks, &grads));
    }

    #[test]
    fn encode_append_embeds_a_verbatim_message_after_a_prefix() {
        // The transport frames embed gradient messages as tails: the
        // appended bytes must equal a standalone encode, decodable in
        // place from the offset.
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let mut frame = vec![9, 9, 9];
        codec.encode_append(1, &masks, &grads, &mut frame);
        assert_eq!(&frame[..3], &[9, 9, 9]);
        assert_eq!(&frame[3..], &codec.encode(1, &masks, &grads)[..]);
        let mut acc = be.zeros_like_params();
        assert_eq!(codec.decode_add(&frame[3..], &masks, &mut acc).unwrap(), 1);
        // Dense variant behaves the same way.
        let deltas = be.zeros_like_params();
        let mut dframe = vec![7];
        codec.encode_dense_append(&deltas, &mut dframe);
        assert_eq!(&dframe[1..], &codec.encode_dense(&deltas)[..]);
    }

    #[test]
    fn buf_pool_recycles_after_warmup() {
        let pool = BufPool::new();
        let a = pool.checkout();
        let b = pool.checkout();
        assert_eq!(pool.fresh_allocs(), 2);
        assert_eq!(pool.reuses(), 0);
        let mut a = a;
        a.extend_from_slice(&[1, 2, 3]);
        pool.give_back(a);
        pool.give_back(b);
        let c = pool.checkout();
        assert!(c.is_empty(), "recycled buffers come back cleared");
        assert_eq!(pool.fresh_allocs(), 2, "steady state: no new allocations");
        assert_eq!(pool.reuses(), 1);
    }

    #[test]
    fn wire_precision_parses() {
        assert_eq!(WirePrecision::parse("f32").unwrap(), WirePrecision::F32);
        assert_eq!(WirePrecision::parse("FP16").unwrap(), WirePrecision::F16);
        assert_eq!(WirePrecision::parse("half").unwrap(), WirePrecision::F16);
        assert!(WirePrecision::parse("bf16").is_err());
        assert_eq!(WirePrecision::F16.label(), "f16");
        assert_eq!(WirePrecision::default(), WirePrecision::F32);
    }

    #[test]
    fn wire_stats_savings() {
        let mut s = WireStats::default();
        s.record_up(600, 1000);
        s.record_up(400, 1000);
        s.record_down(1000);
        assert_eq!(s.up_msgs, 2);
        assert_eq!(s.total_bytes(), 2000);
        assert!((s.grad_savings() - 0.5).abs() < 1e-12);
    }
}
