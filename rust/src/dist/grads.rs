//! Masked-gradient wire format: serialize exactly the parameter slices
//! a schedule leaves trainable, nothing else.
//!
//! ## Why no index structure
//!
//! D2FT's schedule is computed centrally and known to every node before
//! the batch runs, so sender and receiver can both derive the payload
//! layout from `(model structure, MaskPair)`. A message is therefore a
//! 28-byte header (magic, precision flags, micro, mask fingerprint,
//! element count) plus raw little-endian payload elements in canonical
//! order — f32 by default, IEEE binary16 under [`WirePrecision::F16`]
//! — the densest encoding the mask admits, which makes the byte
//! accounting an honest measurement of the paper's communication claim
//! rather than a property of a clever container format. The mask
//! fingerprint catches sender/receiver schedule divergence; the flags
//! catch a precision mismatch.
//!
//! ## What ships
//!
//! Per parameter tensor (canonical sorted-name order):
//!
//! * non-trainable tensors (LoRA-frozen base weights) — never ship;
//! * *shared* elements (embeddings, layer norms, classifier — owned by
//!   no head) — always ship;
//! * elements owned by subnet (block `l`, head `h`) — ship iff the
//!   backward mask is 1 for that head (`p_f`). `p_o` and `p_s` heads
//!   ship nothing: the backend's freeze contract guarantees those
//!   gradient slices are exactly zero, so dropping them is lossless —
//!   [`GradCodec::decode_add`] of an encoded message reconstructs the
//!   dense gradient bit-for-bit (`tests/dist.rs` pins this property).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

/// Message magic: "D2FG" (masked gradient payload).
const MAGIC_GRAD: u32 = 0x4432_4647;
/// Message magic: "D2FD" (dense delta payload, parameter-server mode).
const MAGIC_DELTA: u32 = 0x4432_4644;
/// Header: magic u32, flags u32 (wire precision + compression), micro
/// u32, mask fingerprint u64, payload elems u64.
const HEADER_BYTES: usize = 28;
/// Header flags bit 0: payload elements are IEEE binary16 (2 bytes)
/// instead of the default f32.
const FLAG_F16: u32 = 1;
/// Header flags bit 1: payload is int8-quantized per slice.
const FLAG_INT8: u32 = 2;
/// Header flags bit 2: payload is int4-quantized per slice (packed
/// nibbles).
const FLAG_INT4: u32 = 4;
/// Header flags bit 3: payload is top-k sparsified (delta-encoded
/// indices + values); bits 8..16 carry the kept percentage.
const FLAG_TOPK: u32 = 8;

/// Element precision of gradient payloads on the wire.
///
/// `F32` (the default) is lossless by the freeze contract — the bitwise
/// serial ≡ distributed guarantee holds. `F16` halves every payload
/// byte ([`WireStats`] measures it on the actual messages) at binary16
/// precision (~3 decimal digits); the aggregator then applies the
/// *requantized* reduced gradient so every replica — aggregator
/// included — still sees identical bits, but the trajectory is no
/// longer bit-equal to the serial trainer's.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePrecision {
    /// 4-byte little-endian f32 payload elements (lossless; default).
    #[default]
    F32,
    /// 2-byte IEEE binary16 payload elements (half the bytes, lossy).
    F16,
}

impl WirePrecision {
    /// Parse a CLI label (`f32` | `f16`).
    pub fn parse(s: &str) -> Result<WirePrecision> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => WirePrecision::F32,
            "f16" | "fp16" | "half" => WirePrecision::F16,
            _ => anyhow::bail!("unknown wire precision {s:?} (f32|f16)"),
        })
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::F16 => "f16",
        }
    }

    /// Bytes per payload element.
    fn elem_bytes(self) -> usize {
        match self {
            WirePrecision::F32 => 4,
            WirePrecision::F16 => 2,
        }
    }

    /// Header flag bits for this precision.
    fn flags(self) -> u32 {
        match self {
            WirePrecision::F32 => 0,
            WirePrecision::F16 => FLAG_F16,
        }
    }
}

/// f32 -> IEEE binary16 bits with round-to-nearest-even (overflow to
/// ±inf, underflow through subnormals to ±0; NaN payload preserved as a
/// quiet NaN).
fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let absx = b & 0x7FFF_FFFF;
    if absx >= 0x7F80_0000 {
        // Inf / NaN.
        return sign | 0x7C00 | if absx > 0x7F80_0000 { 0x0200 } else { 0 };
    }
    let exp = (absx >> 23) as i32 - 127;
    if exp > 15 {
        return sign | 0x7C00; // overflow -> ±inf
    }
    if exp < -25 {
        return sign; // below half the smallest subnormal -> ±0
    }
    let mant = (absx & 0x007F_FFFF) | 0x0080_0000; // 24-bit significand
    // Normals drop 13 mantissa bits; subnormals drop more as the
    // exponent sinks below -14.
    let shift: u32 = if exp >= -14 { 13 } else { (13 - 14 - exp) as u32 };
    let base = mant >> shift;
    let rem = mant & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let rounded = if rem > half || (rem == half && base & 1 == 1) { base + 1 } else { base };
    let h = if exp >= -14 {
        // `rounded` carries the implicit bit at 1 << 10; a round-up to
        // 1 << 11 correctly bumps the exponent (and 30 -> 31 is inf).
        ((((exp + 15) as u32) << 10) + (rounded - (1 << 10))) as u16
    } else {
        // Subnormal: no implicit bit; a carry to 1 << 10 lands exactly
        // on the smallest normal.
        rounded as u16
    };
    sign | h
}

/// IEEE binary16 bits -> f32 (exact; every f16 value is representable).
fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 31 {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize into an f32 exponent.
            let mut e = 113u32; // biased exponent of 2^-14
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Append one payload value at the codec's wire precision.
#[inline]
fn write_vals(out: &mut Vec<u8>, vals: &[f32], prec: WirePrecision) {
    match prec {
        WirePrecision::F32 => {
            for &v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        WirePrecision::F16 => {
            for &v in vals {
                out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
    }
}

/// Decode payload values starting at `off`, adding into `dst`; returns
/// the advanced offset.
#[inline]
fn add_vals(dst: &mut [f32], bytes: &[u8], mut off: usize, prec: WirePrecision) -> usize {
    match prec {
        WirePrecision::F32 => {
            for x in dst {
                *x += f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                off += 4;
            }
        }
        WirePrecision::F16 => {
            for x in dst {
                let h = u16::from_le_bytes(bytes[off..off + 2].try_into().unwrap());
                *x += f16_bits_to_f32(h);
                off += 2;
            }
        }
    }
    off
}

/// Lossy payload compression stacked under the wire precision.
///
/// `None` is the bitwise-reference mode: the payload is exactly the
/// [`WirePrecision`] elements, and the serial ≡ distributed contract
/// holds on the f32 wire. The lossy modes trade bits for bytes and are
/// pinned by loss-trajectory delta instead:
///
/// * `Int8` / `Int4` — per-slice symmetric quantization, where a
///   *slice* is one parameter tensor's shipped elements in a message:
///   each ships a 4-byte f32 scale (`max|v| / 127` resp. `/ 7`)
///   followed by 1-byte (resp. packed 4-bit) signed codes, so the
///   overhead is bytes-per-parameter, not bytes-per-run.
/// * `TopK { pct }` — only the `pct`% largest-magnitude payload
///   elements ship, as delta-encoded varint indices plus values at the
///   wire precision (the one mode that composes with
///   [`WirePrecision::F16`]).
///
/// Both lossy families support **error feedback**: the encoder adds the
/// residual left over from the previous message before quantizing or
/// selecting, and stores the new quantization/sparsification error back
/// ([`GradCodec::encode_append_ef`]) — across steps the accumulated
/// error stays bounded instead of compounding.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireCompression {
    /// Verbatim payload at the wire precision (lossless; default).
    #[default]
    None,
    /// Per-slice-scaled 8-bit quantization (~4x vs f32).
    Int8,
    /// Per-slice-scaled packed 4-bit quantization (~8x vs f32).
    Int4,
    /// Keep only the `pct`% largest-magnitude elements (1..=100).
    TopK {
        /// Percentage of payload elements kept (by magnitude).
        pct: u8,
    },
}

impl WireCompression {
    /// Parse a CLI label: `none` | `int8` | `int4` | `topk` |
    /// `topk:PCT` (default 10%).
    pub fn parse(s: &str) -> Result<WireCompression> {
        let lower = s.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "none" | "off" => WireCompression::None,
            "int8" | "q8" => WireCompression::Int8,
            "int4" | "q4" => WireCompression::Int4,
            "topk" => WireCompression::TopK { pct: 10 },
            _ => {
                if let Some(p) = lower.strip_prefix("topk:") {
                    let pct: u8 = p
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad top-k percentage {p:?}"))?;
                    anyhow::ensure!(
                        (1..=100).contains(&pct),
                        "top-k percentage must be in 1..=100, got {pct}"
                    );
                    WireCompression::TopK { pct }
                } else {
                    anyhow::bail!(
                        "unknown wire compression {s:?} (none|int8|int4|topk[:PCT])"
                    )
                }
            }
        })
    }

    /// Display label (`topk:PCT` carries its percentage).
    pub fn label(&self) -> String {
        match self {
            WireCompression::None => "none".to_string(),
            WireCompression::Int8 => "int8".to_string(),
            WireCompression::Int4 => "int4".to_string(),
            WireCompression::TopK { pct } => format!("topk:{pct}"),
        }
    }

    /// True for the lossy modes (everything but `None`).
    pub fn is_lossy(&self) -> bool {
        !matches!(self, WireCompression::None)
    }

    /// Header flag bits (the top-k percentage rides in bits 8..16 so a
    /// sender/receiver disagreement on `pct` is caught like any other
    /// flag mismatch).
    fn flags(self) -> u32 {
        match self {
            WireCompression::None => 0,
            WireCompression::Int8 => FLAG_INT8,
            WireCompression::Int4 => FLAG_INT4,
            WireCompression::TopK { pct } => FLAG_TOPK | ((pct as u32) << 8),
        }
    }
}

/// Append `v` as an LEB128 varint.
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Read an LEB128 varint at `*off`, advancing it. Truncation and
/// overlong encodings error instead of panicking.
fn get_varint(bytes: &[u8], off: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        anyhow::ensure!(*off < bytes.len(), "truncated varint");
        anyhow::ensure!(shift < 64, "varint overflow");
        let b = bytes[*off];
        *off += 1;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Symmetric per-slice quantization scale for `levels` signed steps.
fn quant_scale(vals: &[f32], levels: f32) -> f32 {
    let max = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max > 0.0 {
        max / levels
    } else {
        0.0
    }
}

/// Quantize `v` to a signed integer code in `[-levels, levels]`.
#[inline]
fn quant_code(v: f32, scale: f32, levels: f32) -> i32 {
    if scale == 0.0 {
        0
    } else {
        (v / scale).round().clamp(-levels, levels) as i32
    }
}

/// Owner tag for elements belonging to no head.
const SHARED: u32 = u32::MAX;

/// A contiguous `[lo, hi)` element range within one parameter tensor.
type Range = (usize, usize);

#[derive(Clone, Debug)]
struct ParamLayout {
    /// False for LoRA-frozen base weights — never on the wire.
    trainable: bool,
    /// Total element count of the tensor.
    len: usize,
    /// Maximal runs owned by no head (ship whenever trainable).
    shared: Vec<Range>,
    /// Maximal runs owned by subnet `l * heads + h`.
    per_head: Vec<Vec<Range>>,
}

/// Encoder/decoder for masked gradient messages, specialized to one
/// model instance. Construction walks the backend's per-head parameter
/// ownership map once; encode/decode are then pure range copies.
#[derive(Clone, Debug)]
pub struct GradCodec {
    depth: usize,
    heads: usize,
    params: Vec<ParamLayout>,
    /// Total trainable elements (the dense message payload).
    dense_elems: usize,
    /// Payload element precision on the wire (f32 default).
    precision: WirePrecision,
    /// Payload compression stacked under the precision (none default).
    compress: WireCompression,
}

impl GradCodec {
    /// Build the codec for `be`'s exact parameter layout (LoRA rank,
    /// depth, heads). Replicas built from the same spec share a layout,
    /// so one codec serves a whole cluster.
    pub fn new(be: &NativeBackend) -> GradCodec {
        let cfg = be.config();
        let (depth, heads) = (cfg.depth, cfg.heads);
        let n = be.n_param_tensors();
        let mut owner: Vec<Vec<u32>> =
            (0..n).map(|i| vec![SHARED; be.param_elems(i)]).collect();
        for l in 0..depth {
            for h in 0..heads {
                let tag = (l * heads + h) as u32;
                be.visit_head_elems(l, h, &mut |pi, ei| {
                    debug_assert_eq!(owner[pi][ei], SHARED, "element owned twice");
                    owner[pi][ei] = tag;
                });
            }
        }
        let trainable = be.trainable_flags();
        let mut params = Vec::with_capacity(n);
        let mut dense_elems = 0usize;
        for (pi, own) in owner.iter().enumerate() {
            let mut shared = Vec::new();
            let mut per_head: Vec<Vec<Range>> = vec![Vec::new(); depth * heads];
            let mut i = 0;
            while i < own.len() {
                let tag = own[i];
                let mut j = i + 1;
                while j < own.len() && own[j] == tag {
                    j += 1;
                }
                if tag == SHARED {
                    shared.push((i, j));
                } else {
                    per_head[tag as usize].push((i, j));
                }
                i = j;
            }
            if trainable[pi] {
                dense_elems += own.len();
            }
            params.push(ParamLayout {
                trainable: trainable[pi],
                len: own.len(),
                shared,
                per_head,
            });
        }
        GradCodec {
            depth,
            heads,
            params,
            dense_elems,
            precision: WirePrecision::F32,
            compress: WireCompression::None,
        }
    }

    /// Same layout, different wire precision (builder style). All
    /// cluster nodes must agree — the header flags catch a mismatch at
    /// decode time.
    pub fn with_precision(mut self, precision: WirePrecision) -> GradCodec {
        self.precision = precision;
        self
    }

    /// The payload element precision this codec reads and writes.
    pub fn precision(&self) -> WirePrecision {
        self.precision
    }

    /// Same layout, different payload compression (builder style). Like
    /// the precision, all cluster nodes must agree — the header flags
    /// catch a mismatch (including a top-k percentage disagreement) at
    /// decode time. Compression applies to masked gradient messages
    /// only; the dense parameter-server delta path stays verbatim.
    pub fn with_compression(mut self, compress: WireCompression) -> GradCodec {
        self.compress = compress;
        self
    }

    /// The payload compression this codec reads and writes.
    pub fn compression(&self) -> WireCompression {
        self.compress
    }

    /// Combined header flag word (precision + compression).
    fn flags(&self) -> u32 {
        self.precision.flags() | self.compress.flags()
    }

    /// Visit every shipped `(param index, lo, hi)` range under the
    /// activity vector, in canonical wire order.
    fn for_each_range(&self, act: &[bool], f: &mut impl FnMut(usize, usize, usize)) {
        for (pi, p) in self.params.iter().enumerate() {
            if !p.trainable {
                continue;
            }
            for &(lo, hi) in &p.shared {
                f(pi, lo, hi);
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    f(pi, lo, hi);
                }
            }
        }
    }

    /// Shipped `[lo, hi)` ranges of one parameter under the activity
    /// vector, in wire order (shared runs, then active heads).
    fn shipped_ranges(p: &ParamLayout, act: &[bool]) -> Vec<Range> {
        if !p.trainable {
            return Vec::new();
        }
        let mut v = p.shared.clone();
        for (t, ranges) in p.per_head.iter().enumerate() {
            if act[t] {
                v.extend_from_slice(ranges);
            }
        }
        v
    }

    /// Shipped element count of one parameter under the activity vector.
    fn param_payload_elems(p: &ParamLayout, act: &[bool]) -> usize {
        if !p.trainable {
            return 0;
        }
        let mut n: usize = p.shared.iter().map(|r| r.1 - r.0).sum();
        for (t, ranges) in p.per_head.iter().enumerate() {
            if act[t] {
                n += ranges.iter().map(|r| r.1 - r.0).sum::<usize>();
            }
        }
        n
    }

    /// Which subnets ship under `masks`: a head's slices travel iff its
    /// backward mask is 1 (only `p_f` produces nonzero gradients there).
    fn active(&self, masks: &MaskPair) -> Vec<bool> {
        assert_eq!(
            masks.bwd.shape(),
            &[self.depth, self.heads],
            "mask shape vs codec model"
        );
        let mut v = vec![false; self.depth * self.heads];
        for l in 0..self.depth {
            for h in 0..self.heads {
                v[l * self.heads + h] = masks.bwd.at(&[l, h]) >= 0.5;
            }
        }
        v
    }

    /// Payload element count for a precomputed activity vector.
    fn payload_elems_with(&self, act: &[bool]) -> usize {
        let mut n = 0usize;
        for p in &self.params {
            if !p.trainable {
                continue;
            }
            n += p.shared.iter().map(|r| r.1 - r.0).sum::<usize>();
            for (t, ranges) in p.per_head.iter().enumerate() {
                if act[t] {
                    n += ranges.iter().map(|r| r.1 - r.0).sum::<usize>();
                }
            }
        }
        n
    }

    /// Payload element count of one message under `masks`.
    pub fn payload_elems(&self, masks: &MaskPair) -> usize {
        self.payload_elems_with(&self.active(masks))
    }

    /// Exact payload byte count under the activity vector for the
    /// deterministic-size modes. `TopK` messages are data-dependent
    /// (varint index deltas), so their size is validated while parsing
    /// instead; this returns `None` for them.
    fn payload_bytes_with(&self, act: &[bool]) -> Option<usize> {
        match self.compress {
            WireCompression::None => {
                Some(self.precision.elem_bytes() * self.payload_elems_with(act))
            }
            WireCompression::Int8 | WireCompression::Int4 => {
                let int8 = self.compress == WireCompression::Int8;
                let mut total = 0usize;
                for p in &self.params {
                    let n = Self::param_payload_elems(p, act);
                    if n == 0 {
                        continue;
                    }
                    total += 4 + if int8 { n } else { n.div_ceil(2) };
                }
                Some(total)
            }
            WireCompression::TopK { .. } => None,
        }
    }

    /// Encoded byte size of one message under `masks`. Exact for every
    /// mode but `TopK`, whose varint index stream is data-dependent —
    /// there this returns the (never exceeded) bound of a dense index
    /// stream.
    pub fn encoded_len(&self, masks: &MaskPair) -> usize {
        let act = self.active(masks);
        match self.payload_bytes_with(&act) {
            Some(n) => HEADER_BYTES + n,
            None => {
                let n = self.payload_elems_with(&act);
                let k = self.topk_count(n);
                // Bound: 8-byte count, <= 10-byte varints, full values.
                HEADER_BYTES + 8 + 10 * k + self.precision.elem_bytes() * k
            }
        }
    }

    /// Number of elements a top-k message keeps out of `n`.
    fn topk_count(&self, n: usize) -> usize {
        match self.compress {
            WireCompression::TopK { pct } => {
                if n == 0 {
                    0
                } else {
                    ((n * pct as usize).div_ceil(100)).max(1)
                }
            }
            _ => n,
        }
    }

    /// Byte size of a dense (every head active) message — what one
    /// micro-batch of the full, unmasked schedule ships.
    pub fn dense_len(&self) -> usize {
        HEADER_BYTES + self.precision.elem_bytes() * self.dense_elems
    }

    /// Serialize the gradient slices `masks` leaves trainable. `grads`
    /// must be the backend's dense gradients in canonical order (one
    /// tensor per parameter). Allocates a fresh buffer; the hot loop
    /// uses [`GradCodec::encode_into`] with a recycled one.
    pub fn encode(&self, micro: usize, masks: &MaskPair, grads: &[Tensor]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(micro, masks, grads, &mut out);
        out
    }

    /// [`GradCodec::encode`] into a caller-provided scratch buffer: the
    /// buffer is cleared and refilled, so a recycled buffer (see
    /// [`BufPool`]) makes the steady-state encode path allocation-free
    /// once its capacity has grown to the largest message.
    pub fn encode_into(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        out: &mut Vec<u8>,
    ) {
        out.clear();
        self.encode_append(micro, masks, grads, out);
    }

    /// [`GradCodec::encode_into`] without the clear: the message is
    /// appended after whatever `out` already holds. This is how a
    /// transport frame embeds a gradient message as its tail
    /// (`dist::proto`) with zero copies — the codec writes straight
    /// into the frame buffer after the frame's own header.
    pub fn encode_append(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        out: &mut Vec<u8>,
    ) {
        self.encode_append_ef(micro, masks, grads, None, out);
    }

    /// [`GradCodec::encode_into`] with an error-feedback residual (see
    /// [`GradCodec::encode_append_ef`]).
    pub fn encode_into_ef(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        ef: Option<&mut [Tensor]>,
        out: &mut Vec<u8>,
    ) {
        out.clear();
        self.encode_append_ef(micro, masks, grads, ef, out);
    }

    /// [`GradCodec::encode_append`] with **error feedback** for the
    /// lossy compression modes: `ef` (dense residual tensors, e.g. from
    /// [`NativeBackend::zeros_like_params`], owned by the sender and
    /// carried across messages) is added to each shipped value before
    /// quantization/selection, and the part that did not make it onto
    /// the wire is stored back. Under `WireCompression::None` the
    /// residual is ignored — the payload is exact.
    pub fn encode_append_ef(
        &self,
        micro: usize,
        masks: &MaskPair,
        grads: &[Tensor],
        mut ef: Option<&mut [Tensor]>,
        out: &mut Vec<u8>,
    ) {
        assert_eq!(grads.len(), self.params.len(), "grad tensor count");
        if let Some(r) = ef.as_deref() {
            assert_eq!(r.len(), self.params.len(), "residual tensor count");
        }
        let _sp = crate::obs::trace::span("codec", "encode");
        let act = self.active(masks);
        let n_elems = self.payload_elems_with(&act);
        out.reserve(HEADER_BYTES + self.payload_bytes_with(&act).unwrap_or(0));
        out.extend_from_slice(&MAGIC_GRAD.to_le_bytes());
        out.extend_from_slice(&self.flags().to_le_bytes());
        out.extend_from_slice(&(micro as u32).to_le_bytes());
        out.extend_from_slice(&masks.fingerprint().to_le_bytes());
        out.extend_from_slice(&(n_elems as u64).to_le_bytes());
        match self.compress {
            WireCompression::None => {
                self.for_each_range(&act, &mut |pi, lo, hi| {
                    write_vals(out, &grads[pi].data()[lo..hi], self.precision);
                });
            }
            WireCompression::Int8 | WireCompression::Int4 => {
                let int8 = self.compress == WireCompression::Int8;
                let levels: f32 = if int8 { 127.0 } else { 7.0 };
                let mut slice = Vec::new();
                for (pi, p) in self.params.iter().enumerate() {
                    let ranges = Self::shipped_ranges(p, &act);
                    // Gather this parameter's shipped elements (plus
                    // carried residual) into one contiguous slice and
                    // quantize them under a single scale.
                    slice.clear();
                    let gd = grads[pi].data();
                    for &(lo, hi) in &ranges {
                        slice.extend_from_slice(&gd[lo..hi]);
                    }
                    if slice.is_empty() {
                        continue;
                    }
                    if let Some(r) = ef.as_deref() {
                        let rd = r[pi].data();
                        let mut j = 0usize;
                        for &(lo, hi) in &ranges {
                            for i in lo..hi {
                                slice[j] += rd[i];
                                j += 1;
                            }
                        }
                    }
                    let scale = quant_scale(&slice, levels);
                    out.extend_from_slice(&scale.to_le_bytes());
                    if int8 {
                        for &v in slice.iter() {
                            out.push(quant_code(v, scale, levels) as i8 as u8);
                        }
                    } else {
                        for pair in slice.chunks(2) {
                            let lo4 = (quant_code(pair[0], scale, levels) + 8) as u8;
                            let hi4 = if pair.len() == 2 {
                                (quant_code(pair[1], scale, levels) + 8) as u8
                            } else {
                                8 // padding nibble encodes zero
                            };
                            out.push((lo4 & 0x0F) | (hi4 << 4));
                        }
                    }
                    if let Some(r) = ef.as_deref_mut() {
                        let rd = r[pi].data_mut();
                        let mut j = 0usize;
                        for &(lo, hi) in &ranges {
                            for i in lo..hi {
                                let v = slice[j];
                                let sent = quant_code(v, scale, levels) as f32 * scale;
                                rd[i] = v - sent;
                                j += 1;
                            }
                        }
                    }
                }
            }
            WireCompression::TopK { .. } => {
                // Gather the (residual-corrected) payload stream, pick
                // the k largest magnitudes (ties broken by position so
                // the selection is deterministic), ship sorted indices
                // as varint deltas plus values at the wire precision.
                let mut vals = Vec::with_capacity(n_elems);
                self.for_each_range(&act, &mut |pi, lo, hi| {
                    let gd = grads[pi].data();
                    if let Some(r) = ef.as_deref() {
                        let rd = r[pi].data();
                        for i in lo..hi {
                            vals.push(gd[i] + rd[i]);
                        }
                    } else {
                        vals.extend_from_slice(&gd[lo..hi]);
                    }
                });
                let k = self.topk_count(vals.len());
                let mut order: Vec<u32> = (0..vals.len() as u32).collect();
                order.sort_by(|&a, &b| {
                    let (ma, mb) = (vals[a as usize].abs(), vals[b as usize].abs());
                    mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
                });
                let mut keep: Vec<u32> = order[..k].to_vec();
                keep.sort_unstable();
                out.extend_from_slice(&(k as u64).to_le_bytes());
                let mut prev = 0u64;
                for (j, &idx) in keep.iter().enumerate() {
                    let idx = idx as u64;
                    put_varint(out, if j == 0 { idx } else { idx - prev });
                    prev = idx;
                }
                let mut selected = vec![false; vals.len()];
                for &idx in &keep {
                    selected[idx as usize] = true;
                    write_vals(out, &vals[idx as usize..idx as usize + 1], self.precision);
                }
                if let Some(r) = ef.as_deref_mut() {
                    let mut pos = 0usize;
                    self.for_each_range(&act, &mut |pi, lo, hi| {
                        let rd = r[pi].data_mut();
                        for i in lo..hi {
                            rd[i] = if selected[pos] {
                                // The value survives at the wire
                                // precision: only its rounding error
                                // (zero on f32) feeds back.
                                match self.precision {
                                    WirePrecision::F32 => 0.0,
                                    WirePrecision::F16 => {
                                        vals[pos]
                                            - f16_bits_to_f32(f32_to_f16_bits(vals[pos]))
                                    }
                                }
                            } else {
                                vals[pos]
                            };
                            pos += 1;
                        }
                    });
                }
            }
        }
    }

    /// Decode a message and **add** its payload into dense accumulators
    /// (canonical order, e.g. from
    /// [`NativeBackend::zeros_like_params`]). Elements the mask excluded
    /// are untouched — with a zeroed accumulator this reconstructs the
    /// sender's dense gradient exactly, because excluded slices were
    /// exactly zero. Returns the message's micro-batch index.
    pub fn decode_add(
        &self,
        bytes: &[u8],
        masks: &MaskPair,
        acc: &mut [Tensor],
    ) -> Result<usize> {
        anyhow::ensure!(acc.len() == self.params.len(), "accumulator count");
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let _sp = crate::obs::trace::span("codec", "decode_add");
        let word = |lo: usize| -> [u8; 4] { bytes[lo..lo + 4].try_into().unwrap() };
        let magic = u32::from_le_bytes(word(0));
        anyhow::ensure!(magic == MAGIC_GRAD, "bad gradient-message magic {magic:#x}");
        let flags = u32::from_le_bytes(word(4));
        anyhow::ensure!(
            flags == self.flags(),
            "wire format mismatch: message flags {flags:#x}, codec is {}/{}",
            self.precision.label(),
            self.compress.label()
        );
        let micro = u32::from_le_bytes(word(8)) as usize;
        let fp = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        anyhow::ensure!(
            fp == masks.fingerprint(),
            "mask fingerprint mismatch: sender and receiver disagree on the schedule"
        );
        let act = self.active(masks);
        let expect = self.payload_elems_with(&act);
        let n_elems = u64::from_le_bytes(bytes[20..28].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == expect,
            "payload {n_elems} elems, layout expects {expect}"
        );
        if let Some(pb) = self.payload_bytes_with(&act) {
            anyhow::ensure!(
                bytes.len() == HEADER_BYTES + pb,
                "message length {} vs expected {}",
                bytes.len(),
                HEADER_BYTES + pb
            );
        }
        match self.compress {
            WireCompression::None => {
                let mut off = HEADER_BYTES;
                self.for_each_range_acc(&act, acc, &mut |ad, lo, hi| {
                    off = add_vals(&mut ad[lo..hi], bytes, off, self.precision);
                    Ok(())
                })?;
            }
            WireCompression::Int8 | WireCompression::Int4 => {
                // The exact-length check above makes this walk's
                // indexing safe: it consumes precisely
                // `payload_bytes_with` bytes.
                let mut off = HEADER_BYTES;
                let int8 = self.compress == WireCompression::Int8;
                for (p, a) in self.params.iter().zip(acc.iter_mut()) {
                    let ranges = Self::shipped_ranges(p, &act);
                    let n = Self::param_payload_elems(p, &act);
                    if n == 0 {
                        continue;
                    }
                    let scale =
                        f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                    anyhow::ensure!(scale.is_finite(), "non-finite quantization scale");
                    off += 4;
                    let ad = a.data_mut();
                    let mut j = 0usize;
                    for &(lo, hi) in &ranges {
                        for i in lo..hi {
                            let code = if int8 {
                                bytes[off + j] as i8 as i32
                            } else {
                                let byte = bytes[off + j / 2];
                                let nib = if j % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                                nib as i32 - 8
                            };
                            ad[i] += code as f32 * scale;
                            j += 1;
                        }
                    }
                    off += if int8 { n } else { n.div_ceil(2) };
                }
            }
            WireCompression::TopK { .. } => {
                // Header declares the *stream* length (n_elems); the
                // payload carries k entries. Everything is
                // cursor-parsed with bounds checks so a malformed
                // frame rejects instead of panicking.
                let mut off = HEADER_BYTES;
                anyhow::ensure!(bytes.len() >= off + 8, "truncated top-k count");
                let k = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
                off += 8;
                anyhow::ensure!(
                    k == self.topk_count(n_elems),
                    "top-k count {k} disagrees with codec selection"
                );
                let mut indices = Vec::with_capacity(k);
                let mut prev = 0u64;
                for j in 0..k {
                    let delta = get_varint(bytes, &mut off)?;
                    let idx = if j == 0 {
                        delta
                    } else {
                        anyhow::ensure!(delta > 0, "non-increasing top-k index");
                        match prev.checked_add(delta) {
                            Some(v) => v,
                            None => anyhow::bail!("top-k index overflow"),
                        }
                    };
                    anyhow::ensure!(
                        (idx as usize) < n_elems,
                        "top-k index {idx} out of range {n_elems}"
                    );
                    prev = idx;
                    indices.push(idx as usize);
                }
                let vb = self.precision.elem_bytes();
                anyhow::ensure!(
                    bytes.len() == off + vb * k,
                    "top-k payload length mismatch"
                );
                let mut vals = vec![0.0f32; k];
                for v in vals.iter_mut() {
                    off = add_vals(std::slice::from_mut(v), bytes, off, self.precision);
                }
                let mut cursor = 0usize; // next selected entry to place
                let mut pos = 0usize; // position in the payload stream
                self.for_each_range_acc(&act, acc, &mut |ad, lo, hi| {
                    while cursor < indices.len()
                        && indices[cursor] < pos + (hi - lo)
                    {
                        ad[lo + (indices[cursor] - pos)] += vals[cursor];
                        cursor += 1;
                    }
                    pos += hi - lo;
                    Ok(())
                })?;
            }
        }
        Ok(micro)
    }

    /// Fallible mutable-accumulator companion to
    /// [`GradCodec::for_each_range`]: walks the same wire order handing
    /// each callback the owning tensor's dense data.
    fn for_each_range_acc(
        &self,
        act: &[bool],
        acc: &mut [Tensor],
        f: &mut impl FnMut(&mut [f32], usize, usize) -> Result<()>,
    ) -> Result<()> {
        for (p, a) in self.params.iter().zip(acc.iter_mut()) {
            if !p.trainable {
                continue;
            }
            let ad = a.data_mut();
            for &(lo, hi) in &p.shared {
                f(ad, lo, hi)?;
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    f(ad, lo, hi)?;
                }
            }
        }
        Ok(())
    }

    /// Serialize dense per-parameter values for every trainable tensor —
    /// the parameter-server downlink (update deltas). `vals[i]` must
    /// have the parameter's full element count for trainable `i`
    /// (non-trainable entries are ignored).
    pub fn encode_dense(&self, vals: &[Tensor]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_dense_into(vals, &mut out);
        out
    }

    /// [`GradCodec::encode_dense`] into a caller-provided scratch buffer
    /// (cleared and refilled; reuse makes the steady state
    /// allocation-free).
    pub fn encode_dense_into(&self, vals: &[Tensor], out: &mut Vec<u8>) {
        out.clear();
        self.encode_dense_append(vals, out);
    }

    /// [`GradCodec::encode_dense_into`] without the clear (appended as
    /// a transport frame's tail, like [`GradCodec::encode_append`]).
    pub fn encode_dense_append(&self, vals: &[Tensor], out: &mut Vec<u8>) {
        assert_eq!(vals.len(), self.params.len(), "value tensor count");
        out.reserve(HEADER_BYTES + self.precision.elem_bytes() * self.dense_elems);
        out.extend_from_slice(&MAGIC_DELTA.to_le_bytes());
        out.extend_from_slice(&self.precision.flags().to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&(self.dense_elems as u64).to_le_bytes());
        for (p, v) in self.params.iter().zip(vals) {
            if !p.trainable {
                continue;
            }
            assert_eq!(v.len(), p.len, "dense payload size");
            write_vals(out, v.data(), self.precision);
        }
    }

    /// Decode a dense payload into per-parameter tensors (1-D; zero
    /// length for non-trainable entries, mirroring
    /// [`NativeBackend::update_capture`]).
    pub fn decode_dense(&self, bytes: &[u8]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC_DELTA, "bad delta-message magic {magic:#x}");
        let flags = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(
            flags == self.precision.flags(),
            "wire precision mismatch: message flags {flags:#x}, codec is {}",
            self.precision.label()
        );
        let n_elems = u64::from_le_bytes(bytes[20..28].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == self.dense_elems
                && bytes.len() == HEADER_BYTES + self.precision.elem_bytes() * n_elems,
            "dense payload size mismatch"
        );
        let mut off = HEADER_BYTES;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            if !p.trainable {
                out.push(Tensor::zeros(&[0]));
                continue;
            }
            let mut v = vec![0.0f32; p.len];
            off = add_vals(&mut v, bytes, off, self.precision);
            out.push(Tensor::from_vec(&[p.len], v));
        }
        Ok(out)
    }
}

/// A recycling pool of encode buffers: the dist hot loop checks a
/// buffer out, [`GradCodec::encode_into`] refills it in place, the
/// aggregator gives it back after the reduction consumed the bytes. In
/// steady state (after the first batch grew each buffer's capacity to
/// the largest message) the per-task encode path performs **zero heap
/// allocations** — [`BufPool::fresh_allocs`] stops moving, which
/// `dist::trainer` tests pin.
#[derive(Debug, Default)]
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    fresh: AtomicU64,
    reused: AtomicU64,
}

/// Cap on parked buffers: enough for every micro-batch of a batch to be
/// in flight at once plus slack; beyond this, returned buffers are
/// dropped rather than hoarded.
const BUF_POOL_CAP: usize = 64;

impl BufPool {
    /// Fresh, empty pool.
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// Take a cleared buffer — recycled when one is parked, freshly
    /// allocated otherwise.
    pub fn checkout(&self) -> Vec<u8> {
        if let Some(b) = self.free.lock().expect("buf pool lock").pop() {
            debug_assert!(b.is_empty(), "recycled buffer must come back cleared");
            debug_assert!(b.capacity() > 0, "recycled buffer lost its capacity");
            self.reused.fetch_add(1, Ordering::Relaxed);
            b
        } else {
            self.fresh.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        }
    }

    /// Return a buffer for reuse (cleared here; capacity kept). A
    /// buffer that never grew (e.g. a transport barrier token) is
    /// dropped instead of parked — recycling it buys nothing.
    pub fn give_back(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        b.clear();
        let mut free = self.free.lock().expect("buf pool lock");
        if free.len() < BUF_POOL_CAP {
            free.push(b);
        }
    }

    /// Buffers allocated fresh (steady state: stops growing).
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh.load(Ordering::Relaxed)
    }

    /// Checkouts served by recycling.
    pub fn reuses(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }
}

/// Running bytes-on-the-wire accounting for one distributed run.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Uplink gradient messages (worker -> aggregator).
    pub up_msgs: u64,
    /// Uplink bytes actually serialized.
    pub up_bytes: u64,
    /// What the same messages would have cost unmasked (dense).
    pub dense_up_bytes: u64,
    /// Downlink broadcasts (aggregator -> worker).
    pub down_msgs: u64,
    /// Downlink bytes actually serialized.
    pub down_bytes: u64,
}

impl WireStats {
    /// Record one uplink gradient message of `bytes` against a dense
    /// baseline of `dense` bytes.
    pub fn record_up(&mut self, bytes: usize, dense: usize) {
        self.up_msgs += 1;
        self.up_bytes += bytes as u64;
        self.dense_up_bytes += dense as u64;
    }

    /// Record one downlink broadcast message.
    pub fn record_down(&mut self, bytes: usize) {
        self.down_msgs += 1;
        self.down_bytes += bytes as u64;
    }

    /// Fraction of uplink gradient bytes saved vs the unmasked schedule
    /// (the paper's communication-reduction claim, measured).
    pub fn grad_savings(&self) -> f64 {
        if self.dense_up_bytes == 0 {
            return 0.0;
        }
        1.0 - self.up_bytes as f64 / self.dense_up_bytes as f64
    }

    /// Total bytes moved (uplink + downlink).
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }
}

/// Elementwise-add `grads` into dense accumulators `acc` (canonical
/// tensor order). The ring reduce leg uses this for a worker's own
/// contribution so every summation on the exchange path shares one
/// implementation — and one floating-point evaluation order.
pub fn accumulate(acc: &mut [Tensor], grads: &[Tensor]) {
    assert_eq!(acc.len(), grads.len(), "tensor count");
    for (a, g) in acc.iter_mut().zip(grads) {
        let ad = a.data_mut();
        let gd = g.data();
        debug_assert_eq!(ad.len(), gd.len(), "tensor shape");
        for (x, &v) in ad.iter_mut().zip(gd.iter()) {
            *x += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, NativeSpec};
    use crate::data::{DatasetSpec, SyntheticKind};
    use crate::runtime::ModelConfig;

    fn spec() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xFEED,
            threads: 1,
        }
    }

    fn masks_with(bwd_off: &[(usize, usize)], fwd_off: &[(usize, usize)]) -> MaskPair {
        let mut m = MaskPair::ones(2, 2);
        for &(l, h) in bwd_off {
            m.bwd.set(&[l, h], 0.0);
        }
        for &(l, h) in fwd_off {
            m.fwd.set(&[l, h], 0.0);
            m.bwd.set(&[l, h], 0.0);
        }
        m
    }

    #[test]
    fn masked_message_is_smaller_and_lossless() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        // One p_o head and one p_s head -> two heads' slices off-wire.
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let msg = codec.encode(3, &masks, &grads);
        assert_eq!(msg.len(), codec.encoded_len(&masks));
        assert!(codec.encoded_len(&masks) < codec.dense_len(), "mask must shrink the wire");
        // Decode into zeros reconstructs the dense gradient bit-for-bit.
        let mut acc = be.zeros_like_params();
        let micro = codec.decode_add(&msg, &masks, &mut acc).unwrap();
        assert_eq!(micro, 3);
        for (i, (a, g)) in acc.iter().zip(&grads).enumerate() {
            assert_eq!(a.data(), g.data(), "param {i} reconstruction");
        }
        // Fingerprint mismatch is rejected.
        let other = MaskPair::ones(2, 2);
        assert!(codec.decode_add(&msg, &other, &mut acc).is_err());
    }

    #[test]
    fn dense_and_all_ones_agree() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let ones = MaskPair::ones(2, 2);
        assert_eq!(codec.encoded_len(&ones), codec.dense_len());
        // Fully-masked batch ships only the shared (non-head) slices.
        let none = masks_with(&[], &[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(codec.encoded_len(&none) < codec.dense_len());
        assert!(codec.payload_elems(&none) > 0, "embeddings/classifier still ship");
    }

    #[test]
    fn lora_codec_ships_only_adapters_and_head() {
        let be = NativeBackend::new(&spec(), 2, 2, 3);
        let codec = GradCodec::new(&be);
        let dense = codec.dense_len();
        let full_ft = GradCodec::new(&NativeBackend::new(&spec(), 0, 2, 3)).dense_len();
        assert!(
            dense < full_ft,
            "LoRA wire ({dense}B) must be far below full fine-tuning ({full_ft}B)"
        );
    }

    #[test]
    fn dense_delta_round_trip() {
        let mut be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let deltas = be.update_capture(&grads, 0.05);
        let blob = codec.encode_dense(&deltas);
        let back = codec.decode_dense(&blob).unwrap();
        for (d, b) in deltas.iter().zip(&back) {
            assert_eq!(d.data(), b.data());
        }
    }

    #[test]
    fn f16_conversion_round_trips_and_rounds_to_nearest() {
        // Exactly-representable values survive bit-perfect.
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.5, 1024.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "exact {v}");
        }
        // General values: relative error bounded by half an ulp (2^-11).
        for v in [0.333f32, -7.123, 1e-3, 123.456, -0.9999, 3.146] {
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!(
                (r - v).abs() <= v.abs() * 4.9e-4 + 1e-7,
                "f16 round trip of {v} gave {r}"
            );
        }
        // Overflow saturates to inf; tiny values flush through
        // subnormals to zero; NaN stays NaN; signs survive.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e6)), f32::NEG_INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        let sub = f16_bits_to_f32(f32_to_f16_bits(3e-6));
        assert!(sub > 0.0 && (sub - 3e-6).abs() < 6e-8, "subnormal {sub}");
        // Round-to-nearest-even at the half-ulp boundary: 1 + 2^-11 is
        // exactly between 1.0 and the next f16 (1 + 2^-10) — ties to
        // the even mantissa, i.e. 1.0.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 4.8828125e-4)), 1.0);
        // 1 + 3 * 2^-11 ties upward (odd neighbor below, even above).
        assert_eq!(
            f16_bits_to_f32(f32_to_f16_bits(1.0 + 3.0 * 4.8828125e-4)),
            1.0 + 2.0 * 9.765625e-4
        );
    }

    #[test]
    fn f16_wire_halves_bytes_and_decodes_within_tolerance() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let f32c = GradCodec::new(&be);
        let f16c = GradCodec::new(&be).with_precision(WirePrecision::F16);
        assert_eq!(f16c.precision(), WirePrecision::F16);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let m32 = f32c.encode(2, &masks, &grads);
        let m16 = f16c.encode(2, &masks, &grads);
        // Byte halving, measured on the real messages via WireStats.
        let elems = f32c.payload_elems(&masks);
        assert_eq!(m32.len(), HEADER_BYTES + 4 * elems);
        assert_eq!(m16.len(), HEADER_BYTES + 2 * elems);
        let mut s32 = WireStats::default();
        let mut s16 = WireStats::default();
        s32.record_up(m32.len(), f32c.dense_len());
        s16.record_up(m16.len(), f16c.dense_len());
        assert!(
            s16.up_bytes < s32.up_bytes && (s16.up_bytes as f64) < 0.51 * s32.up_bytes as f64,
            "f16 must roughly halve the uplink: {} vs {}",
            s16.up_bytes,
            s32.up_bytes
        );
        // Round trip within binary16 tolerance.
        let mut acc = be.zeros_like_params();
        let micro = f16c.decode_add(&m16, &masks, &mut acc).unwrap();
        assert_eq!(micro, 2);
        for (a, g) in acc.iter().zip(&grads) {
            for (&va, &vg) in a.data().iter().zip(g.data()) {
                assert!(
                    (va - vg).abs() <= vg.abs() * 1e-3 + 1e-6,
                    "f16 decode {va} vs {vg}"
                );
            }
        }
        // Precision mismatch is caught by the header flags, both ways.
        assert!(f32c.decode_add(&m16, &masks, &mut acc).is_err());
        assert!(f16c.decode_add(&m32, &masks, &mut acc).is_err());
        // Dense delta path honors precision too.
        let deltas = f16c.decode_dense(&f16c.encode_dense(&be.zeros_like_params())).unwrap();
        assert_eq!(deltas.len(), be.n_param_tensors());
        assert!(f32c.decode_dense(&f16c.encode_dense(&be.zeros_like_params())).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer_capacity() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let mut buf = Vec::new();
        codec.encode_into(0, &masks, &grads, &mut buf);
        let first = buf.clone();
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        // Re-encoding into the same buffer must not reallocate (same
        // capacity, same backing pointer) and must produce the bytes
        // `encode` would.
        codec.encode_into(0, &masks, &grads, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(buf.capacity(), cap, "steady-state encode must not grow");
        assert_eq!(buf.as_ptr(), ptr, "steady-state encode must not reallocate");
        assert_eq!(buf, codec.encode(0, &masks, &grads));
    }

    #[test]
    fn encode_append_embeds_a_verbatim_message_after_a_prefix() {
        // The transport frames embed gradient messages as tails: the
        // appended bytes must equal a standalone encode, decodable in
        // place from the offset.
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let mut frame = vec![9, 9, 9];
        codec.encode_append(1, &masks, &grads, &mut frame);
        assert_eq!(&frame[..3], &[9, 9, 9]);
        assert_eq!(&frame[3..], &codec.encode(1, &masks, &grads)[..]);
        let mut acc = be.zeros_like_params();
        assert_eq!(codec.decode_add(&frame[3..], &masks, &mut acc).unwrap(), 1);
        // Dense variant behaves the same way.
        let deltas = be.zeros_like_params();
        let mut dframe = vec![7];
        codec.encode_dense_append(&deltas, &mut dframe);
        assert_eq!(&dframe[1..], &codec.encode_dense(&deltas)[..]);
    }

    #[test]
    fn buf_pool_recycles_after_warmup() {
        let pool = BufPool::new();
        let a = pool.checkout();
        let b = pool.checkout();
        assert_eq!(pool.fresh_allocs(), 2);
        assert_eq!(pool.reuses(), 0);
        let mut a = a;
        a.extend_from_slice(&[1, 2, 3]);
        pool.give_back(a);
        pool.give_back(b);
        let c = pool.checkout();
        assert!(c.is_empty(), "recycled buffers come back cleared");
        assert_eq!(pool.fresh_allocs(), 2, "steady state: no new allocations");
        assert_eq!(pool.reuses(), 1);
    }

    #[test]
    fn wire_precision_parses() {
        assert_eq!(WirePrecision::parse("f32").unwrap(), WirePrecision::F32);
        assert_eq!(WirePrecision::parse("FP16").unwrap(), WirePrecision::F16);
        assert_eq!(WirePrecision::parse("half").unwrap(), WirePrecision::F16);
        assert!(WirePrecision::parse("bf16").is_err());
        assert_eq!(WirePrecision::F16.label(), "f16");
        assert_eq!(WirePrecision::default(), WirePrecision::F32);
    }

    #[test]
    fn wire_stats_savings() {
        let mut s = WireStats::default();
        s.record_up(600, 1000);
        s.record_up(400, 1000);
        s.record_down(1000);
        assert_eq!(s.up_msgs, 2);
        assert_eq!(s.total_bytes(), 2000);
        assert!((s.grad_savings() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn wire_compression_parses_and_flags() {
        use WireCompression as WC;
        assert_eq!(WC::parse("none").unwrap(), WC::None);
        assert_eq!(WC::parse("OFF").unwrap(), WC::None);
        assert_eq!(WC::parse("int8").unwrap(), WC::Int8);
        assert_eq!(WC::parse("q4").unwrap(), WC::Int4);
        assert_eq!(WC::parse("topk").unwrap(), WC::TopK { pct: 10 });
        assert_eq!(WC::parse("TopK:25").unwrap(), WC::TopK { pct: 25 });
        assert!(WC::parse("topk:0").is_err());
        assert!(WC::parse("topk:101").is_err());
        assert!(WC::parse("gzip").is_err());
        assert_eq!(WC::TopK { pct: 25 }.label(), "topk:25");
        assert!(!WC::None.is_lossy() && WC::Int4.is_lossy());
        assert_eq!(WC::default(), WC::None);
        // The kept percentage rides in the flag word, so a pct
        // disagreement rejects like any other format mismatch.
        assert_ne!(WC::TopK { pct: 10 }.flags(), WC::TopK { pct: 25 }.flags());
    }

    #[test]
    fn varint_round_trips_and_rejects_malformed() {
        crate::util::proptest::check("varint-round-trip", 200, |g| {
            let v = g.rng().next_u64() >> g.usize_in(0, 63);
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut off = 0usize;
            let back = get_varint(&buf, &mut off).map_err(|e| e.to_string())?;
            if back != v || off != buf.len() {
                return Err(format!("{v} -> {back} (consumed {off}/{})", buf.len()));
            }
            Ok(())
        });
        // Truncated and overlong streams reject instead of panicking.
        assert!(get_varint(&[0x80], &mut 0).is_err());
        assert!(get_varint(&[0x80u8; 12], &mut 0).is_err());
    }

    #[test]
    fn quantization_error_is_bounded_by_half_a_step() {
        crate::util::proptest::check("quant-error-bound", 100, |g| {
            let n = g.usize_in(1, 64);
            let amp = g.f32_in(1e-6, 10.0);
            let vals = g.vec(n, |g| g.f32_in(-1.0, 1.0) * amp);
            for &levels in &[127.0f32, 7.0] {
                let scale = quant_scale(&vals, levels);
                for &v in &vals {
                    let deq = quant_code(v, scale, levels) as f32 * scale;
                    let bound = 0.5 * scale * (1.0 + 1e-5) + 1e-12;
                    if (deq - v).abs() > bound {
                        return Err(format!(
                            "levels {levels}: {v} -> {deq} (scale {scale})"
                        ));
                    }
                }
            }
            Ok(())
        });
        // All-zero slices quantize to code 0 under a zero scale.
        assert_eq!(quant_scale(&[0.0, -0.0], 127.0), 0.0);
        assert_eq!(quant_code(0.3, 0.0, 127.0), 0);
    }

    #[test]
    fn int8_and_int4_round_trip_within_quantization_error() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let f32c = GradCodec::new(&be);
        let base = f32c.encode(0, &masks, &grads).len();
        for (mode, levels, floor) in [
            (WireCompression::Int8, 127.0f32, 3.5),
            (WireCompression::Int4, 7.0, 6.0),
        ] {
            let codec = GradCodec::new(&be).with_compression(mode);
            assert_eq!(codec.compression(), mode);
            let msg = codec.encode(0, &masks, &grads);
            assert_eq!(msg.len(), codec.encoded_len(&masks), "{mode:?} declared size");
            let ratio = base as f64 / msg.len() as f64;
            assert!(ratio >= floor, "{mode:?} ratio {ratio:.2} below {floor}");
            let mut acc = be.zeros_like_params();
            assert_eq!(codec.decode_add(&msg, &masks, &mut acc).unwrap(), 0);
            // Per-element error bounded by half a quantization step of
            // the owning tensor's scale (range max <= tensor max).
            for (i, (a, g)) in acc.iter().zip(&grads).enumerate() {
                let max = g.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let bound = max / levels * 0.5001 + 1e-9;
                for (&va, &vg) in a.data().iter().zip(g.data()) {
                    assert!(
                        (va - vg).abs() <= bound,
                        "{mode:?} param {i}: {va} vs {vg} (bound {bound})"
                    );
                }
            }
            // Compression mismatch rejects in both directions.
            assert!(f32c.decode_add(&msg, &masks, &mut acc).is_err());
            let plain = f32c.encode(0, &masks, &grads);
            assert!(codec.decode_add(&plain, &masks, &mut acc).is_err());
        }
    }

    #[test]
    fn topk_round_trips_and_keeps_the_largest() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        // pct=100 keeps everything: decode reconstructs bit-for-bit.
        let full = GradCodec::new(&be).with_compression(WireCompression::TopK { pct: 100 });
        let msg = full.encode(5, &masks, &grads);
        assert!(msg.len() <= full.encoded_len(&masks), "bound must hold");
        let mut acc = be.zeros_like_params();
        assert_eq!(full.decode_add(&msg, &masks, &mut acc).unwrap(), 5);
        for (a, g) in acc.iter().zip(&grads) {
            assert_eq!(a.data(), g.data(), "pct=100 is lossless");
        }
        // pct=10 ships ~10% of the elements and every decoded value
        // matches its original exactly (f32 wire); dropped ones are 0.
        let sparse = GradCodec::new(&be).with_compression(WireCompression::TopK { pct: 10 });
        let msg = sparse.encode(0, &masks, &grads);
        let plain = GradCodec::new(&be).encode(0, &masks, &grads);
        let ratio = plain.len() as f64 / msg.len() as f64;
        assert!(ratio >= 5.0, "topk:10 ratio {ratio:.2} below 5x");
        let mut acc = be.zeros_like_params();
        sparse.decode_add(&msg, &masks, &mut acc).unwrap();
        let (mut kept, mut dropped, mut mismatched) = (0u64, 0u64, 0u64);
        let mut min_kept = f32::INFINITY;
        let mut max_dropped = 0.0f32;
        for (a, g) in acc.iter().zip(&grads) {
            for (&va, &vg) in a.data().iter().zip(g.data()) {
                if va != 0.0 {
                    kept += 1;
                    min_kept = min_kept.min(va.abs());
                    if va != vg {
                        mismatched += 1;
                    }
                } else if vg != 0.0 {
                    dropped += 1;
                    max_dropped = max_dropped.max(vg.abs());
                }
            }
        }
        assert_eq!(mismatched, 0, "kept values must be verbatim");
        assert!(kept > 0 && dropped > 0, "10% must keep some, drop some");
        assert!(
            min_kept >= max_dropped,
            "selection must be by magnitude: kept {min_kept} < dropped {max_dropped}"
        );
    }

    #[test]
    fn error_feedback_residual_preserves_the_gradient_sum() {
        // EF identity: sent_t = Q(g + r_(t-1)), r_t = (g + r_(t-1)) -
        // sent_t, so sum(sent) + r_T telescopes to T*g. Decoding every
        // message and adding the final residual must reproduce the
        // accumulated true gradient to float tolerance — the bounded-
        // staleness property that keeps lossy modes trainable.
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        for mode in [
            WireCompression::Int8,
            WireCompression::Int4,
            WireCompression::TopK { pct: 10 },
        ] {
            let codec = GradCodec::new(&be).with_compression(mode);
            let mut ef = be.zeros_like_params();
            let mut acc = be.zeros_like_params();
            let steps = 5usize;
            for s in 0..steps {
                let mut msg = Vec::new();
                codec.encode_append_ef(s, &masks, &grads, Some(&mut ef), &mut msg);
                codec.decode_add(&msg, &masks, &mut acc).unwrap();
            }
            accumulate(&mut acc, &ef);
            for (pi, (a, g)) in acc.iter().zip(&grads).enumerate() {
                let max = g.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let tol = max * 1e-4 + 1e-6;
                for (&va, &vg) in a.data().iter().zip(g.data()) {
                    let want = vg * steps as f32;
                    assert!(
                        (va - want).abs() <= tol * steps as f32,
                        "{mode:?} param {pi}: {va} vs {want}"
                    );
                }
            }
            // And the residual actually engages: for the lossy modes a
            // single EF-encoded message differs from a plain one once a
            // residual is pending.
            let plain = codec.encode(0, &masks, &grads);
            let mut withef = Vec::new();
            codec.encode_append_ef(0, &masks, &grads, Some(&mut ef), &mut withef);
            assert_ne!(plain, withef, "{mode:?}: pending residual must alter the wire");
        }
    }

    #[test]
    fn malformed_compressed_messages_reject_without_panicking() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let codec = GradCodec::new(&be).with_compression(WireCompression::TopK { pct: 10 });
        let good = codec.encode(0, &masks, &grads);
        let mut acc = be.zeros_like_params();
        // Every truncation of the valid message must error cleanly.
        for cut in [0, 4, HEADER_BYTES, HEADER_BYTES + 3, good.len() - 1] {
            assert!(
                codec.decode_add(&good[..cut], &masks, &mut acc).is_err(),
                "truncated at {cut}"
            );
        }
        // Corrupt the top-k count and the index stream.
        let mut bad = good.clone();
        bad[HEADER_BYTES] ^= 0xFF;
        assert!(codec.decode_add(&bad, &masks, &mut acc).is_err(), "bad k");
        // Synthetic message with a repeated index (delta 0): the
        // strictly-increasing check must reject before any apply.
        let k = u64::from_le_bytes(good[HEADER_BYTES..HEADER_BYTES + 8].try_into().unwrap());
        assert!(k >= 2, "model too small for a meaningful top-k test");
        let mut bad = good[..HEADER_BYTES + 8].to_vec();
        bad.resize(bad.len() + k as usize, 0u8);
        assert!(codec.decode_add(&bad, &masks, &mut acc).is_err(), "repeated index");
        // Int8: every wrong-length variant of a valid message rejects.
        let codec8 = GradCodec::new(&be).with_compression(WireCompression::Int8);
        let good8 = codec8.encode(0, &masks, &grads);
        let mut acc8 = be.zeros_like_params();
        assert!(codec8.decode_add(&good8[..good8.len() - 1], &masks, &mut acc8).is_err());
        let mut long = good8.clone();
        long.push(0);
        assert!(codec8.decode_add(&long, &masks, &mut acc8).is_err());
        // Sanity: the untouched messages still decode after all that.
        assert_eq!(codec.decode_add(&good, &masks, &mut acc).unwrap(), 0);
        assert_eq!(codec8.decode_add(&good8, &masks, &mut acc8).unwrap(), 0);
    }

    #[test]
    fn accumulate_adds_elementwise() {
        let mut acc = vec![Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0])];
        let g = vec![Tensor::from_vec(&[3], vec![0.5, -2.0, 1.0])];
        accumulate(&mut acc, &g);
        assert_eq!(acc[0].data(), &[1.5, 0.0, 4.0]);
    }
}
