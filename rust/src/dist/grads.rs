//! Masked-gradient wire format: serialize exactly the parameter slices
//! a schedule leaves trainable, nothing else.
//!
//! ## Why no index structure
//!
//! D2FT's schedule is computed centrally and known to every node before
//! the batch runs, so sender and receiver can both derive the payload
//! layout from `(model structure, MaskPair)`. A message is therefore a
//! 24-byte header plus raw little-endian f32s in canonical order — the
//! densest encoding the mask admits, which makes the byte accounting an
//! honest measurement of the paper's communication claim rather than a
//! property of a clever container format. A mask fingerprint in the
//! header catches sender/receiver schedule divergence.
//!
//! ## What ships
//!
//! Per parameter tensor (canonical sorted-name order):
//!
//! * non-trainable tensors (LoRA-frozen base weights) — never ship;
//! * *shared* elements (embeddings, layer norms, classifier — owned by
//!   no head) — always ship;
//! * elements owned by subnet (block `l`, head `h`) — ship iff the
//!   backward mask is 1 for that head (`p_f`). `p_o` and `p_s` heads
//!   ship nothing: the backend's freeze contract guarantees those
//!   gradient slices are exactly zero, so dropping them is lossless —
//!   [`GradCodec::decode_add`] of an encoded message reconstructs the
//!   dense gradient bit-for-bit (`tests/dist.rs` pins this property).

use anyhow::Result;

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

/// Message magic: "D2FG" (masked gradient payload).
const MAGIC_GRAD: u32 = 0x4432_4647;
/// Message magic: "D2FD" (dense delta payload, parameter-server mode).
const MAGIC_DELTA: u32 = 0x4432_4644;
/// Header: magic u32, micro u32, mask fingerprint u64, payload elems u64.
const HEADER_BYTES: usize = 24;

/// Owner tag for elements belonging to no head.
const SHARED: u32 = u32::MAX;

/// A contiguous `[lo, hi)` element range within one parameter tensor.
type Range = (usize, usize);

#[derive(Clone, Debug)]
struct ParamLayout {
    /// False for LoRA-frozen base weights — never on the wire.
    trainable: bool,
    /// Total element count of the tensor.
    len: usize,
    /// Maximal runs owned by no head (ship whenever trainable).
    shared: Vec<Range>,
    /// Maximal runs owned by subnet `l * heads + h`.
    per_head: Vec<Vec<Range>>,
}

/// Encoder/decoder for masked gradient messages, specialized to one
/// model instance. Construction walks the backend's per-head parameter
/// ownership map once; encode/decode are then pure range copies.
#[derive(Clone, Debug)]
pub struct GradCodec {
    depth: usize,
    heads: usize,
    params: Vec<ParamLayout>,
    /// Total trainable elements (the dense message payload).
    dense_elems: usize,
}

impl GradCodec {
    /// Build the codec for `be`'s exact parameter layout (LoRA rank,
    /// depth, heads). Replicas built from the same spec share a layout,
    /// so one codec serves a whole cluster.
    pub fn new(be: &NativeBackend) -> GradCodec {
        let cfg = be.config();
        let (depth, heads) = (cfg.depth, cfg.heads);
        let n = be.n_param_tensors();
        let mut owner: Vec<Vec<u32>> =
            (0..n).map(|i| vec![SHARED; be.param_elems(i)]).collect();
        for l in 0..depth {
            for h in 0..heads {
                let tag = (l * heads + h) as u32;
                be.visit_head_elems(l, h, &mut |pi, ei| {
                    debug_assert_eq!(owner[pi][ei], SHARED, "element owned twice");
                    owner[pi][ei] = tag;
                });
            }
        }
        let trainable = be.trainable_flags();
        let mut params = Vec::with_capacity(n);
        let mut dense_elems = 0usize;
        for (pi, own) in owner.iter().enumerate() {
            let mut shared = Vec::new();
            let mut per_head: Vec<Vec<Range>> = vec![Vec::new(); depth * heads];
            let mut i = 0;
            while i < own.len() {
                let tag = own[i];
                let mut j = i + 1;
                while j < own.len() && own[j] == tag {
                    j += 1;
                }
                if tag == SHARED {
                    shared.push((i, j));
                } else {
                    per_head[tag as usize].push((i, j));
                }
                i = j;
            }
            if trainable[pi] {
                dense_elems += own.len();
            }
            params.push(ParamLayout {
                trainable: trainable[pi],
                len: own.len(),
                shared,
                per_head,
            });
        }
        GradCodec { depth, heads, params, dense_elems }
    }

    /// Which subnets ship under `masks`: a head's slices travel iff its
    /// backward mask is 1 (only `p_f` produces nonzero gradients there).
    fn active(&self, masks: &MaskPair) -> Vec<bool> {
        assert_eq!(
            masks.bwd.shape(),
            &[self.depth, self.heads],
            "mask shape vs codec model"
        );
        let mut v = vec![false; self.depth * self.heads];
        for l in 0..self.depth {
            for h in 0..self.heads {
                v[l * self.heads + h] = masks.bwd.at(&[l, h]) >= 0.5;
            }
        }
        v
    }

    /// Payload element count for a precomputed activity vector.
    fn payload_elems_with(&self, act: &[bool]) -> usize {
        let mut n = 0usize;
        for p in &self.params {
            if !p.trainable {
                continue;
            }
            n += p.shared.iter().map(|r| r.1 - r.0).sum::<usize>();
            for (t, ranges) in p.per_head.iter().enumerate() {
                if act[t] {
                    n += ranges.iter().map(|r| r.1 - r.0).sum::<usize>();
                }
            }
        }
        n
    }

    /// Payload element count of one message under `masks`.
    pub fn payload_elems(&self, masks: &MaskPair) -> usize {
        self.payload_elems_with(&self.active(masks))
    }

    /// Encoded byte size of one message under `masks`.
    pub fn encoded_len(&self, masks: &MaskPair) -> usize {
        HEADER_BYTES + 4 * self.payload_elems(masks)
    }

    /// Byte size of a dense (every head active) message — what one
    /// micro-batch of the full, unmasked schedule ships.
    pub fn dense_len(&self) -> usize {
        HEADER_BYTES + 4 * self.dense_elems
    }

    /// Serialize the gradient slices `masks` leaves trainable. `grads`
    /// must be the backend's dense gradients in canonical order (one
    /// tensor per parameter).
    pub fn encode(&self, micro: usize, masks: &MaskPair, grads: &[Tensor]) -> Vec<u8> {
        assert_eq!(grads.len(), self.params.len(), "grad tensor count");
        // One layout walk serves capacity, header, and body.
        let act = self.active(masks);
        let n_elems = self.payload_elems_with(&act);
        let mut out = Vec::with_capacity(HEADER_BYTES + 4 * n_elems);
        out.extend_from_slice(&MAGIC_GRAD.to_le_bytes());
        out.extend_from_slice(&(micro as u32).to_le_bytes());
        out.extend_from_slice(&masks.fingerprint().to_le_bytes());
        out.extend_from_slice(&(n_elems as u64).to_le_bytes());
        for (p, g) in self.params.iter().zip(grads) {
            if !p.trainable {
                continue;
            }
            debug_assert_eq!(g.len(), p.len, "grad shape vs layout");
            let gd = g.data();
            for &(lo, hi) in &p.shared {
                for &v in &gd[lo..hi] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    for &v in &gd[lo..hi] {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Decode a message and **add** its payload into dense accumulators
    /// (canonical order, e.g. from
    /// [`NativeBackend::zeros_like_params`]). Elements the mask excluded
    /// are untouched — with a zeroed accumulator this reconstructs the
    /// sender's dense gradient exactly, because excluded slices were
    /// exactly zero. Returns the message's micro-batch index.
    pub fn decode_add(
        &self,
        bytes: &[u8],
        masks: &MaskPair,
        acc: &mut [Tensor],
    ) -> Result<usize> {
        anyhow::ensure!(acc.len() == self.params.len(), "accumulator count");
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let word = |lo: usize| -> [u8; 4] { bytes[lo..lo + 4].try_into().unwrap() };
        let magic = u32::from_le_bytes(word(0));
        anyhow::ensure!(magic == MAGIC_GRAD, "bad gradient-message magic {magic:#x}");
        let micro = u32::from_le_bytes(word(4)) as usize;
        let fp = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        anyhow::ensure!(
            fp == masks.fingerprint(),
            "mask fingerprint mismatch: sender and receiver disagree on the schedule"
        );
        let act = self.active(masks);
        let expect = self.payload_elems_with(&act);
        let n_elems = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == expect,
            "payload {n_elems} elems, layout expects {expect}"
        );
        anyhow::ensure!(
            bytes.len() == HEADER_BYTES + 4 * n_elems,
            "message length {} vs declared payload {}",
            bytes.len(),
            n_elems
        );
        let mut off = HEADER_BYTES;
        for (p, a) in self.params.iter().zip(acc.iter_mut()) {
            if !p.trainable {
                continue;
            }
            let ad = a.data_mut();
            for &(lo, hi) in &p.shared {
                for x in &mut ad[lo..hi] {
                    *x += f32::from_le_bytes(word(off));
                    off += 4;
                }
            }
            for (t, ranges) in p.per_head.iter().enumerate() {
                if !act[t] {
                    continue;
                }
                for &(lo, hi) in ranges {
                    for x in &mut ad[lo..hi] {
                        *x += f32::from_le_bytes(word(off));
                        off += 4;
                    }
                }
            }
        }
        Ok(micro)
    }

    /// Serialize dense per-parameter values for every trainable tensor —
    /// the parameter-server downlink (update deltas). `vals[i]` must
    /// have the parameter's full element count for trainable `i`
    /// (non-trainable entries are ignored).
    pub fn encode_dense(&self, vals: &[Tensor]) -> Vec<u8> {
        assert_eq!(vals.len(), self.params.len(), "value tensor count");
        let mut out = Vec::with_capacity(HEADER_BYTES + 4 * self.dense_elems);
        out.extend_from_slice(&MAGIC_DELTA.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&(self.dense_elems as u64).to_le_bytes());
        for (p, v) in self.params.iter().zip(vals) {
            if !p.trainable {
                continue;
            }
            assert_eq!(v.len(), p.len, "dense payload size");
            for &x in v.data() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Decode a dense payload into per-parameter tensors (1-D; zero
    /// length for non-trainable entries, mirroring
    /// [`NativeBackend::update_capture`]).
    pub fn decode_dense(&self, bytes: &[u8]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(bytes.len() >= HEADER_BYTES, "message shorter than header");
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        anyhow::ensure!(magic == MAGIC_DELTA, "bad delta-message magic {magic:#x}");
        let n_elems = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n_elems == self.dense_elems && bytes.len() == HEADER_BYTES + 4 * n_elems,
            "dense payload size mismatch"
        );
        let mut off = HEADER_BYTES;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            if !p.trainable {
                out.push(Tensor::zeros(&[0]));
                continue;
            }
            let mut v = vec![0.0f32; p.len];
            for x in &mut v {
                *x = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                off += 4;
            }
            out.push(Tensor::from_vec(&[p.len], v));
        }
        Ok(out)
    }
}

/// Running bytes-on-the-wire accounting for one distributed run.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Uplink gradient messages (worker -> aggregator).
    pub up_msgs: u64,
    /// Uplink bytes actually serialized.
    pub up_bytes: u64,
    /// What the same messages would have cost unmasked (dense).
    pub dense_up_bytes: u64,
    /// Downlink broadcasts (aggregator -> worker).
    pub down_msgs: u64,
    /// Downlink bytes actually serialized.
    pub down_bytes: u64,
}

impl WireStats {
    /// Record one uplink gradient message of `bytes` against a dense
    /// baseline of `dense` bytes.
    pub fn record_up(&mut self, bytes: usize, dense: usize) {
        self.up_msgs += 1;
        self.up_bytes += bytes as u64;
        self.dense_up_bytes += dense as u64;
    }

    /// Record one downlink broadcast message.
    pub fn record_down(&mut self, bytes: usize) {
        self.down_msgs += 1;
        self.down_bytes += bytes as u64;
    }

    /// Fraction of uplink gradient bytes saved vs the unmasked schedule
    /// (the paper's communication-reduction claim, measured).
    pub fn grad_savings(&self) -> f64 {
        if self.dense_up_bytes == 0 {
            return 0.0;
        }
        1.0 - self.up_bytes as f64 / self.dense_up_bytes as f64
    }

    /// Total bytes moved (uplink + downlink).
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeBackend, NativeSpec};
    use crate::data::{DatasetSpec, SyntheticKind};
    use crate::runtime::ModelConfig;

    fn spec() -> NativeSpec {
        NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xFEED,
        }
    }

    fn masks_with(bwd_off: &[(usize, usize)], fwd_off: &[(usize, usize)]) -> MaskPair {
        let mut m = MaskPair::ones(2, 2);
        for &(l, h) in bwd_off {
            m.bwd.set(&[l, h], 0.0);
        }
        for &(l, h) in fwd_off {
            m.fwd.set(&[l, h], 0.0);
            m.bwd.set(&[l, h], 0.0);
        }
        m
    }

    #[test]
    fn masked_message_is_smaller_and_lossless() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        // One p_o head and one p_s head -> two heads' slices off-wire.
        let masks = masks_with(&[(0, 1)], &[(1, 0)]);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let msg = codec.encode(3, &masks, &grads);
        assert_eq!(msg.len(), codec.encoded_len(&masks));
        assert!(codec.encoded_len(&masks) < codec.dense_len(), "mask must shrink the wire");
        // Decode into zeros reconstructs the dense gradient bit-for-bit.
        let mut acc = be.zeros_like_params();
        let micro = codec.decode_add(&msg, &masks, &mut acc).unwrap();
        assert_eq!(micro, 3);
        for (i, (a, g)) in acc.iter().zip(&grads).enumerate() {
            assert_eq!(a.data(), g.data(), "param {i} reconstruction");
        }
        // Fingerprint mismatch is rejected.
        let other = MaskPair::ones(2, 2);
        assert!(codec.decode_add(&msg, &other, &mut acc).is_err());
    }

    #[test]
    fn dense_and_all_ones_agree() {
        let be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let ones = MaskPair::ones(2, 2);
        assert_eq!(codec.encoded_len(&ones), codec.dense_len());
        // Fully-masked batch ships only the shared (non-head) slices.
        let none = masks_with(&[], &[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(codec.encoded_len(&none) < codec.dense_len());
        assert!(codec.payload_elems(&none) > 0, "embeddings/classifier still ship");
    }

    #[test]
    fn lora_codec_ships_only_adapters_and_head() {
        let be = NativeBackend::new(&spec(), 2, 2, 3);
        let codec = GradCodec::new(&be);
        let dense = codec.dense_len();
        let full_ft = GradCodec::new(&NativeBackend::new(&spec(), 0, 2, 3)).dense_len();
        assert!(
            dense < full_ft,
            "LoRA wire ({dense}B) must be far below full fine-tuning ({full_ft}B)"
        );
    }

    #[test]
    fn dense_delta_round_trip() {
        let mut be = NativeBackend::new(&spec(), 0, 2, 3);
        let codec = GradCodec::new(&be);
        let data = DatasetSpec::preset(SyntheticKind::Cifar10Like, 8, 2, 5).generate("train");
        let (x, y) = data.gather(&[0, 1]);
        let masks = MaskPair::ones(2, 2);
        let (_, grads) = be.grad_step(&x, &y, &masks).unwrap();
        let deltas = be.update_capture(&grads, 0.05);
        let blob = codec.encode_dense(&deltas);
        let back = codec.decode_dense(&blob).unwrap();
        for (d, b) in deltas.iter().zip(&back) {
            assert_eq!(d.data(), b.data());
        }
    }

    #[test]
    fn wire_stats_savings() {
        let mut s = WireStats::default();
        s.record_up(600, 1000);
        s.record_up(400, 1000);
        s.record_down(1000);
        assert_eq!(s.up_msgs, 2);
        assert_eq!(s.total_bytes(), 2000);
        assert!((s.grad_savings() - 0.5).abs() < 1e-12);
    }
}
