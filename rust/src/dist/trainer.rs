//! `DistTrainer`: the live data-parallel fine-tuning driver.
//!
//! K worker threads each own a full [`NativeBackend`] replica built from
//! the same deterministic init. Per scheduled batch the aggregator
//! assigns every micro-batch to a worker (straggler-aware, see below),
//! each worker runs the masked forward/backward **for real** against the
//! shared parameter snapshot, serializes the masked gradient
//! ([`super::grads`]), and the aggregator reduces the messages in fixed
//! micro order and applies one fused SGD-momentum update — then either
//! broadcasts the reduced masked gradient (workers re-apply the same
//! update locally) or, in parameter-server mode, the dense update
//! deltas. Channel FIFO ordering doubles as the sync barrier: a worker
//! always installs the batch-`b` update before it sees a batch-`b+1`
//! compute job.
//!
//! ## Determinism
//!
//! Every micro-batch gradient is computed by exactly one worker whose
//! replica is bitwise identical to the serial trainer's model at the
//! same point; the wire format is lossless; the reduction order is
//! fixed. So the whole trajectory — losses, parameters, eval accuracy —
//! is bitwise identical to the serial [`crate::coordinator::Trainer`]
//! under [`UpdateMode::BatchAccum`], for *any* worker count and either
//! exchange mode. Placement (which worker computes which micro-batch)
//! is measured-time dependent and deliberately free: it can shift work
//! away from real stragglers without touching a single bit of the math.
//!
//! ## Pipeline (comm/compute overlap)
//!
//! Each worker splits into a compute thread and a dedicated sender
//! thread joined by a bounded one-slot channel: while task *i*'s
//! gradient is being encoded and uploaded, task *i+1*'s `grad_step`
//! already runs — the double-buffered overlap the simulated
//! [`crate::cluster::Engine`] models, now live. The handoff carries
//! owned gradients (never a view of the replica), the aggregator only
//! broadcasts a batch's update after every uplink of that batch
//! arrived, and the [`OrderedReducer`] fixes the reduction order — so
//! pipelining is bitwise invisible. `DistConfig::overlap = false` keeps
//! the serialized reference path; `benches/dist_step.rs` measures the
//! makespan gap between the two.
//!
//! ## Measurement and calibration
//!
//! Uplink/downlink bytes are counted on the actual serialized messages
//! ([`WireStats`]); per-worker task times are wall-clock measurements
//! around the real gradient computation and feed (a) the assignment
//! balancer (EMA per worker), (b) the workload/usage accounting that
//! the simulated [`crate::cluster::Engine`] previously only modeled,
//! and (c) a per-epoch calibration loop: the measured/modeled makespan
//! ratio rescales the engine's [`ExecTimeModel`] (via
//! `ExecTimeModel::calibrated`) so the modeled accounting tracks this
//! host instead of the paper's V100. The residual modeled-vs-measured
//! drift is reported in `TrainReport::makespan_drift`.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::Result;

use super::allreduce::{ExchangeMode, OrderedReducer};
use super::grads::{BufPool, GradCodec, WirePrecision, WireStats};
use crate::backend::native::{NativeBackend, NativeProvider};
use crate::backend::Backend;
use crate::cluster::{CostModel, Engine, EngineConfig, ExecTimeModel, WorkloadTracker};
use crate::coordinator::{build_scheduler, prepare_run, TrainReport, TrainerConfig, UpdateMode};
use crate::data::{Batcher, Dataset, DatasetSpec, SyntheticKind};
use crate::metrics::{rel_drift, DeviceUsage, Meter};
use crate::partition::Partition;
use crate::schedule::{MaskPair, Scheduler};
use crate::scores::ScoreBook;
use crate::tensor::Tensor;

/// Configuration of one distributed run: the full serial trainer config
/// plus the cluster shape.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// The training run (dataset, schedule, budget, seed, ...). The
    /// update mode is forced to [`UpdateMode::BatchAccum`] — the only
    /// semantics a synchronous data-parallel cluster can implement.
    pub train: TrainerConfig,
    /// Worker replica count (>= 1).
    pub workers: usize,
    /// Gradient exchange topology.
    pub exchange: ExchangeMode,
    /// Pipeline each worker's encode + upload of task *i* behind task
    /// *i+1*'s gradient computation (a dedicated sender thread per
    /// worker, double-buffered handoff). Default `true`; `false` is the
    /// serialized reference path — `benches/dist_step.rs` measures the
    /// gap. Bitwise-neutral either way (the bytes are identical and the
    /// reduction order is fixed).
    pub overlap: bool,
    /// Gradient payload precision on the wire. The `F32` default is
    /// lossless (bitwise serial ≡ dist). `F16` halves the measured
    /// bytes; the aggregate gradient is then requantized before
    /// *anyone* (aggregator included) applies it, so all replicas still
    /// agree bitwise with each other — only with the serial trainer do
    /// they diverge. Masked-allreduce only.
    pub wire_precision: WirePrecision,
    /// Simulated NIC cost in milliseconds per MiB of *actual encoded
    /// message*, slept on the uplink path (sender thread when
    /// overlapping, compute thread when serialized). 0 disables it.
    /// This is a bench/experiment knob: in-process channels are
    /// effectively free, so hiding a modeled wire behind compute is how
    /// the comm/compute-overlap claim becomes measurable on one host.
    pub sim_wire_ms_per_mib: f64,
    /// Recalibrate the modeled [`ExecTimeModel`] from measured per-task
    /// times at every epoch boundary (see `DistReport::train`'s
    /// `calib_*` fields). Default `true`; scheduling decisions are
    /// placement-only, so calibration never touches the numerics.
    pub calibrate: bool,
}

impl DistConfig {
    /// Masked-allreduce cluster of `workers` replicas with the default
    /// performance knobs: overlap on, lossless f32 wire, no simulated
    /// NIC, calibration on.
    pub fn new(train: TrainerConfig, workers: usize) -> DistConfig {
        DistConfig {
            train,
            workers,
            exchange: ExchangeMode::MaskedAllReduce,
            overlap: true,
            wire_precision: WirePrecision::F32,
            sim_wire_ms_per_mib: 0.0,
            calibrate: true,
        }
    }
}

/// Outcome of a distributed run: the serial-comparable training report
/// plus the measured wire and straggler data.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// The standard training report (losses, accuracy, modeled cluster
    /// metrics), field-compatible with the serial trainer's. The
    /// `straggler_ms` field here is the *real* per-batch straggler: the
    /// slowest worker's measured gradient-computation time.
    pub train: TrainReport,
    /// Worker replicas that executed the run.
    pub n_workers: usize,
    /// Exchange topology label (`masked-allreduce` / `param-server`).
    pub exchange: String,
    /// Measured bytes on the wire for the *scheduled fine-tuning*
    /// batches (actual serialized messages) — the traffic the paper's
    /// communication claim is about.
    pub wire: WireStats,
    /// Measured bytes for the synthetic pre-training phase (all-ones
    /// masks, so uplink is always dense). Kept separate so
    /// [`DistReport::grad_savings`] and the measured-vs-modeled
    /// comparison are not diluted by unscheduled traffic.
    pub pretrain_wire: WireStats,
    /// Uplink gradient bytes saved vs the unmasked schedule (measured).
    pub grad_savings: f64,
    /// What the simulated engine *modeled* for the same schedules, for
    /// the measured-vs-modeled comparison (DESIGN.md §dist).
    pub modeled_wire_bytes: u64,
    /// Mean measured wall time per fine-tuning batch (dispatch through
    /// aggregator update), ms.
    pub mean_step_ms: f64,
    /// Accumulated measured busy time per worker (ms).
    pub worker_busy_ms: Vec<f64>,
    /// Mean worker utilization (busy / per-batch makespan).
    pub worker_utilization: f64,
    /// Worker straggler-over-mean imbalance (0 = perfectly balanced).
    pub worker_imbalance: f64,
    /// Encode buffers allocated fresh over the whole run (steady state:
    /// bounded by in-flight messages, not by batch count — the
    /// zero-allocation hot-loop property, pinned by tests).
    pub encode_buf_fresh: u64,
    /// Encode-buffer checkouts served by recycling.
    pub encode_buf_reused: u64,
}

/// One unit of worker compute: run micro `micro` under `masks`.
struct MicroJob {
    micro: usize,
    x: Tensor,
    y: Vec<i32>,
    masks: MaskPair,
}

/// Aggregator -> worker messages. FIFO per worker, so an update always
/// lands before the next batch's compute.
enum Job {
    /// Compute masked gradients for these micro-batches (one snapshot).
    Compute(Vec<MicroJob>),
    /// Apply the reduced masked gradient (allreduce mode).
    Apply { lr: f32, union: MaskPair, blob: Arc<Vec<u8>> },
    /// Install dense update deltas (parameter-server mode).
    ApplyDeltas { blob: Arc<Vec<u8>> },
    /// Zero the momentum buffers (pretrain -> fine-tune boundary).
    ResetMomentum,
}

/// Worker -> aggregator: one computed micro-batch gradient message.
struct Up {
    worker: usize,
    micro: usize,
    loss: f32,
    n_correct: f32,
    /// The serialized masked gradient — the bytes that cross the wire.
    blob: Vec<u8>,
    /// Measured wall time of the gradient computation alone (ms) — the
    /// signal the assignment balancer and the exec-time calibration
    /// consume. Encode/upload time is excluded: when overlapping it
    /// runs on the sender thread, hidden behind the next task.
    ms: f64,
}

/// Compute-thread -> sender-thread handoff (overlap mode): one computed
/// gradient awaiting encode + upload.
struct Computed {
    micro: usize,
    loss: f32,
    n_correct: f32,
    masks: MaskPair,
    grads: Vec<Tensor>,
    ms: f64,
}

/// Per-worker knobs threaded into [`worker_loop`].
#[derive(Clone)]
struct WorkerOpts {
    /// Encode + upload on a dedicated sender thread, double-buffered.
    overlap: bool,
    /// Simulated NIC ms per MiB of encoded message (0 = off).
    wire_ms_per_mib: f64,
    /// Recycled encode buffers (shared with the aggregator).
    pool: Arc<BufPool>,
}

/// Sleep out the simulated NIC time for one `bytes`-sized message. A
/// sleep — not a spin — because a real NIC moves bytes by DMA without
/// burning a core: the sender thread must *wait* without stealing CPU
/// from the compute threads, or the measured overlap win would vanish
/// on core-saturated hosts for the wrong reason.
fn sim_wire_delay(bytes: usize, ms_per_mib: f64) {
    if ms_per_mib > 0.0 {
        let ms = bytes as f64 / (1024.0 * 1024.0) * ms_per_mib;
        thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
    }
}

/// Encode one computed gradient into a recycled buffer, pay the
/// (optional) simulated NIC, and upload it to the aggregator.
fn encode_and_send(
    codec: &GradCodec,
    opts: &WorkerOpts,
    worker: usize,
    c: Computed,
    tx: &mpsc::Sender<Up>,
) -> bool {
    let mut blob = opts.pool.checkout();
    codec.encode_into(c.micro, &c.masks, &c.grads, &mut blob);
    sim_wire_delay(blob.len(), opts.wire_ms_per_mib);
    tx.send(Up {
        worker,
        micro: c.micro,
        loss: c.loss,
        n_correct: c.n_correct,
        blob,
        ms: c.ms,
    })
    .is_ok()
}

/// One worker's main loop. With `opts.overlap` the loop splits in two:
/// this (compute) thread runs `grad_step` back to back and hands each
/// finished gradient to a dedicated sender thread over a **bounded**
/// one-slot channel — so the encode + upload of task *i* overlaps task
/// *i+1*'s computation, with classic double buffering (one gradient in
/// the channel, one being encoded) as backpressure: compute can never
/// run more than two tasks ahead of the wire. Serialized mode
/// (`overlap == false`) encodes and sends inline, the PR 3 behaviour.
///
/// Ordering safety: the aggregator broadcasts a batch's update only
/// after it has received *every* uplink message of that batch, so by
/// the time an `Apply` job reaches this thread the sender queue is
/// already drained — the replica can never apply an update while its
/// own gradients for that batch are still in flight. (The handed-off
/// gradients are owned tensors, so the sender never reads the replica.)
fn worker_loop(
    mut be: NativeBackend,
    codec: Arc<GradCodec>,
    worker: usize,
    rx: mpsc::Receiver<Job>,
    tx: mpsc::Sender<Up>,
    opts: WorkerOpts,
) {
    let (sender_tx, sender_handle) = if opts.overlap {
        // Double buffering: one slot in the channel + one in the
        // sender's hands.
        let (stx, srx) = mpsc::sync_channel::<Computed>(1);
        let codec = Arc::clone(&codec);
        let up = tx.clone();
        let sopts = opts.clone();
        let handle = thread::Builder::new()
            .name(format!("d2ft-dist-{worker}-tx"))
            .spawn(move || {
                while let Ok(c) = srx.recv() {
                    if !encode_and_send(&codec, &sopts, worker, c, &up) {
                        return;
                    }
                }
            })
            .expect("spawning dist sender");
        (Some(stx), Some(handle))
    } else {
        (None, None)
    };
    while let Ok(job) = rx.recv() {
        match job {
            Job::Compute(items) => {
                for it in items {
                    let t0 = Instant::now();
                    let (out, grads) = be
                        .grad_step(&it.x, &it.y, &it.masks)
                        .expect("native grad step on worker");
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let c = Computed {
                        micro: it.micro,
                        loss: out.loss,
                        n_correct: out.n_correct,
                        masks: it.masks,
                        grads,
                        ms,
                    };
                    let alive = match &sender_tx {
                        Some(stx) => stx.send(c).is_ok(),
                        None => encode_and_send(&codec, &opts, worker, c, &tx),
                    };
                    if !alive {
                        return;
                    }
                }
            }
            Job::Apply { lr, union, blob } => {
                let mut acc = be.zeros_like_params();
                codec
                    .decode_add(&blob, &union, &mut acc)
                    .expect("decoding reduced gradient broadcast");
                be.apply_grads(&acc, lr).expect("applying reduced gradient");
            }
            Job::ApplyDeltas { blob } => {
                let deltas = codec.decode_dense(&blob).expect("decoding delta broadcast");
                be.apply_deltas(&deltas).expect("installing deltas");
            }
            Job::ResetMomentum => {
                be.reset_momentum().expect("resetting momentum");
            }
        }
    }
    // Shut the sender down cleanly before the compute thread exits.
    drop(sender_tx);
    if let Some(h) = sender_handle {
        let _ = h.join();
    }
}

/// Per-batch outcome of one distributed execution.
struct BatchOut {
    /// `(loss, n_correct)` in micro order.
    outs: Vec<(f32, f32)>,
    /// Measured busy ms per worker (0 for idle workers).
    worker_ms: Vec<f64>,
}

/// The distributed data-parallel trainer (see the module docs).
pub struct DistTrainer {
    cfg: DistConfig,
    /// The aggregator's authoritative replica (scores, eval, updates).
    agg: NativeBackend,
    codec: Arc<GradCodec>,
    partition: Partition,
    train: Dataset,
    test: Dataset,
    txs: Vec<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Up>,
    handles: Vec<thread::JoinHandle<()>>,
    /// Per-worker EMA of measured ms per micro-batch task — the
    /// straggler signal the assignment balancer reacts to.
    ema_ms: Vec<f64>,
    /// Recycled encode buffers: workers check out, the aggregator gives
    /// back after every reduction.
    buf_pool: Arc<BufPool>,
}

impl DistTrainer {
    /// Build the cluster: an aggregator replica plus `cfg.workers`
    /// worker replicas, all deterministically initialized from the same
    /// `(spec, lora_rank, seed)` so they are bitwise identical.
    pub fn new(provider: &NativeProvider, cfg: DistConfig) -> Result<DistTrainer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker replica");
        anyhow::ensure!(
            cfg.wire_precision == WirePrecision::F32
                || cfg.exchange == ExchangeMode::MaskedAllReduce,
            "f16 wire precision supports masked-allreduce only (the \
             parameter-server update is applied server-side before \
             encoding, so its deltas cannot be requantized consistently)"
        );
        let mut cfg = cfg;
        cfg.train.update = UpdateMode::BatchAccum;
        let spec = provider.spec();
        if cfg.train.lora_rank > 0 {
            anyhow::ensure!(
                spec.lora_ranks.contains(&cfg.train.lora_rank),
                "native spec advertises LoRA ranks {:?}, not {}",
                spec.lora_ranks,
                cfg.train.lora_rank
            );
        }
        let mb = spec.micro_batch;
        let agg = NativeBackend::new(spec, cfg.train.lora_rank, mb, cfg.train.seed);
        // Shared with the serial trainer so the two drivers cannot
        // drift on partition/dataset setup.
        let setup = prepare_run(agg.config(), &cfg.train)?;
        let codec = Arc::new(GradCodec::new(&agg).with_precision(cfg.wire_precision));
        let buf_pool = Arc::new(BufPool::new());
        let opts = WorkerOpts {
            overlap: cfg.overlap,
            wire_ms_per_mib: cfg.sim_wire_ms_per_mib,
            pool: Arc::clone(&buf_pool),
        };
        let (up_tx, up_rx) = mpsc::channel::<Up>();
        let mut txs = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let (tx, job_rx) = mpsc::channel::<Job>();
            let replica = NativeBackend::new(spec, cfg.train.lora_rank, mb, cfg.train.seed);
            let codec = Arc::clone(&codec);
            let up = up_tx.clone();
            let wopts = opts.clone();
            let handle = thread::Builder::new()
                .name(format!("d2ft-dist-{w}"))
                .spawn(move || worker_loop(replica, codec, w, job_rx, up, wopts))
                .expect("spawning dist worker");
            txs.push(tx);
            handles.push(handle);
        }
        let ema_ms = vec![1.0; cfg.workers];
        Ok(DistTrainer {
            cfg,
            agg,
            codec,
            partition: setup.partition,
            train: setup.train,
            test: setup.test,
            txs,
            rx: up_rx,
            handles,
            ema_ms,
            buf_pool,
        })
    }

    /// The aggregator's replica (authoritative parameters).
    pub fn backend(&self) -> &NativeBackend {
        &self.agg
    }

    /// The model partition this run schedules over.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The gradient codec (wire-layout queries, e.g. dense size).
    pub fn codec(&self) -> &GradCodec {
        &self.codec
    }

    /// Assign each of `n_micro` micro-batches to a worker: greedy
    /// least-finish-time over the measured per-task EMAs, so a slow
    /// worker (real straggler) receives fewer tasks next batch. Purely
    /// a placement decision — replicas are bitwise identical, so any
    /// assignment yields identical numerics.
    fn assign(&self, n_micro: usize) -> Vec<usize> {
        let k = self.txs.len();
        let mut load = vec![0.0f64; k];
        let mut out = Vec::with_capacity(n_micro);
        for _ in 0..n_micro {
            let mut best = 0;
            for w in 1..k {
                if load[w] + self.ema_ms[w] < load[best] + self.ema_ms[best] {
                    best = w;
                }
            }
            load[best] += self.ema_ms[best];
            out.push(best);
        }
        out
    }

    /// Execute one batch: dispatch compute jobs, run the ordered-reduce
    /// barrier, apply the update on the aggregator, broadcast it to the
    /// workers, and account the bytes.
    fn exec_batch(
        &mut self,
        micros: &[(Tensor, Vec<i32>)],
        masks: &[MaskPair],
        stats: &mut WireStats,
    ) -> Result<BatchOut> {
        let n = micros.len();
        assert_eq!(masks.len(), n, "one mask pair per micro-batch");
        let k = self.txs.len();
        let assignment = self.assign(n);
        let mut jobs: Vec<Vec<MicroJob>> = (0..k).map(|_| Vec::new()).collect();
        for (i, (x, y)) in micros.iter().enumerate() {
            jobs[assignment[i]].push(MicroJob {
                micro: i,
                x: x.clone(),
                y: y.clone(),
                masks: masks[i].clone(),
            });
        }
        let mut tasks_per_worker = vec![0usize; k];
        for (w, job) in jobs.into_iter().enumerate() {
            if job.is_empty() {
                continue;
            }
            tasks_per_worker[w] = job.len();
            self.txs[w].send(Job::Compute(job)).expect("dist worker queue closed");
        }
        // Barrier: one gradient message per micro-batch.
        let mut reducer = OrderedReducer::new(n);
        let mut outs = vec![(0.0f32, 0.0f32); n];
        let mut worker_ms = vec![0.0f64; k];
        let dense = self.codec.dense_len();
        for _ in 0..n {
            let up = self.rx.recv().expect("dist worker died");
            worker_ms[up.worker] += up.ms;
            outs[up.micro] = (up.loss, up.n_correct);
            stats.record_up(up.blob.len(), dense);
            reducer.push(up.micro, up.blob)?;
        }
        // Straggler feedback: EMA of measured ms per task.
        for w in 0..k {
            if tasks_per_worker[w] > 0 {
                let per_task = worker_ms[w] / tasks_per_worker[w] as f64;
                self.ema_ms[w] = 0.8 * self.ema_ms[w] + 0.2 * per_task;
            }
        }
        // Fixed-order reduction -> batch-mean gradient.
        let mut acc = self.agg.zeros_like_params();
        reducer.reduce(&self.codec, masks, &mut acc)?;
        // Recycle the message buffers: with the workers' checkout this
        // closes the loop that makes the steady-state encode path
        // allocation-free.
        for blob in reducer.into_blobs() {
            self.buf_pool.give_back(blob);
        }
        let lr = self.cfg.train.lr;
        match self.cfg.exchange {
            ExchangeMode::MaskedAllReduce => {
                let union = MaskPair::union(masks);
                let blob = Arc::new(self.codec.encode(0, &union, &acc));
                if self.codec.precision() == WirePrecision::F32 {
                    self.agg.apply_grads(&acc, lr)?;
                } else {
                    // Lossy wire: every replica must apply the exact
                    // bits that crossed it, the aggregator included —
                    // decode our own broadcast so all K+1 replicas stay
                    // mutually bitwise identical.
                    let mut quantized = self.agg.zeros_like_params();
                    self.codec.decode_add(&blob, &union, &mut quantized)?;
                    self.agg.apply_grads(&quantized, lr)?;
                }
                for tx in &self.txs {
                    stats.record_down(blob.len());
                    tx.send(Job::Apply { lr, union: union.clone(), blob: Arc::clone(&blob) })
                        .expect("dist worker queue closed");
                }
            }
            ExchangeMode::ParamServer => {
                let deltas = self.agg.update_capture(&acc, lr);
                let blob = Arc::new(self.codec.encode_dense(&deltas));
                for tx in &self.txs {
                    stats.record_down(blob.len());
                    tx.send(Job::ApplyDeltas { blob: Arc::clone(&blob) })
                        .expect("dist worker queue closed");
                }
            }
        }
        Ok(BatchOut { outs, worker_ms })
    }

    /// Distributed synthetic pre-training (all-ones masks), mirroring
    /// the serial trainer's pretrain arithmetic exactly.
    fn pretrain(&mut self, stats: &mut WireStats) -> Result<()> {
        let cfg = self.cfg.train.clone();
        if cfg.pretrain_batches == 0 {
            return Ok(());
        }
        let mc = self.agg.config().clone();
        let mb = self.agg.micro_batch();
        let n = cfg.pretrain_batches * cfg.micros_per_batch * mb;
        let pre = DatasetSpec::preset(SyntheticKind::Pretrain, mc.img_size, n, cfg.seed ^ 0x5A)
            .generate("train");
        let mut batcher = Batcher::new(&pre, mb, cfg.micros_per_batch, cfg.seed);
        while let Some(micros) = batcher.next_batch() {
            let masks: Vec<MaskPair> =
                (0..micros.len()).map(|_| MaskPair::ones(mc.depth, mc.heads)).collect();
            self.exec_batch(&micros, &masks, stats)?;
        }
        self.agg.reset_momentum()?;
        for tx in &self.txs {
            tx.send(Job::ResetMomentum).expect("dist worker queue closed");
        }
        Ok(())
    }

    /// Evaluate test top-1 on the aggregator replica (full forward).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mb = self.agg.eval_micro_batch();
        let mut meter = Meter::new();
        let mut i = 0;
        while i + mb <= self.test.len() {
            let idxs: Vec<usize> = (i..i + mb).collect();
            let (x, y) = self.test.gather(&idxs);
            let out = self.agg.eval(&x, &y, None)?;
            meter.push(out.loss, out.n_correct, mb);
            i += mb;
        }
        Ok((meter.top1(), meter.mean_loss()))
    }

    /// Run the full distributed fine-tuning loop.
    pub fn run(&mut self) -> Result<DistReport> {
        let cfg = self.cfg.train.clone();
        let mb = self.agg.micro_batch();
        let k = self.txs.len();
        // Pretrain traffic is accounted separately: its all-ones masks
        // ship dense messages, which would dilute the fine-tuning
        // savings headline if folded in.
        let mut pretrain_stats = WireStats::default();
        self.pretrain(&mut pretrain_stats)?;
        let mut stats = WireStats::default();

        let mut scheduler = build_scheduler(cfg.scheduler, cfg.scores, cfg.seed);
        let budget = match &cfg.hetero {
            Some(h) => h.budget(cfg.budget.clone(), self.partition.n_subnets()),
            None => cfg.budget.clone(),
        };
        let cost = CostModel::paper();
        let n_devices = self.partition.n_subnets();
        let mut workloads = WorkloadTracker::new(cost, n_devices);
        // The simulated engine still runs for the modeled accounting —
        // that is exactly what the measured numbers are compared
        // against. Its exec-time model starts at the paper's V100 table
        // and, when calibration is on, is rescaled at every epoch
        // boundary from *this* run's measured per-task times.
        let mut ecfg = EngineConfig::accounting(cfg.exec, cfg.seed);
        ecfg.bytes_per_fullop = self.codec.dense_len() as u64;
        let mut exec_model = ExecTimeModel::paper();
        let mut engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
        // Calibration state: per-epoch means of measured batch straggler
        // (slowest worker's summed task compute) vs modeled makespan;
        // after the first calibration, each further epoch contributes a
        // modeled-vs-measured drift sample.
        let mut calib_scale = 1.0f64;
        let mut calib_epochs = 0usize;
        let mut drift_sum = 0.0f64;
        let mut drift_n = 0usize;
        let mut ep_meas = 0.0f64;
        let mut ep_model = 0.0f64;
        let mut ep_batches = 0usize;
        let mut usage = DeviceUsage::new(n_devices);
        let mut worker_usage = DeviceUsage::new(k);
        let mut loss_curve = Vec::with_capacity(cfg.batches);
        let mut eval_curve = Vec::new();
        let mut score_cache: Vec<Option<ScoreBook>> = Vec::new();
        let mut exec_ms_sum = 0.0;
        let mut makespan_sum = 0.0;
        let mut modeled_wire_bytes = 0u64;
        let mut step_ms_sum = 0.0;
        let mut meter = Meter::new();

        // Cloned so the epoch iterator does not hold a borrow of `self`
        // across the `exec_batch` calls.
        let train_data = self.train.clone();
        let t0 = Instant::now();
        let mut batch_idx = 0;
        'outer: while batch_idx < cfg.batches {
            let mut batcher = Batcher::new(&train_data, mb, cfg.micros_per_batch, cfg.seed);
            let mut epoch_pos = 0usize;
            while let Some(micros) = batcher.next_batch() {
                if batch_idx >= cfg.batches {
                    break 'outer;
                }
                // --- contribution scores (cached, aggregator-side) --------
                if score_cache.len() <= epoch_pos {
                    score_cache.resize(epoch_pos + 1, None);
                }
                if score_cache[epoch_pos].is_none() {
                    // Keep this guard in lockstep with the serial
                    // trainer's score-cache block — the bitwise
                    // serial ≡ dist contract depends on it.
                    let can_probe = self.agg.supports_probe();
                    score_cache[epoch_pos] = Some(if scheduler.needs_scores() && can_probe {
                        let probes: Vec<Tensor> = micros
                            .iter()
                            .map(|(x, y)| self.agg.score_probe(x, y))
                            .collect::<Result<_>>()?;
                        ScoreBook::from_probes(&self.partition, &probes)
                    } else {
                        ScoreBook::zeros(self.partition.n_subnets(), micros.len())
                    });
                }
                let book = score_cache[epoch_pos].as_ref().unwrap();
                // --- schedule + distributed execution ---------------------
                let table = scheduler.schedule(book, &budget);
                let masks = table.all_masks(&self.partition);
                let ts = Instant::now();
                let out = self.exec_batch(&micros, &masks, &mut stats)?;
                step_ms_sum += ts.elapsed().as_secs_f64() * 1e3;
                for &(loss, n_correct) in &out.outs {
                    meter.push(loss, n_correct, mb);
                    loss_curve.push(loss);
                }
                worker_usage.record(&out.worker_ms);
                // --- modeled accounting (the comparison baseline) ---------
                let cluster = engine.execute(&table);
                workloads.record(&table);
                workloads.record_measured(&cluster.measured_ms());
                usage.record(&cluster.finish_ms());
                exec_ms_sum += cluster.mean_device_ms;
                makespan_sum += cluster.makespan_ms;
                modeled_wire_bytes += cluster.wire_bytes;
                // Calibration sample: this batch's measured straggler
                // (the slowest worker's summed task compute — exactly
                // what gates the synchronous step) against the modeled
                // makespan for the same schedule.
                ep_meas += out.worker_ms.iter().copied().fold(0.0, f64::max);
                ep_model += cluster.makespan_ms;
                ep_batches += 1;
                if cfg.eval_every > 0 && (batch_idx + 1) % cfg.eval_every == 0 {
                    let (top1, _) = self.evaluate()?;
                    eval_curve.push((batch_idx + 1, top1));
                }
                batch_idx += 1;
                epoch_pos += 1;
            }
            // ---- epoch boundary: drift report + recalibration --------
            // Means over the epoch (not single batches) so host noise
            // averages out of both the drift metric and the scale.
            if ep_batches > 0 {
                let meas = ep_meas / ep_batches as f64;
                let model = ep_model / ep_batches as f64;
                if calib_epochs > 0 {
                    drift_sum += rel_drift(model, meas);
                    drift_n += 1;
                }
                if self.cfg.calibrate && meas > 0.0 && model > 0.0 {
                    // Feed the measured/modeled ratio back through
                    // ExecTimeModel::calibrated (via `scaled`): the
                    // knapsack accounting for the *next* epoch runs on
                    // this host's real timings. Placement-only — the
                    // numerics cannot move.
                    let scale = meas / model;
                    exec_model = exec_model.scaled(scale);
                    calib_scale *= scale;
                    engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
                    calib_epochs += 1;
                }
                ep_meas = 0.0;
                ep_model = 0.0;
                ep_batches = 0;
            }
        }
        // A run that ends mid-epoch still reports the partial epoch's
        // drift (it just never feeds another calibration).
        if ep_batches > 0 && calib_epochs > 0 {
            drift_sum += rel_drift(ep_model / ep_batches as f64, ep_meas / ep_batches as f64);
            drift_n += 1;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let (test_top1, test_loss) = self.evaluate()?;
        let b = workloads.batches().max(1) as f64;
        let train = TrainReport {
            scheduler: cfg.scheduler.label().to_string(),
            backend: self.agg.label().to_string(),
            final_train_loss: meter.mean_loss(),
            test_top1,
            test_loss,
            loss_curve,
            eval_curve,
            compute_fraction: workloads.total_compute_fraction(),
            comm_fraction: workloads.total_comm_fraction(),
            workload_variance: workloads.workload_variance(),
            sample_count_variance: workloads.sample_count_variance(),
            mean_exec_ms: exec_ms_sum / b,
            makespan_ms: makespan_sum / b,
            engine: format!("dist({k} workers, {})", self.cfg.exchange.label()),
            utilization: usage.mean_utilization(),
            imbalance: usage.imbalance(),
            // Real straggler: slowest worker's measured time per batch.
            straggler_ms: worker_usage.total_makespan_ms() / worker_usage.steps().max(1) as f64,
            wall_s,
            batches: batch_idx,
            calib_scale,
            calib_epochs,
            makespan_drift: if drift_n > 0 { drift_sum / drift_n as f64 } else { 0.0 },
        };
        let n_batches = worker_usage.steps().max(1) as f64;
        Ok(DistReport {
            grad_savings: stats.grad_savings(),
            n_workers: k,
            exchange: self.cfg.exchange.label().to_string(),
            wire: stats,
            pretrain_wire: pretrain_stats,
            modeled_wire_bytes,
            mean_step_ms: step_ms_sum / n_batches,
            worker_busy_ms: worker_usage.busy_ms().to_vec(),
            worker_utilization: worker_usage.mean_utilization(),
            worker_imbalance: worker_usage.imbalance(),
            encode_buf_fresh: self.buf_pool.fresh_allocs(),
            encode_buf_reused: self.buf_pool.reuses(),
            train,
        })
    }
}

impl Drop for DistTrainer {
    fn drop(&mut self) {
        // Closing the job queues ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeSpec;
    use crate::coordinator::SchedulerKind;
    use crate::runtime::ModelConfig;
    use crate::schedule::Budget;

    fn small_provider() -> NativeProvider {
        NativeProvider::new(NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xBEEF,
            threads: 1,
        })
    }

    fn quick_cfg() -> TrainerConfig {
        TrainerConfig {
            train_size: 60,
            test_size: 12,
            batches: 2,
            pretrain_batches: 1,
            ..TrainerConfig::quick(
                crate::data::SyntheticKind::Cifar10Like,
                SchedulerKind::D2ft,
                Budget::uniform(5, 3, 1),
            )
        }
    }

    #[test]
    fn dist_trainer_runs_and_counts_bytes() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        let r = dt.run().unwrap();
        assert_eq!(r.n_workers, 2);
        assert_eq!(r.train.batches, 2);
        assert_eq!(r.train.loss_curve.len(), 10);
        assert!(r.train.final_train_loss.is_finite());
        assert!(r.wire.up_bytes > 0 && r.wire.down_bytes > 0);
        // 3 p_f + 1 p_o of 5 leaves head slices off the wire.
        assert!(r.grad_savings > 0.0, "masked schedule must save bytes");
        assert!(r.wire.up_bytes < r.wire.dense_up_bytes);
        assert_eq!(r.worker_busy_ms.len(), 2);
    }

    #[test]
    fn overlap_off_matches_overlap_on_bitwise() {
        // The pipelined sender changes *when* bytes move, never which
        // bytes or how they reduce: trajectories and parameters must be
        // bit-equal with the pipeline on and off.
        let provider = small_provider();
        let run = |overlap: bool| {
            let dcfg = DistConfig { overlap, ..DistConfig::new(quick_cfg(), 3) };
            let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
            let r = dt.run().unwrap();
            let w = dt.backend().param("b00_wqkv").unwrap();
            (r, w)
        };
        let (on, w_on) = run(true);
        let (off, w_off) = run(false);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&on.train.loss_curve), bits(&off.train.loss_curve));
        assert_eq!(w_on, w_off, "overlap must not move a single parameter bit");
        assert_eq!(on.wire.up_bytes, off.wire.up_bytes, "same bytes either way");
    }

    #[test]
    fn encode_buffers_recycle_in_steady_state() {
        // Zero per-task allocations after warmup: fresh buffer count is
        // bounded by what can be in flight at once (workers x 2 slots +
        // one batch's messages), not by how many batches ran.
        let provider = small_provider();
        let mut cfg = quick_cfg();
        cfg.batches = 4;
        let workers = 2;
        let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, workers)).unwrap();
        let r = dt.run().unwrap();
        let in_flight_bound = 5 + 2 * workers as u64; // micros + double buffers
        assert!(
            r.encode_buf_fresh <= in_flight_bound,
            "fresh allocations ({}) exceed the in-flight bound ({in_flight_bound}) — \
             the recycle loop is broken",
            r.encode_buf_fresh
        );
        assert!(
            r.encode_buf_reused > r.encode_buf_fresh,
            "most checkouts must be recycled: fresh {} vs reused {}",
            r.encode_buf_fresh,
            r.encode_buf_reused
        );
        assert_eq!(r.encode_buf_fresh + r.encode_buf_reused, r.wire.up_msgs + r.pretrain_wire.up_msgs);
    }

    #[test]
    fn f16_wire_halves_measured_bytes_and_trains() {
        let provider = small_provider();
        let run = |prec| {
            let dcfg =
                DistConfig { wire_precision: prec, ..DistConfig::new(quick_cfg(), 2) };
            DistTrainer::new(&provider, dcfg).unwrap().run().unwrap()
        };
        let r32 = run(WirePrecision::F32);
        let r16 = run(WirePrecision::F16);
        assert!(r16.train.final_train_loss.is_finite());
        assert_eq!(r32.wire.up_msgs, r16.wire.up_msgs);
        let ratio = r16.wire.up_bytes as f64 / r32.wire.up_bytes as f64;
        assert!(
            ratio < 0.52,
            "f16 must roughly halve the measured uplink, got {ratio:.3}"
        );
        // f16 + parameter server is rejected up front.
        let bad = DistConfig {
            wire_precision: WirePrecision::F16,
            exchange: ExchangeMode::ParamServer,
            ..DistConfig::new(quick_cfg(), 2)
        };
        assert!(DistTrainer::new(&provider, bad).is_err());
    }

    #[test]
    fn worker_count_must_be_positive() {
        let provider = small_provider();
        assert!(DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 0)).is_err());
    }

    #[test]
    fn assignment_balances_by_measured_ema() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        // Pretend worker 1 is 3x slower than worker 0.
        dt.ema_ms = vec![1.0, 3.0];
        let a = dt.assign(4);
        let w0 = a.iter().filter(|&&w| w == 0).count();
        let w1 = a.iter().filter(|&&w| w == 1).count();
        assert!(w0 > w1, "fast worker takes more micro-batches: {a:?}");
        assert_eq!(w0 + w1, 4);
    }
}
