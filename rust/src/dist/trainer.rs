//! `DistTrainer`: the live data-parallel fine-tuning driver.
//!
//! K workers each own a full [`NativeBackend`] replica built from the
//! same deterministic init. Per scheduled batch the aggregator assigns
//! every micro-batch to a worker (straggler-aware, see below), each
//! worker runs the masked forward/backward **for real** against the
//! shared parameter snapshot, serializes the masked gradient
//! ([`super::grads`]), and the aggregator reduces the messages in fixed
//! micro order and applies one fused SGD-momentum update — then either
//! broadcasts the reduced masked gradient (workers re-apply the same
//! update locally) or, in parameter-server mode, the dense update
//! deltas. Per-link FIFO ordering doubles as the sync barrier: a worker
//! always installs the batch-`b` update before it sees a batch-`b+1`
//! compute job.
//!
//! ## The transport seam
//!
//! Every aggregator ↔ worker exchange travels as a [`super::proto`]
//! frame over a [`Transport`] link ([`super::transport`]):
//! [`TransportKind::Channel`] keeps the workers as threads of this
//! process (the PR 3/4 shape), [`TransportKind::Tcp`] runs the *same*
//! [`super::worker::run_worker`] loop in separate threads, forked
//! `repro dist-worker` subprocesses, or externally launched processes
//! on other hosts. Both transports deliver identical bytes in identical
//! per-link order, so the trainer is **bitwise identical across
//! transports** — `tests/dist_tcp.rs` pins serial ≡ channel ≡ tcp.
//!
//! ## Determinism
//!
//! Every micro-batch gradient is computed by exactly one worker whose
//! replica is bitwise identical to the serial trainer's model at the
//! same point; the wire format is lossless; the reduction order is
//! fixed. So the whole trajectory — losses, parameters, eval accuracy —
//! is bitwise identical to the serial [`crate::coordinator::Trainer`]
//! under [`UpdateMode::BatchAccum`], for *any* worker count, either
//! exchange mode, and either transport. Placement (which worker
//! computes which micro-batch) is measured-time dependent and
//! deliberately free: it can shift work away from real stragglers
//! without touching a single bit of the math.
//!
//! ## Pipeline (comm/compute overlap)
//!
//! Each worker splits into a compute thread and a dedicated sender
//! thread joined by a bounded one-slot channel: while task *i*'s
//! gradient is being encoded and uploaded, task *i+1*'s `grad_step`
//! already runs (see [`super::worker`]). The handoff carries owned
//! gradients, the aggregator only broadcasts a batch's update after
//! every uplink of that batch arrived, and the [`OrderedReducer`] fixes
//! the reduction order — so pipelining is bitwise invisible.
//!
//! ## Measurement and calibration
//!
//! Uplink/downlink gradient bytes are counted on the actual serialized
//! messages ([`WireStats`]); the transport layer separately counts the
//! raw frame bytes that crossed each link ([`TransportStats`] — for
//! TCP, real socket traffic). Per-worker task times are wall-clock
//! measurements around the real gradient computation and feed (a) the
//! assignment balancer (EMA per worker), (b) the workload/usage
//! accounting, and (c) a per-epoch calibration of the modeled
//! [`ExecTimeModel`]: a per-task least-squares split of the measured
//! times into separate `p_f` and `p_o` factors
//! ([`crate::cluster::OpCalibrator`]), renormalized so the modeled
//! makespan matches the measured straggler — heterogeneous op costs
//! are tracked per op, and the modeled-vs-measured drift
//! (`TrainReport::makespan_drift`) stays anchored.

use std::process::{Child, Command};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::allreduce::{ExchangeMode, OrderedReducer};
use super::grads::{BufPool, GradCodec, WirePrecision, WireStats};
use super::proto::{self, InitMsg, MicroJob, UpHdr};
use super::transport::{
    accept_workers, channel_pair, listen, BlobRx, BlobTx, SpawnMode, StatsCell, TcpTransport,
    Transport, TransportKind, TransportStats,
};
use super::worker::run_worker;
use crate::backend::native::{NativeBackend, NativeProvider};
use crate::backend::Backend;
use crate::cluster::{
    CostModel, Engine, EngineConfig, ExecTimeModel, OpCalibrator, WorkloadTracker,
};
use crate::coordinator::{build_scheduler, prepare_run, TrainReport, TrainerConfig, UpdateMode};
use crate::data::{Batcher, Dataset, DatasetSpec, SyntheticKind};
use crate::metrics::{rel_drift, DeviceUsage, Meter};
use crate::partition::Partition;
use crate::schedule::{MaskPair, Scheduler};
use crate::scores::ScoreBook;
use crate::tensor::Tensor;

/// Configuration of one distributed run: the full serial trainer config
/// plus the cluster shape.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// The training run (dataset, schedule, budget, seed, ...). The
    /// update mode is forced to [`UpdateMode::BatchAccum`] — the only
    /// semantics a synchronous data-parallel cluster can implement.
    pub train: TrainerConfig,
    /// Worker replica count (>= 1).
    pub workers: usize,
    /// Gradient exchange topology.
    pub exchange: ExchangeMode,
    /// How frames move between the aggregator and its workers:
    /// in-process channels (threads) or TCP (threads, forked `repro
    /// dist-worker` subprocesses, or external/multi-host workers).
    /// Numerics are bitwise identical either way.
    pub transport: TransportKind,
    /// Pipeline each worker's encode + upload of task *i* behind task
    /// *i+1*'s gradient computation (a dedicated sender thread per
    /// worker, double-buffered handoff). Default `true`; `false` is the
    /// serialized reference path — `benches/dist_step.rs` measures the
    /// gap. Bitwise-neutral either way (the bytes are identical and the
    /// reduction order is fixed).
    pub overlap: bool,
    /// Gradient payload precision on the wire. The `F32` default is
    /// lossless (bitwise serial ≡ dist). `F16` halves the measured
    /// bytes; the aggregate gradient is then requantized before
    /// *anyone* (aggregator included) applies it, so all replicas still
    /// agree bitwise with each other — only with the serial trainer do
    /// they diverge. Masked-allreduce only.
    pub wire_precision: WirePrecision,
    /// Simulated NIC cost in milliseconds per MiB of *actual encoded
    /// message*, slept on the uplink path (sender thread when
    /// overlapping, compute thread when serialized). 0 disables it.
    /// This is a bench/experiment knob: in-process channels are
    /// effectively free, so hiding a modeled wire behind compute is how
    /// the comm/compute-overlap claim becomes measurable on one host.
    pub sim_wire_ms_per_mib: f64,
    /// Recalibrate the modeled [`ExecTimeModel`] from measured per-task
    /// times at every epoch boundary (see `DistReport::train`'s
    /// `calib_*` fields). Default `true`; scheduling decisions are
    /// placement-only, so calibration never touches the numerics.
    pub calibrate: bool,
}

impl DistConfig {
    /// Masked-allreduce cluster of `workers` replicas with the default
    /// performance knobs: in-process channel transport, overlap on,
    /// lossless f32 wire, no simulated NIC, calibration on.
    pub fn new(train: TrainerConfig, workers: usize) -> DistConfig {
        DistConfig {
            train,
            workers,
            exchange: ExchangeMode::MaskedAllReduce,
            transport: TransportKind::Channel,
            overlap: true,
            wire_precision: WirePrecision::F32,
            sim_wire_ms_per_mib: 0.0,
            calibrate: true,
        }
    }
}

/// Outcome of a distributed run: the serial-comparable training report
/// plus the measured wire and straggler data.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// The standard training report (losses, accuracy, modeled cluster
    /// metrics), field-compatible with the serial trainer's. The
    /// `straggler_ms` field here is the *real* per-batch straggler: the
    /// slowest worker's measured gradient-computation time.
    pub train: TrainReport,
    /// Worker replicas that executed the run.
    pub n_workers: usize,
    /// Exchange topology label (`masked-allreduce` / `param-server`).
    pub exchange: String,
    /// Transport label (`channel` / `tcp`).
    pub transport: String,
    /// Measured bytes on the wire for the *scheduled fine-tuning*
    /// batches (actual serialized messages) — the traffic the paper's
    /// communication claim is about.
    pub wire: WireStats,
    /// Measured bytes for the synthetic pre-training phase (all-ones
    /// masks, so uplink is always dense). Kept separate so
    /// [`DistReport::grad_savings`] and the measured-vs-modeled
    /// comparison are not diluted by unscheduled traffic.
    pub pretrain_wire: WireStats,
    /// Transport-layer totals over all aggregator-side links — whole
    /// frames including control messages, handshakes, and (for TCP)
    /// length prefixes: the bytes that actually crossed the socket,
    /// reported next to the modeled bytes by `benches/dist_step.rs`.
    pub socket: TransportStats,
    /// Uplink gradient bytes saved vs the unmasked schedule (measured).
    pub grad_savings: f64,
    /// What the simulated engine *modeled* for the same schedules, for
    /// the measured-vs-modeled comparison (DESIGN.md §dist).
    pub modeled_wire_bytes: u64,
    /// Mean measured wall time per fine-tuning batch (dispatch through
    /// aggregator update), ms.
    pub mean_step_ms: f64,
    /// Accumulated measured busy time per worker (ms).
    pub worker_busy_ms: Vec<f64>,
    /// Mean worker utilization (busy / per-batch makespan).
    pub worker_utilization: f64,
    /// Worker straggler-over-mean imbalance (0 = perfectly balanced).
    pub worker_imbalance: f64,
    /// Encode/frame buffers allocated fresh over the whole run, summed
    /// across every pool in the cluster (the aggregator's, plus — in
    /// TCP mode, where each process recycles locally — the per-worker
    /// pools reported in their Bye frames). Steady state: bounded by
    /// in-flight messages, not by batch count — the zero-allocation
    /// hot-loop property, pinned by tests.
    pub encode_buf_fresh: u64,
    /// Buffer checkouts served by recycling (same pools).
    pub encode_buf_reused: u64,
}

/// What a reader thread forwards from one worker's link into the
/// aggregator's single arrival queue.
enum Arrival {
    /// One computed micro-batch gradient (frame tail holds the blob).
    Up { worker: usize, hdr: UpHdr, frame: Vec<u8> },
    /// Shutdown acknowledgment with the worker's local pool counters.
    Bye { worker: usize, fresh: u64, reused: u64 },
    /// The link died or produced an undecodable frame. Surfaced as an
    /// error by whoever is waiting — a lost worker can never hang the
    /// barrier.
    Lost { worker: usize, error: String },
}

/// Drain one worker's uplink into the shared arrival queue. Exits on
/// Bye (clean shutdown), on link/decode failure (after forwarding a
/// [`Arrival::Lost`]), or when the aggregator is gone.
fn reader_loop(worker: usize, mut rx: Box<dyn BlobRx>, tx: mpsc::Sender<Arrival>) {
    loop {
        let frame = match rx.recv_blob() {
            Ok(f) => f,
            Err(e) => {
                let _ = tx.send(Arrival::Lost { worker, error: format!("{e:#}") });
                return;
            }
        };
        let forwarded = match proto::peek_tag(&frame) {
            Ok(proto::TAG_UP) => match proto::decode_up(&frame) {
                Ok(hdr) => tx.send(Arrival::Up { worker, hdr, frame }).is_ok(),
                Err(e) => {
                    let _ = tx.send(Arrival::Lost { worker, error: format!("{e:#}") });
                    return;
                }
            },
            Ok(proto::TAG_BYE) => {
                match proto::decode_bye(&frame) {
                    Ok((fresh, reused)) => {
                        let _ = tx.send(Arrival::Bye { worker, fresh, reused });
                    }
                    Err(e) => {
                        let _ = tx.send(Arrival::Lost { worker, error: format!("{e:#}") });
                    }
                }
                return;
            }
            Ok(tag) => {
                let _ = tx.send(Arrival::Lost {
                    worker,
                    error: format!("unexpected frame tag {tag:#x} on the uplink"),
                });
                return;
            }
            Err(e) => {
                let _ = tx.send(Arrival::Lost { worker, error: format!("{e:#}") });
                return;
            }
        };
        if !forwarded {
            return;
        }
    }
}

/// Per-batch outcome of one distributed execution.
struct BatchOut {
    /// `(loss, n_correct)` in micro order.
    outs: Vec<(f32, f32)>,
    /// Measured busy ms per worker (0 for idle workers).
    worker_ms: Vec<f64>,
    /// Measured gradient-computation ms per micro-batch (micro order) —
    /// the per-task signal the op-split calibration consumes.
    micro_ms: Vec<f64>,
}

/// The distributed data-parallel trainer (see the module docs).
pub struct DistTrainer {
    cfg: DistConfig,
    /// The aggregator's authoritative replica (scores, eval, updates).
    agg: NativeBackend,
    codec: GradCodec,
    partition: Partition,
    train: Dataset,
    test: Dataset,
    /// Downlink halves, one per worker (worker id = index).
    links: Vec<Box<dyn BlobTx>>,
    /// Fan-in of every worker's uplink (reader threads feed it).
    arrivals: mpsc::Receiver<Arrival>,
    readers: Vec<thread::JoinHandle<()>>,
    /// In-process workers (channel / tcp-threads modes).
    worker_threads: Vec<thread::JoinHandle<()>>,
    /// Forked `repro dist-worker` subprocesses (tcp processes mode).
    worker_procs: Vec<Child>,
    /// Live per-link transport counters (aggregator side).
    link_stats: Vec<Arc<StatsCell>>,
    /// Per-worker EMA of measured ms per micro-batch task — the
    /// straggler signal the assignment balancer reacts to.
    ema_ms: Vec<f64>,
    /// Recycled frame/encode buffers (aggregator side; in channel mode
    /// shared with the worker threads, closing the recycle loop
    /// in-process).
    buf_pool: Arc<BufPool>,
    /// Whether the shutdown handshake already ran.
    shut_down: bool,
    /// Summed worker-side pool counters from Bye frames.
    bye_fresh: u64,
    bye_reused: u64,
}

impl DistTrainer {
    /// Build the cluster: an aggregator replica plus `cfg.workers`
    /// worker replicas — threads over channels, threads over loopback
    /// TCP, forked subprocesses, or externally launched processes,
    /// per `cfg.transport` — all deterministically initialized from
    /// the same `(spec, lora_rank, seed)` so they are bitwise
    /// identical.
    pub fn new(provider: &NativeProvider, cfg: DistConfig) -> Result<DistTrainer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker replica");
        anyhow::ensure!(
            cfg.wire_precision == WirePrecision::F32
                || cfg.exchange == ExchangeMode::MaskedAllReduce,
            "f16 wire precision supports masked-allreduce only (the \
             parameter-server update is applied server-side before \
             encoding, so its deltas cannot be requantized consistently)"
        );
        let mut cfg = cfg;
        cfg.train.update = UpdateMode::BatchAccum;
        let spec = provider.spec();
        if cfg.train.lora_rank > 0 {
            anyhow::ensure!(
                spec.lora_ranks.contains(&cfg.train.lora_rank),
                "native spec advertises LoRA ranks {:?}, not {}",
                spec.lora_ranks,
                cfg.train.lora_rank
            );
        }
        let mb = spec.micro_batch;
        let agg = NativeBackend::new(spec, cfg.train.lora_rank, mb, cfg.train.seed);
        // Shared with the serial trainer so the two drivers cannot
        // drift on partition/dataset setup.
        let setup = prepare_run(agg.config(), &cfg.train)?;
        let codec = GradCodec::new(&agg).with_precision(cfg.wire_precision);
        let buf_pool = Arc::new(BufPool::new());
        let k = cfg.workers;

        // --- launch the workers and connect one link per worker -------
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(k);
        let mut link_stats = Vec::with_capacity(k);
        let mut worker_threads = Vec::new();
        let mut worker_procs = Vec::new();
        match cfg.transport.clone() {
            TransportKind::Channel => {
                for w in 0..k {
                    let (agg_end, worker_end) = channel_pair();
                    // One process-wide pool: worker encode buffers come
                    // back via the aggregator's give-backs and vice
                    // versa, so the recycle loop closes in-process.
                    let pool = Arc::clone(&buf_pool);
                    let handle = thread::Builder::new()
                        .name(format!("d2ft-dist-{w}"))
                        .spawn(move || {
                            if let Err(e) = run_worker(Box::new(worker_end), pool) {
                                crate::warn_!("dist worker {w} exited with error: {e:#}");
                            }
                        })
                        .context("spawning dist worker thread")?;
                    worker_threads.push(handle);
                    link_stats.push(agg_end.stats_cell());
                    transports.push(Box::new(agg_end));
                }
            }
            TransportKind::Tcp { listen: addr, spawn } => {
                let (listener, local) = listen(&addr)?;
                match spawn {
                    SpawnMode::Threads => {
                        for w in 0..k {
                            let dial = local.to_string();
                            let handle = thread::Builder::new()
                                .name(format!("d2ft-dist-{w}"))
                                .spawn(move || {
                                    // Worker-local pool, exactly like a
                                    // separate process would have.
                                    let pool = Arc::new(BufPool::new());
                                    let res = TcpTransport::connect(
                                        &dial,
                                        Duration::from_secs(30),
                                        Arc::clone(&pool),
                                    )
                                    .and_then(|t| run_worker(Box::new(t), pool));
                                    if let Err(e) = res {
                                        crate::warn_!("dist worker {w} exited with error: {e:#}");
                                    }
                                })
                                .context("spawning tcp dist worker thread")?;
                            worker_threads.push(handle);
                        }
                    }
                    SpawnMode::Processes => {
                        let exe = std::env::current_exe()
                            .context("resolving current executable for dist-worker spawn")?;
                        for _ in 0..k {
                            let child = Command::new(&exe)
                                .arg("dist-worker")
                                .arg("--connect")
                                .arg(local.to_string())
                                .arg("--quiet")
                                .spawn()
                                .context("forking `repro dist-worker` subprocess")?;
                            worker_procs.push(child);
                        }
                    }
                    SpawnMode::External => {
                        crate::info!(
                            "waiting for {k} external workers: repro dist-worker --connect {local}"
                        );
                    }
                }
                for stream in accept_workers(&listener, k, Duration::from_secs(120))? {
                    let t = TcpTransport::from_stream(stream, Arc::clone(&buf_pool))?;
                    link_stats.push(t.stats_cell());
                    transports.push(Box::new(t));
                }
            }
        }

        // --- handshake: Init every worker, then barrier every link ----
        // (Inits first so the K replica builds run concurrently.)
        for (w, link) in transports.iter_mut().enumerate() {
            let msg = InitMsg {
                worker: w,
                spec: spec.clone(),
                lora_rank: cfg.train.lora_rank,
                seed: cfg.train.seed,
                precision: cfg.wire_precision,
                overlap: cfg.overlap,
                sim_wire_ms_per_mib: cfg.sim_wire_ms_per_mib,
            };
            let mut frame = buf_pool.checkout();
            proto::encode_init(&msg, &mut frame);
            link.send_blob(frame).with_context(|| format!("sending Init to worker {w}"))?;
        }
        for (w, link) in transports.iter_mut().enumerate() {
            link.barrier().with_context(|| format!("handshake barrier with worker {w}"))?;
        }

        // --- split the links; reader threads fan uplinks in -----------
        let (arr_tx, arrivals) = mpsc::channel::<Arrival>();
        let mut links = Vec::with_capacity(k);
        let mut readers = Vec::with_capacity(k);
        for (w, link) in transports.into_iter().enumerate() {
            let (tx, rx) = link.split();
            links.push(tx);
            let fan_in = arr_tx.clone();
            let handle = thread::Builder::new()
                .name(format!("d2ft-dist-{w}-rx"))
                .spawn(move || reader_loop(w, rx, fan_in))
                .context("spawning dist reader thread")?;
            readers.push(handle);
        }
        drop(arr_tx);

        let ema_ms = vec![1.0; k];
        Ok(DistTrainer {
            cfg,
            agg,
            codec,
            partition: setup.partition,
            train: setup.train,
            test: setup.test,
            links,
            arrivals,
            readers,
            worker_threads,
            worker_procs,
            link_stats,
            ema_ms,
            buf_pool,
            shut_down: false,
            bye_fresh: 0,
            bye_reused: 0,
        })
    }

    /// The aggregator's replica (authoritative parameters).
    pub fn backend(&self) -> &NativeBackend {
        &self.agg
    }

    /// The model partition this run schedules over.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The gradient codec (wire-layout queries, e.g. dense size).
    pub fn codec(&self) -> &GradCodec {
        &self.codec
    }

    /// Assign each of `n_micro` micro-batches to a worker: greedy
    /// least-finish-time over the measured per-task EMAs, so a slow
    /// worker (real straggler) receives fewer tasks next batch. Purely
    /// a placement decision — replicas are bitwise identical, so any
    /// assignment yields identical numerics.
    fn assign(&self, n_micro: usize) -> Vec<usize> {
        let k = self.ema_ms.len();
        let mut load = vec![0.0f64; k];
        let mut out = Vec::with_capacity(n_micro);
        for _ in 0..n_micro {
            let mut best = 0;
            for w in 1..k {
                if load[w] + self.ema_ms[w] < load[best] + self.ema_ms[best] {
                    best = w;
                }
            }
            load[best] += self.ema_ms[best];
            out.push(best);
        }
        out
    }

    /// Broadcast one frame to every worker, checking a pooled copy out
    /// per link (the transport consumes its buffer). Records `payload`
    /// bytes per link into `stats` as downlink traffic.
    ///
    /// The K copies are a deliberate trade for the uniform seam: the
    /// pre-transport code shared one `Arc<Vec<u8>>` across in-process
    /// workers, but any real multi-process transport must materialize
    /// per-link bytes anyway, and one memcpy per worker per batch is
    /// noise next to a batch's gradient compute. Buffers come from the
    /// pool, so the copies add no steady-state allocations.
    fn broadcast(&mut self, master: &[u8], payload: usize, stats: &mut WireStats) -> Result<()> {
        for (w, link) in self.links.iter_mut().enumerate() {
            stats.record_down(payload);
            let mut frame = self.buf_pool.checkout();
            frame.extend_from_slice(master);
            link.send_blob(frame)
                .with_context(|| format!("broadcasting to dist worker {w}"))?;
        }
        Ok(())
    }

    /// Execute one batch: dispatch compute jobs, run the ordered-reduce
    /// barrier, apply the update on the aggregator, broadcast it to the
    /// workers, and account the bytes.
    fn exec_batch(
        &mut self,
        micros: &[(Tensor, Vec<i32>)],
        masks: &[MaskPair],
        stats: &mut WireStats,
    ) -> Result<BatchOut> {
        let n = micros.len();
        assert_eq!(masks.len(), n, "one mask pair per micro-batch");
        let k = self.links.len();
        let assignment = self.assign(n);
        let mut jobs: Vec<Vec<MicroJob>> = (0..k).map(|_| Vec::new()).collect();
        for (i, (x, y)) in micros.iter().enumerate() {
            jobs[assignment[i]].push(MicroJob {
                micro: i,
                x: x.clone(),
                y: y.clone(),
                masks: masks[i].clone(),
            });
        }
        let mut tasks_per_worker = vec![0usize; k];
        for (w, job) in jobs.into_iter().enumerate() {
            if job.is_empty() {
                continue;
            }
            tasks_per_worker[w] = job.len();
            let mut frame = self.buf_pool.checkout();
            proto::encode_compute(&job, &mut frame);
            self.links[w]
                .send_blob(frame)
                .with_context(|| format!("dispatching compute jobs to worker {w}"))?;
        }
        // Barrier: one gradient message per micro-batch. A lost worker
        // surfaces as an error here — never a hang.
        let mut reducer = OrderedReducer::new(n);
        let mut outs = vec![(0.0f32, 0.0f32); n];
        let mut worker_ms = vec![0.0f64; k];
        let mut micro_ms = vec![0.0f64; n];
        let dense = self.codec.dense_len();
        for _ in 0..n {
            match self.arrivals.recv() {
                Ok(Arrival::Up { worker, hdr, frame }) => {
                    worker_ms[worker] += hdr.ms;
                    stats.record_up(frame.len() - proto::UP_GRAD_OFF, dense);
                    reducer.push(hdr.micro, frame, proto::UP_GRAD_OFF)?;
                    outs[hdr.micro] = (hdr.loss, hdr.n_correct);
                    micro_ms[hdr.micro] = hdr.ms;
                }
                Ok(Arrival::Lost { worker, error }) => {
                    anyhow::bail!("dist worker {worker} lost mid-batch: {error}")
                }
                Ok(Arrival::Bye { worker, .. }) => {
                    anyhow::bail!("dist worker {worker} sent an unexpected Bye mid-batch")
                }
                Err(_) => anyhow::bail!("every dist worker link closed mid-batch"),
            }
        }
        // Straggler feedback: EMA of measured ms per task.
        for w in 0..k {
            if tasks_per_worker[w] > 0 {
                let per_task = worker_ms[w] / tasks_per_worker[w] as f64;
                self.ema_ms[w] = 0.8 * self.ema_ms[w] + 0.2 * per_task;
            }
        }
        // Fixed-order reduction -> batch-mean gradient.
        let mut acc = self.agg.zeros_like_params();
        reducer.reduce(&self.codec, masks, &mut acc)?;
        // Recycle the message buffers: with the workers' checkout this
        // closes the loop that makes the steady-state encode path
        // allocation-free.
        for blob in reducer.into_blobs() {
            self.buf_pool.give_back(blob);
        }
        let lr = self.cfg.train.lr;
        match self.cfg.exchange {
            ExchangeMode::MaskedAllReduce => {
                let union = MaskPair::union(masks);
                let mut gbuf = self.buf_pool.checkout();
                self.codec.encode_into(0, &union, &acc, &mut gbuf);
                if self.codec.precision() == WirePrecision::F32 {
                    self.agg.apply_grads(&acc, lr)?;
                } else {
                    // Lossy wire: every replica must apply the exact
                    // bits that crossed it, the aggregator included —
                    // decode our own broadcast so all K+1 replicas stay
                    // mutually bitwise identical.
                    let mut quantized = self.agg.zeros_like_params();
                    self.codec.decode_add(&gbuf, &union, &mut quantized)?;
                    self.agg.apply_grads(&quantized, lr)?;
                }
                let mut master = self.buf_pool.checkout();
                let grad_off = proto::encode_apply(lr, &union, &gbuf, &mut master);
                let payload = master.len() - grad_off;
                self.buf_pool.give_back(gbuf);
                self.broadcast(&master, payload, stats)?;
                self.buf_pool.give_back(master);
            }
            ExchangeMode::ParamServer => {
                let deltas = self.agg.update_capture(&acc, lr);
                let mut master = self.buf_pool.checkout();
                let off = proto::encode_deltas_header(&mut master);
                self.codec.encode_dense_append(&deltas, &mut master);
                let payload = master.len() - off;
                self.broadcast(&master, payload, stats)?;
                self.buf_pool.give_back(master);
            }
        }
        Ok(BatchOut { outs, worker_ms, micro_ms })
    }

    /// Distributed synthetic pre-training (all-ones masks), mirroring
    /// the serial trainer's pretrain arithmetic exactly.
    fn pretrain(&mut self, stats: &mut WireStats) -> Result<()> {
        let cfg = self.cfg.train.clone();
        if cfg.pretrain_batches == 0 {
            return Ok(());
        }
        let mc = self.agg.config().clone();
        let mb = self.agg.micro_batch();
        let n = cfg.pretrain_batches * cfg.micros_per_batch * mb;
        let pre = DatasetSpec::preset(SyntheticKind::Pretrain, mc.img_size, n, cfg.seed ^ 0x5A)
            .generate("train");
        let mut batcher = Batcher::new(&pre, mb, cfg.micros_per_batch, cfg.seed);
        while let Some(micros) = batcher.next_batch() {
            let masks: Vec<MaskPair> =
                (0..micros.len()).map(|_| MaskPair::ones(mc.depth, mc.heads)).collect();
            self.exec_batch(&micros, &masks, stats)?;
        }
        self.agg.reset_momentum()?;
        for (w, link) in self.links.iter_mut().enumerate() {
            let mut frame = self.buf_pool.checkout();
            proto::encode_ctrl(proto::TAG_RESET, &mut frame);
            link.send_blob(frame)
                .with_context(|| format!("sending momentum reset to worker {w}"))?;
        }
        Ok(())
    }

    /// Evaluate test top-1 on the aggregator replica (full forward).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mb = self.agg.eval_micro_batch();
        let mut meter = Meter::new();
        let mut i = 0;
        while i + mb <= self.test.len() {
            let idxs: Vec<usize> = (i..i + mb).collect();
            let (x, y) = self.test.gather(&idxs);
            let out = self.agg.eval(&x, &y, None)?;
            meter.push(out.loss, out.n_correct, mb);
            i += mb;
        }
        Ok((meter.top1(), meter.mean_loss()))
    }

    /// Graceful cluster teardown: send every worker a shutdown frame,
    /// collect their Bye acknowledgments (local pool counters), and
    /// join reader threads, worker threads, and worker subprocesses.
    /// Idempotent; run at the end of [`DistTrainer::run`] so the report
    /// can include worker-side counters, and again (as a no-op) on
    /// drop.
    fn shutdown_workers(&mut self) -> Result<()> {
        if self.shut_down {
            return Ok(());
        }
        self.shut_down = true;
        for (w, link) in self.links.iter_mut().enumerate() {
            let mut frame = self.buf_pool.checkout();
            proto::encode_ctrl(proto::TAG_SHUTDOWN, &mut frame);
            link.send_blob(frame)
                .with_context(|| format!("sending shutdown to worker {w}"))?;
        }
        let mut byes = 0;
        while byes < self.links.len() {
            match self.arrivals.recv_timeout(Duration::from_secs(60)) {
                Ok(Arrival::Bye { fresh, reused, .. }) => {
                    byes += 1;
                    self.bye_fresh += fresh;
                    self.bye_reused += reused;
                }
                Ok(Arrival::Up { worker, .. }) => {
                    anyhow::bail!("worker {worker} sent a gradient during shutdown")
                }
                Ok(Arrival::Lost { worker, error }) => {
                    anyhow::bail!("dist worker {worker} died during shutdown: {error}")
                }
                Err(_) => anyhow::bail!(
                    "timed out waiting for worker Bye frames ({byes} of {} received)",
                    self.links.len()
                ),
            }
        }
        for handle in self.readers.drain(..) {
            let _ = handle.join();
        }
        for handle in self.worker_threads.drain(..) {
            let _ = handle.join();
        }
        for mut child in self.worker_procs.drain(..) {
            let _ = child.wait();
        }
        Ok(())
    }

    /// Run the full distributed fine-tuning loop.
    pub fn run(&mut self) -> Result<DistReport> {
        let cfg = self.cfg.train.clone();
        let mb = self.agg.micro_batch();
        let k = self.links.len();
        // Pretrain traffic is accounted separately: its all-ones masks
        // ship dense messages, which would dilute the fine-tuning
        // savings headline if folded in.
        let mut pretrain_stats = WireStats::default();
        self.pretrain(&mut pretrain_stats)?;
        let mut stats = WireStats::default();

        let mut scheduler = build_scheduler(cfg.scheduler, cfg.scores, cfg.seed);
        let budget = match &cfg.hetero {
            Some(h) => h.budget(cfg.budget.clone(), self.partition.n_subnets()),
            None => cfg.budget.clone(),
        };
        let cost = CostModel::paper();
        let n_devices = self.partition.n_subnets();
        let mut workloads = WorkloadTracker::new(cost, n_devices);
        // The simulated engine still runs for the modeled accounting —
        // that is exactly what the measured numbers are compared
        // against. Its exec-time model starts at the paper's V100 table
        // and, when calibration is on, is rescaled at every epoch
        // boundary from *this* run's measured per-task times.
        let mut ecfg = EngineConfig::accounting(cfg.exec, cfg.seed);
        ecfg.bytes_per_fullop = self.codec.dense_len() as u64;
        let mut exec_model = ExecTimeModel::paper();
        let mut engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
        // Calibration state. Two signals per epoch: (a) the per-task
        // least-squares system that splits the measured times into p_f
        // vs p_o factors, and (b) per-batch modeled device rows, so the
        // split factors can be renormalized to keep the modeled
        // makespan matched to the measured straggler (the drift
        // anchor). After the first calibration, each further epoch
        // contributes a modeled-vs-measured drift sample.
        let mut op_cal = OpCalibrator::new();
        let mut ep_rows: Vec<Vec<(f64, f64)>> = Vec::new();
        let mut calib_scale_full = 1.0f64;
        let mut calib_scale_fwd = 1.0f64;
        let mut calib_epochs = 0usize;
        let mut drift_sum = 0.0f64;
        let mut drift_n = 0usize;
        let mut ep_meas = 0.0f64;
        let mut ep_model = 0.0f64;
        let mut ep_batches = 0usize;
        let mut usage = DeviceUsage::new(n_devices);
        let mut worker_usage = DeviceUsage::new(k);
        let mut loss_curve = Vec::with_capacity(cfg.batches);
        let mut eval_curve = Vec::new();
        let mut score_cache: Vec<Option<ScoreBook>> = Vec::new();
        let mut exec_ms_sum = 0.0;
        let mut makespan_sum = 0.0;
        let mut modeled_wire_bytes = 0u64;
        let mut step_ms_sum = 0.0;
        let mut meter = Meter::new();

        // Cloned so the epoch iterator does not hold a borrow of `self`
        // across the `exec_batch` calls.
        let train_data = self.train.clone();
        let t0 = Instant::now();
        let mut batch_idx = 0;
        'outer: while batch_idx < cfg.batches {
            let mut batcher = Batcher::new(&train_data, mb, cfg.micros_per_batch, cfg.seed);
            let mut epoch_pos = 0usize;
            while let Some(micros) = batcher.next_batch() {
                if batch_idx >= cfg.batches {
                    break 'outer;
                }
                // --- contribution scores (cached, aggregator-side) --------
                if score_cache.len() <= epoch_pos {
                    score_cache.resize(epoch_pos + 1, None);
                }
                if score_cache[epoch_pos].is_none() {
                    // Keep this guard in lockstep with the serial
                    // trainer's score-cache block — the bitwise
                    // serial ≡ dist contract depends on it.
                    let can_probe = self.agg.supports_probe();
                    score_cache[epoch_pos] = Some(if scheduler.needs_scores() && can_probe {
                        let probes: Vec<Tensor> = micros
                            .iter()
                            .map(|(x, y)| self.agg.score_probe(x, y))
                            .collect::<Result<_>>()?;
                        ScoreBook::from_probes(&self.partition, &probes)
                    } else {
                        ScoreBook::zeros(self.partition.n_subnets(), micros.len())
                    });
                }
                let book = score_cache[epoch_pos].as_ref().unwrap();
                // --- schedule + distributed execution ---------------------
                let table = scheduler.schedule(book, &budget);
                let masks = table.all_masks(&self.partition);
                let ts = Instant::now();
                let out = self.exec_batch(&micros, &masks, &mut stats)?;
                step_ms_sum += ts.elapsed().as_secs_f64() * 1e3;
                for &(loss, n_correct) in &out.outs {
                    meter.push(loss, n_correct, mb);
                    loss_curve.push(loss);
                }
                worker_usage.record(&out.worker_ms);
                // --- modeled accounting (the comparison baseline) ---------
                let cluster = engine.execute(&table);
                workloads.record(&table);
                workloads.record_measured(&cluster.measured_ms());
                usage.record(&cluster.finish_ms());
                exec_ms_sum += cluster.mean_device_ms;
                makespan_sum += cluster.makespan_ms;
                modeled_wire_bytes += cluster.wire_bytes;
                // Calibration samples: each task's measured compute
                // against its modeled p_f/p_o components (for the op
                // split), the batch's measured straggler against the
                // modeled makespan (for the drift anchor), and the
                // modeled device rows (for the renormalization).
                for (i, &ms) in out.micro_ms.iter().enumerate() {
                    let (mf, mo) = exec_model.micro_components(&table, i);
                    op_cal.observe(mf, mo, ms);
                }
                ep_rows.push(
                    (0..n_devices).map(|d| exec_model.device_row_components(&table, d)).collect(),
                );
                ep_meas += out.worker_ms.iter().copied().fold(0.0, f64::max);
                ep_model += cluster.makespan_ms;
                ep_batches += 1;
                if cfg.eval_every > 0 && (batch_idx + 1) % cfg.eval_every == 0 {
                    let (top1, _) = self.evaluate()?;
                    eval_curve.push((batch_idx + 1, top1));
                }
                batch_idx += 1;
                epoch_pos += 1;
            }
            // ---- epoch boundary: drift report + recalibration --------
            // Means over the epoch (not single batches) so host noise
            // averages out of both the drift metric and the scale.
            if ep_batches > 0 {
                let meas = ep_meas / ep_batches as f64;
                let model = ep_model / ep_batches as f64;
                if calib_epochs > 0 {
                    drift_sum += rel_drift(model, meas);
                    drift_n += 1;
                }
                if self.cfg.calibrate && meas > 0.0 && model > 0.0 {
                    // Two-stage feedback: the least-squares solve gives
                    // the p_f : p_o *shape* from per-task measurements;
                    // the factors are then renormalized so the epoch's
                    // mean modeled makespan under the new tables equals
                    // the measured straggler mean — the same fixed
                    // point the uniform calibration converged to, now
                    // with per-op structure. A degenerate system (e.g.
                    // a schedule with no p_o tasks) falls back to the
                    // uniform measured/modeled ratio.
                    let uniform = meas / model;
                    let (pf, po) = match op_cal.solve() {
                        Some((pf_raw, po_raw)) => {
                            let renorm: f64 = ep_rows
                                .iter()
                                .map(|rows| {
                                    rows.iter()
                                        .map(|&(f, o)| pf_raw * f + po_raw * o)
                                        .fold(0.0, f64::max)
                                })
                                .sum::<f64>()
                                / ep_rows.len() as f64;
                            if renorm > 0.0 {
                                let u = meas / renorm;
                                (pf_raw * u, po_raw * u)
                            } else {
                                (uniform, uniform)
                            }
                        }
                        None => (uniform, uniform),
                    };
                    exec_model = exec_model.scaled_per_op(pf, po);
                    calib_scale_full *= pf;
                    calib_scale_fwd *= po;
                    engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
                    calib_epochs += 1;
                }
                op_cal.reset();
                ep_rows.clear();
                ep_meas = 0.0;
                ep_model = 0.0;
                ep_batches = 0;
            }
        }
        // A run that ends mid-epoch still reports the partial epoch's
        // drift (it just never feeds another calibration).
        if ep_batches > 0 && calib_epochs > 0 {
            drift_sum += rel_drift(ep_model / ep_batches as f64, ep_meas / ep_batches as f64);
            drift_n += 1;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let (test_top1, test_loss) = self.evaluate()?;
        // Tear the cluster down *inside* run so the report can fold in
        // the worker-side pool counters and the final socket totals.
        self.shutdown_workers()?;
        let mut socket = TransportStats::default();
        for cell in &self.link_stats {
            socket.merge(&cell.snapshot());
        }
        // In channel mode every party shares the aggregator's pool (one
        // set of counters); in TCP mode each process pools locally and
        // reports its counters in its Bye frame.
        let (buf_fresh, buf_reused) = match self.cfg.transport {
            TransportKind::Channel => (self.buf_pool.fresh_allocs(), self.buf_pool.reuses()),
            TransportKind::Tcp { .. } => (
                self.buf_pool.fresh_allocs() + self.bye_fresh,
                self.buf_pool.reuses() + self.bye_reused,
            ),
        };
        let b = workloads.batches().max(1) as f64;
        let train = TrainReport {
            scheduler: cfg.scheduler.label().to_string(),
            backend: self.agg.label().to_string(),
            final_train_loss: meter.mean_loss(),
            test_top1,
            test_loss,
            loss_curve,
            eval_curve,
            compute_fraction: workloads.total_compute_fraction(),
            comm_fraction: workloads.total_comm_fraction(),
            workload_variance: workloads.workload_variance(),
            sample_count_variance: workloads.sample_count_variance(),
            mean_exec_ms: exec_ms_sum / b,
            makespan_ms: makespan_sum / b,
            engine: format!(
                "dist({k} workers, {}, {})",
                self.cfg.exchange.label(),
                self.cfg.transport.label()
            ),
            utilization: usage.mean_utilization(),
            imbalance: usage.imbalance(),
            // Real straggler: slowest worker's measured time per batch.
            straggler_ms: worker_usage.total_makespan_ms() / worker_usage.steps().max(1) as f64,
            wall_s,
            batches: batch_idx,
            calib_scale: (calib_scale_full * calib_scale_fwd).sqrt(),
            calib_scale_full,
            calib_scale_fwd,
            calib_epochs,
            makespan_drift: if drift_n > 0 { drift_sum / drift_n as f64 } else { 0.0 },
        };
        let n_batches = worker_usage.steps().max(1) as f64;
        Ok(DistReport {
            grad_savings: stats.grad_savings(),
            n_workers: k,
            exchange: self.cfg.exchange.label().to_string(),
            transport: self.cfg.transport.label().to_string(),
            wire: stats,
            pretrain_wire: pretrain_stats,
            socket,
            modeled_wire_bytes,
            mean_step_ms: step_ms_sum / n_batches,
            worker_busy_ms: worker_usage.busy_ms().to_vec(),
            worker_utilization: worker_usage.mean_utilization(),
            worker_imbalance: worker_usage.imbalance(),
            encode_buf_fresh: buf_fresh,
            encode_buf_reused: buf_reused,
            train,
        })
    }
}

impl Drop for DistTrainer {
    fn drop(&mut self) {
        if !self.shut_down {
            // Best effort: a shutdown frame lets live workers exit
            // cleanly; closing the links afterwards unblocks any that
            // missed it.
            for link in &mut self.links {
                let mut frame = Vec::new();
                proto::encode_ctrl(proto::TAG_SHUTDOWN, &mut frame);
                let _ = link.send_blob(frame);
            }
        }
        self.links.clear();
        for handle in self.readers.drain(..) {
            let _ = handle.join();
        }
        for handle in self.worker_threads.drain(..) {
            let _ = handle.join();
        }
        for mut child in self.worker_procs.drain(..) {
            let _ = child.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeSpec;
    use crate::coordinator::SchedulerKind;
    use crate::runtime::ModelConfig;
    use crate::schedule::Budget;

    fn small_provider() -> NativeProvider {
        NativeProvider::new(NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xBEEF,
            threads: 1,
        })
    }

    fn quick_cfg() -> TrainerConfig {
        TrainerConfig {
            train_size: 60,
            test_size: 12,
            batches: 2,
            pretrain_batches: 1,
            ..TrainerConfig::quick(
                crate::data::SyntheticKind::Cifar10Like,
                SchedulerKind::D2ft,
                Budget::uniform(5, 3, 1),
            )
        }
    }

    #[test]
    fn dist_trainer_runs_and_counts_bytes() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        let r = dt.run().unwrap();
        assert_eq!(r.n_workers, 2);
        assert_eq!(r.transport, "channel");
        assert_eq!(r.train.batches, 2);
        assert_eq!(r.train.loss_curve.len(), 10);
        assert!(r.train.final_train_loss.is_finite());
        assert!(r.wire.up_bytes > 0 && r.wire.down_bytes > 0);
        // 3 p_f + 1 p_o of 5 leaves head slices off the wire.
        assert!(r.grad_savings > 0.0, "masked schedule must save bytes");
        assert!(r.wire.up_bytes < r.wire.dense_up_bytes);
        assert_eq!(r.worker_busy_ms.len(), 2);
        // The transport layer saw every gradient frame plus the control
        // traffic (init/jobs/broadcasts), in both directions.
        assert!(r.socket.bytes_sent > 0 && r.socket.bytes_recv > 0);
        assert!(r.socket.bytes_recv >= r.wire.up_bytes + r.pretrain_wire.up_bytes);
        assert!(r.socket.frames_recv >= r.wire.up_msgs + r.pretrain_wire.up_msgs);
    }

    #[test]
    fn overlap_off_matches_overlap_on_bitwise() {
        // The pipelined sender changes *when* bytes move, never which
        // bytes or how they reduce: trajectories and parameters must be
        // bit-equal with the pipeline on and off.
        let provider = small_provider();
        let run = |overlap: bool| {
            let dcfg = DistConfig { overlap, ..DistConfig::new(quick_cfg(), 3) };
            let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
            let r = dt.run().unwrap();
            let w = dt.backend().param("b00_wqkv").unwrap();
            (r, w)
        };
        let (on, w_on) = run(true);
        let (off, w_off) = run(false);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&on.train.loss_curve), bits(&off.train.loss_curve));
        assert_eq!(w_on, w_off, "overlap must not move a single parameter bit");
        assert_eq!(on.wire.up_bytes, off.wire.up_bytes, "same bytes either way");
    }

    #[test]
    fn encode_buffers_recycle_in_steady_state() {
        // Zero per-task allocations after warmup: fresh buffer count is
        // bounded by what can be in flight at once (job frames, double
        // buffers, one batch's gradient messages, broadcast copies),
        // never by how many batches ran.
        let provider = small_provider();
        let mut cfg = quick_cfg();
        cfg.batches = 8;
        let workers = 2u64;
        let micros = 5u64;
        let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, workers as usize)).unwrap();
        let r = dt.run().unwrap();
        let in_flight_bound = 2 * micros + 6 * workers + 8;
        assert!(
            r.encode_buf_fresh <= in_flight_bound,
            "fresh allocations ({}) exceed the in-flight bound ({in_flight_bound}) — \
             the recycle loop is broken",
            r.encode_buf_fresh
        );
        assert!(
            r.encode_buf_reused > r.encode_buf_fresh,
            "most checkouts must be recycled: fresh {} vs reused {}",
            r.encode_buf_fresh,
            r.encode_buf_reused
        );
        // Every gradient message took exactly one checkout on its way
        // out of a worker (plus control traffic on top).
        assert!(
            r.encode_buf_fresh + r.encode_buf_reused
                >= r.wire.up_msgs + r.pretrain_wire.up_msgs,
            "pool counters must cover every uplink message"
        );
    }

    #[test]
    fn f16_wire_halves_measured_bytes_and_trains() {
        let provider = small_provider();
        let run = |prec| {
            let dcfg =
                DistConfig { wire_precision: prec, ..DistConfig::new(quick_cfg(), 2) };
            DistTrainer::new(&provider, dcfg).unwrap().run().unwrap()
        };
        let r32 = run(WirePrecision::F32);
        let r16 = run(WirePrecision::F16);
        assert!(r16.train.final_train_loss.is_finite());
        assert_eq!(r32.wire.up_msgs, r16.wire.up_msgs);
        let ratio = r16.wire.up_bytes as f64 / r32.wire.up_bytes as f64;
        assert!(
            ratio < 0.52,
            "f16 must roughly halve the measured uplink, got {ratio:.3}"
        );
        // f16 + parameter server is rejected up front.
        let bad = DistConfig {
            wire_precision: WirePrecision::F16,
            exchange: ExchangeMode::ParamServer,
            ..DistConfig::new(quick_cfg(), 2)
        };
        assert!(DistTrainer::new(&provider, bad).is_err());
    }

    #[test]
    fn worker_count_must_be_positive() {
        let provider = small_provider();
        assert!(DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 0)).is_err());
    }

    #[test]
    fn assignment_balances_by_measured_ema() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        // Pretend worker 1 is 3x slower than worker 0.
        dt.ema_ms = vec![1.0, 3.0];
        let a = dt.assign(4);
        let w0 = a.iter().filter(|&&w| w == 0).count();
        let w1 = a.iter().filter(|&&w| w == 1).count();
        assert!(w0 > w1, "fast worker takes more micro-batches: {a:?}");
        assert_eq!(w0 + w1, 4);
    }

    #[test]
    fn per_op_calibration_converges_and_reports_split_factors() {
        // Two epochs over a mixed p_f/p_o schedule: the epoch boundary
        // must produce at least one calibration with finite positive
        // split factors, and the geometric-mean scale must agree with
        // the reported per-op factors.
        let provider = small_provider();
        let mut cfg = quick_cfg();
        cfg.train_size = 40; // 4 batches/epoch at mb 2 x 5 micros
        cfg.batches = 8;
        let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, 2)).unwrap();
        let r = dt.run().unwrap();
        assert!(r.train.calib_epochs >= 1, "two epochs must calibrate at least once");
        assert!(r.train.calib_scale_full.is_finite() && r.train.calib_scale_full > 0.0);
        assert!(r.train.calib_scale_fwd.is_finite() && r.train.calib_scale_fwd > 0.0);
        let geo = (r.train.calib_scale_full * r.train.calib_scale_fwd).sqrt();
        assert!((r.train.calib_scale - geo).abs() < 1e-12);
    }
}
