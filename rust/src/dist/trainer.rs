//! `DistTrainer`: the live data-parallel fine-tuning driver.
//!
//! K workers each own a full [`NativeBackend`] replica built from the
//! same deterministic init. Per scheduled batch the aggregator assigns
//! every micro-batch to a worker (straggler-aware, see below), each
//! worker runs the masked forward/backward **for real** against the
//! shared parameter snapshot, serializes the masked gradient
//! ([`super::grads`]), and the aggregator reduces the messages in fixed
//! micro order and applies one fused SGD-momentum update — then either
//! broadcasts the reduced masked gradient (workers re-apply the same
//! update locally) or, in parameter-server mode, the dense update
//! deltas. Per-link FIFO ordering doubles as the sync barrier: a worker
//! always installs the batch-`b` update before it sees a batch-`b+1`
//! compute job.
//!
//! ## The transport seam
//!
//! Every aggregator ↔ worker exchange travels as a [`super::proto`]
//! frame over a [`Transport`] link ([`super::transport`]):
//! [`TransportKind::Channel`] keeps the workers as threads of this
//! process (the PR 3/4 shape), [`TransportKind::Tcp`] runs the *same*
//! [`super::worker::run_worker`] loop in separate threads, forked
//! `repro dist-worker` subprocesses, or externally launched processes
//! on other hosts. Both transports deliver identical bytes in identical
//! per-link order, so the trainer is **bitwise identical across
//! transports** — `tests/dist_tcp.rs` pins serial ≡ channel ≡ tcp.
//!
//! ## Surviving the coordinator
//!
//! The aggregator is no longer the one process that must not die.
//! Epoch checkpoints are written atomically (tmp + fsync + rename,
//! rotated to `checkpoint_retain`), a step-granular `progress.d2pr`
//! record is rewritten after every batch, and `resume_from` pointed at
//! the checkpoint *directory* restarts from the newest loadable
//! checkpoint — re-executing the tail deterministically, so the
//! resumed trajectory is bitwise the uninterrupted one. TCP workers
//! that outlive the aggregator redial with capped exponential backoff
//! ([`super::worker::run_worker_reconnecting`]) and re-Join carrying
//! the incarnation token from their last Init; the restarted
//! aggregator counts those as `reconnects`, re-ships State, and
//! continues. Mid-run, a dropped link gets one `try_reconnect` accept
//! window before eviction, and a frame that fails its CRC32C trailer
//! surfaces as [`Arrival::Corrupt`] — answered with a NACK for a
//! resend, never an eviction.
//!
//! ## Determinism
//!
//! Every micro-batch gradient is computed by exactly one worker whose
//! replica is bitwise identical to the serial trainer's model at the
//! same point; the wire format is lossless; the reduction order is
//! fixed. So the whole trajectory — losses, parameters, eval accuracy —
//! is bitwise identical to the serial [`crate::coordinator::Trainer`]
//! under [`UpdateMode::BatchAccum`], for *any* worker count, either
//! exchange mode, and either transport. Placement (which worker
//! computes which micro-batch) is measured-time dependent and
//! deliberately free: it can shift work away from real stragglers
//! without touching a single bit of the math.
//!
//! ## Pipeline (comm/compute overlap)
//!
//! Each worker splits into a compute thread and a dedicated sender
//! thread joined by a bounded one-slot channel: while task *i*'s
//! gradient is being encoded and uploaded, task *i+1*'s `grad_step`
//! already runs (see [`super::worker`]). The handoff carries owned
//! gradients, the aggregator only broadcasts a batch's update after
//! every uplink of that batch arrived, and the [`OrderedReducer`] fixes
//! the reduction order — so pipelining is bitwise invisible.
//!
//! ## Measurement and calibration
//!
//! Uplink/downlink gradient bytes are counted on the actual serialized
//! messages ([`WireStats`]); the transport layer separately counts the
//! raw frame bytes that crossed each link ([`TransportStats`] — for
//! TCP, real socket traffic). Per-worker task times are wall-clock
//! measurements around the real gradient computation and feed (a) the
//! assignment balancer (EMA per worker), (b) the workload/usage
//! accounting, and (c) a per-epoch calibration of the modeled
//! [`ExecTimeModel`]: a per-task least-squares split of the measured
//! times into separate `p_f` and `p_o` factors
//! ([`crate::cluster::OpCalibrator`]), renormalized so the modeled
//! makespan matches the measured straggler — heterogeneous op costs
//! are tracked per op, and the modeled-vs-measured drift
//! (`TrainReport::makespan_drift`) stays anchored.

use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::allreduce::{ExchangeMode, OrderedReducer};
use super::checkpoint::{ckpt_path, fnv64, latest_valid, rotate, Checkpoint, Progress};
use super::fault::{FaultAction, FaultPlan};
use super::grads::{BufPool, GradCodec, WireCompression, WirePrecision, WireStats};
use super::proto::{self, CastRole, InitMsg, MicroJob, RingExec, UpHdr};
use super::transport::{
    accept_workers, channel_pair, frame_class, is_corrupt_frame_err, listen, liveness_window,
    BlobRx, BlobTx, FlakyState, FlakyTransport, SpawnMode, StatsCell, TcpTransport, Transport,
    TransportKind, TransportStats, FRAME_CLASSES,
};
use super::worker::{run_worker, run_worker_reconnecting, run_worker_with_faults};
use crate::backend::native::NativeSpec;
use crate::backend::native::{NativeBackend, NativeProvider};
use crate::backend::Backend;
use crate::cluster::{
    CostModel, Engine, EngineConfig, ExecTimeModel, OpCalibrator, WorkloadTracker,
};
use crate::coordinator::{build_scheduler, prepare_run, TrainReport, TrainerConfig, UpdateMode};
use crate::data::{Batcher, Dataset, DatasetSpec, SyntheticKind};
use crate::metrics::{rel_drift, DeviceUsage, Meter};
use crate::obs::metrics::Registry;
use crate::obs::trace;
use crate::partition::Partition;
use crate::util::json::Json;
use crate::schedule::{MaskPair, Scheduler};
use crate::scores::ScoreBook;
use crate::tensor::Tensor;

/// Configuration of one distributed run: the full serial trainer config
/// plus the cluster shape.
///
/// `#[non_exhaustive]`: construct via [`DistConfig::builder`] (or the
/// [`DistConfig::new`] default shorthand) — fields stay pub for reading
/// and targeted mutation, but the struct-literal form is reserved to
/// the builder module ([`crate::config`]).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct DistConfig {
    /// The training run (dataset, schedule, budget, seed, ...). The
    /// update mode is forced to [`UpdateMode::BatchAccum`] — the only
    /// semantics a synchronous data-parallel cluster can implement.
    pub train: TrainerConfig,
    /// Worker replica count (>= 1).
    pub workers: usize,
    /// Gradient exchange topology.
    pub exchange: ExchangeMode,
    /// How frames move between the aggregator and its workers:
    /// in-process channels (threads) or TCP (threads, forked `repro
    /// dist-worker` subprocesses, or external/multi-host workers).
    /// Numerics are bitwise identical either way.
    pub transport: TransportKind,
    /// Pipeline each worker's encode + upload of task *i* behind task
    /// *i+1*'s gradient computation (a dedicated sender thread per
    /// worker, double-buffered handoff). Default `true`; `false` is the
    /// serialized reference path — `benches/dist_step.rs` measures the
    /// gap. Bitwise-neutral either way (the bytes are identical and the
    /// reduction order is fixed).
    pub overlap: bool,
    /// Gradient payload precision on the wire. The `F32` default is
    /// lossless (bitwise serial ≡ dist). `F16` halves the measured
    /// bytes; the aggregate gradient is then requantized before
    /// *anyone* (aggregator included) applies it, so all replicas still
    /// agree bitwise with each other — only with the serial trainer do
    /// they diverge. Gradient exchanges only (not parameter-server).
    pub wire_precision: WirePrecision,
    /// Lossy payload compression under the precision layer: `None`
    /// (bitwise reference, default), `Int8`/`Int4` quantization with
    /// per-slice scales and worker-side error feedback, or `TopK`
    /// sparsification. Every replica — aggregator included — applies
    /// the exact bytes that crossed the wire, so the cluster stays
    /// internally bitwise consistent; only against the serial f32
    /// trainer do lossy modes diverge (boundedly, via error feedback).
    /// Gradient exchanges only (masked-allreduce / ring /
    /// hierarchical), not the parameter-server delta broadcast.
    pub compress: WireCompression,
    /// Group size for [`ExchangeMode::Hierarchical`]: the chain over
    /// the live workers is cut into contiguous groups of this size and
    /// each group's leader receives the reduced gradient directly from
    /// the aggregator, then casts it intra-group. `0` picks ⌈√K⌉.
    /// Ignored by the other exchange modes.
    pub ring_group: usize,
    /// Simulated NIC cost in milliseconds per MiB of *actual encoded
    /// message*, slept on the uplink path (sender thread when
    /// overlapping, compute thread when serialized). 0 disables it.
    /// This is a bench/experiment knob: in-process channels are
    /// effectively free, so hiding a modeled wire behind compute is how
    /// the comm/compute-overlap claim becomes measurable on one host.
    pub sim_wire_ms_per_mib: f64,
    /// Recalibrate the modeled [`ExecTimeModel`] from measured per-task
    /// times at every epoch boundary (see `DistReport::train`'s
    /// `calib_*` fields). Default `true`; scheduling decisions are
    /// placement-only, so calibration never touches the numerics.
    pub calibrate: bool,
    /// Worker heartbeat interval in milliseconds. Workers ping on a
    /// dedicated thread at this cadence, so a slow-but-alive worker
    /// (long compute, scripted stall) keeps its link warm. 0 disables
    /// heartbeats *and* liveness eviction.
    pub heartbeat_ms: u64,
    /// Consecutive missed heartbeat intervals before a silent link is
    /// declared dead (see [`liveness_window`]). The deadline scales
    /// with `heartbeat_ms`, never with compute load.
    pub liveness_misses: u32,
    /// How long the aggregator waits on an incomplete batch barrier
    /// before duplicating the unfilled micro-batches onto other live
    /// workers (straggler reassignment — bitwise harmless, replicas
    /// compute identical gradients).
    pub stall_reassign_ms: u64,
    /// Hard per-batch deadline: a batch that cannot complete within
    /// this bound fails descriptively instead of hanging forever.
    pub batch_timeout_ms: u64,
    /// Scripted fault plans per worker slot (`(worker, plan)`), acted
    /// out by the worker against its gradient-send counter and by the
    /// aggregator for [`FaultAction::RejoinAtEpoch`]. Tests/chaos only;
    /// empty in production runs.
    pub faults: Vec<(usize, FaultPlan)>,
    /// Directory for epoch-boundary checkpoints (`ckpt_e{N}.d2ck`);
    /// `None` disables checkpointing. The same directory holds the
    /// step-granular `progress.d2pr` record, rewritten (atomically)
    /// after every completed batch.
    pub checkpoint_dir: Option<PathBuf>,
    /// Write a checkpoint every N completed epochs (min 1).
    pub checkpoint_every: usize,
    /// Epoch checkpoints kept after rotation (min 1): every successful
    /// write deletes `ckpt_e*.d2ck` files older than the newest N, so a
    /// long run cannot fill the disk.
    pub checkpoint_retain: usize,
    /// Resume a crashed run. A *directory* resumes from its newest
    /// loadable checkpoint plus the `progress.d2pr` restart counter —
    /// the `--resume` crash-recovery path; a *file* is the legacy exact
    /// checkpoint form. Either way the run installs the checkpoint's
    /// parameters, momentum, and score cache, skips pretraining, and
    /// re-executes deterministically from the checkpoint's batch —
    /// bitwise identical to the uninterrupted run.
    pub resume_from: Option<PathBuf>,
    /// Crash simulation (tests only): stop dead — no shutdown
    /// handshake, `run` returns an error — right after completing this
    /// many batches, with that batch's progress record already on disk.
    /// Deterministic stand-in for SIGKILL in the in-process
    /// crash/`--resume` bitwise matrix.
    pub halt_after_batch: Option<usize>,
    /// Write a merged Chrome trace-event JSON (aggregator + every
    /// worker lane, clocks normalized via the Init handshake) here at
    /// the end of the run — open it in Perfetto. `None` (the default)
    /// leaves the recorder disarmed: every `span!`/`instant!` site then
    /// costs a single relaxed atomic load. Tracing is observation-only;
    /// the loss trajectory is bitwise identical either way.
    pub trace_out: Option<PathBuf>,
    /// Metrics registry this run publishes into — step-latency
    /// histogram, wire/socket byte counters, membership counters — the
    /// same instance `--metrics-addr` serves live over HTTP. `None`
    /// (the default) skips publishing entirely. Observation-only.
    pub metrics: Option<Arc<Registry>>,
}

impl DistConfig {
    /// Builder over `train` with `workers` replicas; every construction
    /// site goes through it (see [`crate::config`]).
    pub fn builder(train: TrainerConfig, workers: usize) -> crate::config::DistConfigBuilder {
        crate::config::DistConfigBuilder::new(train, workers)
    }

    /// Masked-allreduce cluster of `workers` replicas with the default
    /// performance knobs: in-process channel transport, overlap on,
    /// lossless f32 wire, no simulated NIC, calibration on.
    ///
    /// Unlike [`DistConfig::builder`] this never fails: a zero worker
    /// count is preserved so `DistTrainer::new` can reject it with its
    /// own descriptive error (tests rely on that path).
    pub fn new(train: TrainerConfig, workers: usize) -> DistConfig {
        let mut cfg = DistConfig::builder(train, workers.max(1))
            .build()
            .expect("default dist knobs always validate");
        cfg.workers = workers;
        cfg
    }
}

/// One membership change in the worker set (for [`DistReport`]).
#[derive(Clone, Debug)]
pub struct MembershipEvent {
    /// Global batch index when the change took effect.
    pub batch: usize,
    /// Worker slot affected.
    pub worker: usize,
    /// `"evict"` or `"join"`.
    pub kind: String,
}

/// Outcome of a distributed run: the serial-comparable training report
/// plus the measured wire and straggler data.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// The standard training report (losses, accuracy, modeled cluster
    /// metrics), field-compatible with the serial trainer's. The
    /// `straggler_ms` field here is the *real* per-batch straggler: the
    /// slowest worker's measured gradient-computation time.
    pub train: TrainReport,
    /// Worker replicas that executed the run.
    pub n_workers: usize,
    /// Exchange topology label (`masked-allreduce` / `param-server` /
    /// `ring` / `hierarchical`).
    pub exchange: String,
    /// Transport label (`channel` / `tcp`).
    pub transport: String,
    /// Measured bytes on the wire for the *scheduled fine-tuning*
    /// batches (actual serialized messages) — the traffic the paper's
    /// communication claim is about.
    pub wire: WireStats,
    /// Measured bytes for the synthetic pre-training phase (all-ones
    /// masks, so uplink is always dense). Kept separate so
    /// [`DistReport::grad_savings`] and the measured-vs-modeled
    /// comparison are not diluted by unscheduled traffic.
    pub pretrain_wire: WireStats,
    /// Transport-layer totals over all aggregator-side links — whole
    /// frames including control messages, handshakes, and (for TCP)
    /// length prefixes: the bytes that actually crossed the socket,
    /// reported next to the modeled bytes by `benches/dist_step.rs`.
    pub socket: TransportStats,
    /// Per-link transport totals in link-creation order (worker slots
    /// first; rejoins append). Each entry carries the per-frame-class
    /// byte breakdown, so "what did worker 3's Deltas channel cost"
    /// is answerable without re-running.
    pub socket_links: Vec<TransportStats>,
    /// Per-worker `(sent, recv)` bytes over worker↔worker ring links
    /// (from Bye frames; all zeros for the star topologies). This is
    /// the traffic the aggregator's own sockets never see — the bench
    /// adds it to the per-node totals when checking ring flatness.
    pub ring_bytes: Vec<(u64, u64)>,
    /// Wire-compression label (`none` / `int8` / `int4` / `topk:P`).
    pub compress: String,
    /// Uplink gradient bytes saved vs the unmasked schedule (measured).
    pub grad_savings: f64,
    /// What the simulated engine *modeled* for the same schedules, for
    /// the measured-vs-modeled comparison (DESIGN.md §dist).
    pub modeled_wire_bytes: u64,
    /// Mean measured wall time per fine-tuning batch (dispatch through
    /// aggregator update), ms.
    pub mean_step_ms: f64,
    /// Accumulated measured busy time per worker (ms).
    pub worker_busy_ms: Vec<f64>,
    /// Mean worker utilization (busy / per-batch makespan).
    pub worker_utilization: f64,
    /// Worker straggler-over-mean imbalance (0 = perfectly balanced).
    pub worker_imbalance: f64,
    /// Encode/frame buffers allocated fresh over the whole run, summed
    /// across every pool in the cluster (the aggregator's, plus — in
    /// TCP mode, where each process recycles locally — the per-worker
    /// pools reported in their Bye frames). Steady state: bounded by
    /// in-flight messages, not by batch count — the zero-allocation
    /// hot-loop property, pinned by tests.
    pub encode_buf_fresh: u64,
    /// Buffer checkouts served by recycling (same pools).
    pub encode_buf_reused: u64,
    /// Worker slots still live when the run finished.
    pub live_workers: usize,
    /// Workers evicted by the control plane (lost links, liveness
    /// deadline misses, undecodable frames, failed sends).
    pub evictions: usize,
    /// Workers that (re)joined mid-run via the elastic handshake.
    pub joins: usize,
    /// Worker links that re-attached instead of being evicted: mid-run
    /// redials accepted inside the liveness window, plus handshake
    /// Joins that presented a learned identity (a surviving worker
    /// redialing into a restarted aggregator).
    pub reconnects: usize,
    /// Frames that failed their CRC32C trailer check on an
    /// aggregator-side link. Each one is NACKed for a resend — never
    /// fatal, never an eviction by itself.
    pub frames_corrupt: usize,
    /// NACK frames sent asking a worker to resend its retained
    /// gradient after a corrupt arrival.
    pub resends: usize,
    /// Aggregator generations before this one (from the progress
    /// record's restart counter); 0 for an uninterrupted run.
    pub aggregator_restarts: usize,
    /// Micro-batches re-dispatched to a survivor after a loss or stall
    /// (duplicates are bitwise harmless; see the module docs).
    pub reassigned_micros: usize,
    /// Membership-triggered knapsack re-solves: batches whose schedule
    /// was solved right after an evict/join with freshly reset
    /// straggler EMAs.
    pub knapsack_resolves: usize,
    /// Epochs fully completed (boundary count).
    pub epochs: usize,
    /// Epoch-boundary checkpoints written to `checkpoint_dir`.
    pub checkpoints_written: usize,
    /// Every membership change, in order.
    pub membership: Vec<MembershipEvent>,
}

impl DistReport {
    /// Serialize the parts of the report the chaos CI step inspects —
    /// loss/accuracy, membership churn, byte totals, and the recovery
    /// counters — as JSON (the `--report-json` artifact).
    ///
    /// The shape is a contract: `schema_version` gates consumers, and
    /// `tests/dist_report_schema.rs` pins the exact key set. Adding a
    /// key means bumping the version and updating that golden test; the
    /// legacy `schema` string stays for scripts that match on it.
    pub fn to_json(&self) -> Json {
        crate::report::dist_report_json(self)
    }
}

/// What a reader thread forwards from one worker's link into the
/// aggregator's single arrival queue.
enum Arrival {
    /// One computed micro-batch gradient (frame tail holds the blob).
    Up { worker: usize, hdr: UpHdr, frame: Vec<u8> },
    /// A ring control frame (Addr / Ready / Final) forwarded verbatim —
    /// the ring orchestrator decodes it against the step it is waiting
    /// on; anything stale is dropped there, not here.
    Ring { worker: usize, frame: Vec<u8> },
    /// Shutdown acknowledgment with the worker's local counters.
    Bye { worker: usize, msg: proto::ByeMsg },
    /// One frame failed its CRC32C trailer check. The stream itself is
    /// intact — the length prefix framed the damaged bytes — so the
    /// reader keeps draining the link; the trainer answers with a NACK
    /// so the worker resends its retained gradient.
    Corrupt { worker: usize },
    /// The link died or produced an undecodable frame. Surfaced as an
    /// error by whoever is waiting — a lost worker can never hang the
    /// barrier.
    Lost { worker: usize, error: String },
}

/// Drain one worker's uplink into the shared arrival queue. Exits on
/// Bye (clean shutdown), on link/decode failure (after forwarding a
/// [`Arrival::Lost`]), when the link stays silent past the liveness
/// deadline (also [`Arrival::Lost`] — the failure detector), or when
/// the aggregator is gone. Heartbeat Pings are swallowed here: their
/// arrival resets the liveness timer, nothing downstream needs them.
fn reader_loop(
    worker: usize,
    mut rx: Box<dyn BlobRx>,
    tx: mpsc::Sender<Arrival>,
    liveness: Duration,
    pool: Arc<BufPool>,
    traces: Arc<Mutex<Vec<proto::TraceMsg>>>,
) {
    loop {
        let frame = match rx.recv_blob_timeout(liveness) {
            Ok(Some(f)) => f,
            Ok(None) => {
                let _ = tx.send(Arrival::Lost {
                    worker,
                    error: format!(
                        "no frame or heartbeat from worker {worker} ({}) for {liveness:?} — \
                         missed liveness deadline",
                        rx.peer()
                    ),
                });
                return;
            }
            Err(e) if is_corrupt_frame_err(&e) => {
                // The framing survived (only payload bits are bad), so
                // this is retryable: report it and keep draining.
                crate::warn_!(
                    "worker {worker} ({}): dropped a corrupt frame: {e:#}",
                    rx.peer()
                );
                if tx.send(Arrival::Corrupt { worker }).is_err() {
                    return;
                }
                continue;
            }
            Err(e) => {
                let _ = tx.send(Arrival::Lost {
                    worker,
                    error: format!("recv from worker {worker} ({}) failed: {e:#}", rx.peer()),
                });
                return;
            }
        };
        let forwarded = match proto::peek_tag(&frame) {
            Ok(proto::TAG_PING) => {
                let ok = proto::decode_ping(&frame).is_ok();
                pool.give_back(frame);
                if !ok {
                    let _ = tx.send(Arrival::Lost {
                        worker,
                        error: "malformed Ping frame on the uplink".to_string(),
                    });
                    return;
                }
                continue;
            }
            Ok(proto::TAG_UP) => match proto::decode_up(&frame) {
                Ok(hdr) => tx.send(Arrival::Up { worker, hdr, frame }).is_ok(),
                Err(e) => {
                    let _ = tx.send(Arrival::Lost {
                        worker,
                        error: format!(
                            "decoding a {} frame from worker {worker} ({}): {e:#}",
                            FRAME_CLASSES[frame_class(&frame)],
                            rx.peer()
                        ),
                    });
                    return;
                }
            },
            Ok(proto::TAG_RING_ADDR) | Ok(proto::TAG_RING_READY) | Ok(proto::TAG_RING_FINAL) => {
                tx.send(Arrival::Ring { worker, frame }).is_ok()
            }
            Ok(proto::TAG_TRACE) => {
                // Observability side-channel: collect the worker's trace
                // batch for the end-of-run merge. A malformed trace frame
                // is dropped with a warning rather than surfaced as Lost —
                // observation must never evict a worker.
                match proto::decode_trace(&frame) {
                    Ok(msg) => match traces.lock() {
                        Ok(mut sink) => sink.push(msg),
                        Err(poisoned) => poisoned.into_inner().push(msg),
                    },
                    Err(e) => crate::warn_!("worker {worker}: dropping bad trace frame: {e:#}"),
                }
                pool.give_back(frame);
                continue;
            }
            Ok(proto::TAG_BYE) => {
                match proto::decode_bye(&frame) {
                    Ok(msg) => {
                        let _ = tx.send(Arrival::Bye { worker, msg });
                    }
                    Err(e) => {
                        let _ = tx.send(Arrival::Lost {
                            worker,
                            error: format!(
                                "decoding a {} frame from worker {worker} ({}): {e:#}",
                                FRAME_CLASSES[frame_class(&frame)],
                                rx.peer()
                            ),
                        });
                    }
                }
                return;
            }
            Ok(tag) => {
                let _ = tx.send(Arrival::Lost {
                    worker,
                    error: format!(
                        "unexpected frame tag {tag:#x} ({} class) from worker {worker} ({}) \
                         on the uplink",
                        FRAME_CLASSES[frame_class(&frame)],
                        rx.peer()
                    ),
                });
                return;
            }
            Err(e) => {
                let _ = tx.send(Arrival::Lost {
                    worker,
                    error: format!(
                        "reading the frame tag from worker {worker} ({}): {e:#}",
                        rx.peer()
                    ),
                });
                return;
            }
        };
        if !forwarded {
            return;
        }
    }
}

/// Per-batch outcome of one distributed execution.
struct BatchOut {
    /// `(loss, n_correct)` in micro order.
    outs: Vec<(f32, f32)>,
    /// Measured busy ms per worker (0 for idle workers).
    worker_ms: Vec<f64>,
    /// Measured gradient-computation ms per micro-batch (micro order) —
    /// the per-task signal the op-split calibration consumes.
    micro_ms: Vec<f64>,
}

/// The distributed data-parallel trainer (see the module docs).
pub struct DistTrainer {
    cfg: DistConfig,
    /// The aggregator's authoritative replica (scores, eval, updates).
    agg: NativeBackend,
    codec: GradCodec,
    partition: Partition,
    train: Dataset,
    test: Dataset,
    /// The spec every replica is built from (kept for rejoin Inits).
    spec: NativeSpec,
    /// Downlink halves, one per worker slot; `None` = evicted/dead.
    links: Vec<Option<Box<dyn BlobTx>>>,
    /// Fan-in of every worker's uplink (reader threads feed it).
    arrivals: mpsc::Receiver<Arrival>,
    /// Kept open so rejoin can attach new reader threads to the fan-in.
    arr_tx: mpsc::Sender<Arrival>,
    /// The TCP listener (rejoins accept through it; `None` on channel).
    listener: Option<(TcpListener, SocketAddr)>,
    readers: Vec<thread::JoinHandle<()>>,
    /// In-process workers (channel / tcp-threads modes).
    worker_threads: Vec<thread::JoinHandle<()>>,
    /// Forked `repro dist-worker` subprocesses (tcp processes mode).
    worker_procs: Vec<Child>,
    /// Live per-link transport counters (aggregator side).
    link_stats: Vec<Arc<StatsCell>>,
    /// Per-worker EMA of measured ms per micro-batch task — the
    /// straggler signal the assignment balancer reacts to.
    ema_ms: Vec<f64>,
    /// Recycled frame/encode buffers (aggregator side; in channel mode
    /// shared with the worker threads, closing the recycle loop
    /// in-process).
    buf_pool: Arc<BufPool>,
    /// Whether the shutdown handshake already ran.
    shut_down: bool,
    /// Summed worker-side pool counters from Bye frames.
    bye_fresh: u64,
    bye_reused: u64,
    /// Per-worker `(sent, recv)` bytes over worker↔worker ring links,
    /// reported in Bye frames (the aggregator never sees that traffic
    /// on its own sockets).
    bye_ring: Vec<(u64, u64)>,
    /// Ring links must be (re)negotiated before the next exchange —
    /// set at start and on every membership change.
    ring_dirty: bool,
    /// Monotone batch step stamped into Compute frames; stale or
    /// duplicate gradient uplinks are dropped by comparing against it.
    step: u64,
    /// Global batch index (stamps membership events).
    cur_batch: usize,
    /// Control-plane counters for the report.
    evictions: usize,
    joins: usize,
    reconnects: usize,
    frames_corrupt: usize,
    resends: usize,
    /// Prior aggregator generations (progress record + 1 on resume).
    aggregator_restarts: usize,
    reassigned_micros: usize,
    knapsack_resolves: usize,
    checkpoints_written: usize,
    /// The run-identity fingerprint stamped into every Init: stable
    /// across aggregator restarts of the same config, so a surviving
    /// worker's redial Join (which echoes it) reads as a reconnect.
    incarnation: u64,
    membership: Vec<MembershipEvent>,
    /// Set on evict/join; the next scheduled batch counts a
    /// membership-triggered knapsack re-solve and resets the EMAs.
    membership_dirty: bool,
    /// Worker trace batches shipped over `TAG_TRACE` frames (reader
    /// threads push as they arrive; [`DistTrainer::write_trace_artifact`]
    /// drains at the end of the run).
    trace_sink: Arc<Mutex<Vec<proto::TraceMsg>>>,
}

/// The scripted fault plan for worker `w` (empty when none).
fn plan_for(faults: &[(usize, FaultPlan)], w: usize) -> FaultPlan {
    faults.iter().find(|(i, _)| *i == w).map(|(_, p)| p.clone()).unwrap_or_default()
}

/// The reader's silent-link deadline. With heartbeats disabled there
/// is no liveness signal to miss, so the deadline is effectively off
/// (a day) and only real link errors surface losses.
fn reader_liveness(heartbeat_ms: u64, misses: u32) -> Duration {
    if heartbeat_ms == 0 {
        Duration::from_secs(24 * 3600)
    } else {
        liveness_window(heartbeat_ms, misses)
    }
}

/// Contiguous ascending micro-batch blocks for a ring exchange: entry
/// `p` is chain position `p`'s `[start, end)` range over `n` micros.
/// The first `n % k` positions take one extra micro; blocks may be
/// empty when `n < k` (the worker still relays the chain sum).
/// Contiguity in chain order is what keeps the fold bitwise equal to
/// the serial ascending reduction.
fn ring_blocks(k: usize, n: usize) -> Vec<(usize, usize)> {
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for p in 0..k {
        let len = base + usize::from(p < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Contiguous chain-position groups for the hierarchical topology:
/// `[start, end)` ranges over the live list. `group = 0` picks ⌈√k⌉,
/// the per-node-traffic optimum for a two-level scheme.
fn ring_groups(k: usize, group: usize) -> Vec<(usize, usize)> {
    let g = if group == 0 {
        let mut r = 1;
        while r * r < k {
            r += 1;
        }
        r
    } else {
        group.min(k)
    };
    let mut out = Vec::new();
    let mut start = 0;
    while start < k {
        out.push((start, (start + g).min(k)));
        start += g;
    }
    out
}

/// What one bounded wait on the arrival queue produced for the ring
/// orchestrator.
enum RingCtrl {
    /// A ring control frame (Addr / Ready / Final) from `worker`.
    Frame(usize, Vec<u8>),
    /// A worker that was live a moment ago is gone (already evicted
    /// here; at least one survivor remains).
    LostLive,
    /// The local wait window passed without a frame.
    TimedOut,
}

impl DistTrainer {
    /// Build the cluster: an aggregator replica plus `cfg.workers`
    /// worker replicas — threads over channels, threads over loopback
    /// TCP, forked subprocesses, or externally launched processes,
    /// per `cfg.transport` — all deterministically initialized from
    /// the same `(spec, lora_rank, seed)` so they are bitwise
    /// identical.
    pub fn new(provider: &NativeProvider, cfg: DistConfig) -> Result<DistTrainer> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker replica");
        anyhow::ensure!(
            cfg.wire_precision == WirePrecision::F32
                || cfg.exchange != ExchangeMode::ParamServer,
            "f16 wire precision supports gradient exchanges only (the \
             parameter-server update is applied server-side before \
             encoding, so its deltas cannot be requantized consistently)"
        );
        anyhow::ensure!(
            cfg.compress == WireCompression::None || cfg.exchange != ExchangeMode::ParamServer,
            "wire compression applies to gradient exchanges only, not \
             the parameter-server delta broadcast (deltas are applied \
             server-side before encoding)"
        );
        anyhow::ensure!(
            cfg.wire_precision == WirePrecision::F32
                || matches!(cfg.compress, WireCompression::None | WireCompression::TopK { .. }),
            "int8/int4 quantization replaces the value encoding and \
             cannot stack on the f16 wire (top-k composes — its kept \
             values ride at the wire precision)"
        );
        let mut cfg = cfg;
        cfg.train.update = UpdateMode::BatchAccum;
        let spec = provider.spec();
        if cfg.train.lora_rank > 0 {
            anyhow::ensure!(
                spec.lora_ranks.contains(&cfg.train.lora_rank),
                "native spec advertises LoRA ranks {:?}, not {}",
                spec.lora_ranks,
                cfg.train.lora_rank
            );
        }
        let mb = spec.micro_batch;
        let agg = NativeBackend::new(spec, cfg.train.lora_rank, mb, cfg.train.seed);
        // Shared with the serial trainer so the two drivers cannot
        // drift on partition/dataset setup.
        let setup = prepare_run(agg.config(), &cfg.train)?;
        let codec =
            GradCodec::new(&agg).with_precision(cfg.wire_precision).with_compression(cfg.compress);
        let buf_pool = Arc::new(BufPool::new());
        let k = cfg.workers;

        // Arm the trace recorder before any worker thread spawns so
        // channel-mode workers (which share this process's recorder)
        // never miss their earliest events. Lane 0 is the aggregator.
        if cfg.trace_out.is_some() {
            trace::set_enabled(true);
        }
        trace::set_lane(0);

        // Fingerprint of the run identity: any aggregator process
        // running this config computes the same token (never 0 — that
        // is the fresh-Join sentinel), so a worker that outlives one
        // aggregator presents a Join the next generation recognizes as
        // a reconnect rather than a fresh dial.
        let incarnation = {
            let id = format!(
                "d2ft:{}:{}:{}:{}:{}",
                cfg.train.seed,
                cfg.workers,
                cfg.train.batches,
                cfg.train.lora_rank,
                cfg.exchange.label()
            );
            fnv64(id.as_bytes()).max(1)
        };

        // --- launch the workers and connect one link per worker -------
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(k);
        let mut link_stats = Vec::with_capacity(k);
        let mut worker_threads = Vec::new();
        let mut worker_procs = Vec::new();
        let mut held_listener = None;
        match cfg.transport.clone() {
            TransportKind::Channel => {
                for w in 0..k {
                    let (agg_end, worker_end) = channel_pair();
                    // One process-wide pool: worker encode buffers come
                    // back via the aggregator's give-backs and vice
                    // versa, so the recycle loop closes in-process.
                    let pool = Arc::clone(&buf_pool);
                    let plan = plan_for(&cfg.faults, w);
                    // Network fault verbs act at the transport layer:
                    // wrap the worker's end in the scripted flaky shim.
                    let flaky = FlakyState::from_plan(&plan);
                    let handle = thread::Builder::new()
                        .name(format!("d2ft-dist-{w}"))
                        .spawn(move || {
                            let link: Box<dyn Transport> = match flaky {
                                Some(state) => {
                                    Box::new(FlakyTransport::wrap(Box::new(worker_end), state))
                                }
                                None => Box::new(worker_end),
                            };
                            if let Err(e) = run_worker_with_faults(link, pool, plan) {
                                crate::warn_!("dist worker {w} exited with error: {e:#}");
                            }
                        })
                        .context("spawning dist worker thread")?;
                    worker_threads.push(handle);
                    link_stats.push(agg_end.stats_cell());
                    transports.push(Box::new(agg_end));
                }
            }
            TransportKind::Tcp { listen: addr, spawn } => {
                let (listener, local) = listen(&addr)?;
                match spawn {
                    SpawnMode::Threads => {
                        for w in 0..k {
                            let dial = local.to_string();
                            let plan = plan_for(&cfg.faults, w);
                            let handle = thread::Builder::new()
                                .name(format!("d2ft-dist-{w}"))
                                .spawn(move || {
                                    // Worker-local pool, exactly like a
                                    // separate process would have. The
                                    // reconnecting loop makes a link drop
                                    // a redial (backoff + jitter), not a
                                    // death — the aggregator's held
                                    // listener re-accepts it.
                                    let pool = Arc::new(BufPool::new());
                                    let res = run_worker_reconnecting(
                                        &dial,
                                        pool,
                                        plan,
                                        Duration::from_secs(60),
                                    );
                                    if let Err(e) = res {
                                        crate::warn_!("dist worker {w} exited with error: {e:#}");
                                    }
                                })
                                .context("spawning tcp dist worker thread")?;
                            worker_threads.push(handle);
                        }
                    }
                    SpawnMode::Processes => {
                        let exe = std::env::current_exe()
                            .context("resolving current executable for dist-worker spawn")?;
                        for w in 0..k {
                            // Note: with subprocess spawn, link slots are
                            // assigned in *accept* order, so a scripted
                            // plan travels with the process, not the slot.
                            let plan = plan_for(&cfg.faults, w);
                            let mut cmd = Command::new(&exe);
                            cmd.arg("dist-worker")
                                .arg("--connect")
                                .arg(local.to_string())
                                .arg("--quiet");
                            if !plan.is_empty() {
                                cmd.arg("--fault").arg(plan.to_string());
                            }
                            let child = cmd
                                .spawn()
                                .context("forking `repro dist-worker` subprocess")?;
                            worker_procs.push(child);
                        }
                    }
                    SpawnMode::External => {
                        crate::info!(
                            "waiting for {k} external workers: repro dist-worker --connect {local}"
                        );
                    }
                }
                for stream in accept_workers(&listener, k, Duration::from_secs(120))? {
                    let t = TcpTransport::from_stream(stream, Arc::clone(&buf_pool))?;
                    link_stats.push(t.stats_cell());
                    transports.push(Box::new(t));
                }
                held_listener = Some((listener, local));
            }
        }

        // --- handshake: Join in, version-check, Init out, barrier -----
        // (Per-link Join→Init first, barriers after, so the K replica
        // builds still run concurrently.)
        let mut reconnects = 0usize;
        for (w, link) in transports.iter_mut().enumerate() {
            let join = link
                .recv_blob_timeout(Duration::from_secs(60))
                .with_context(|| format!("waiting for Join from worker {w}"))?
                .ok_or_else(|| {
                    anyhow::anyhow!("worker {w} sent no Join within the 60s handshake deadline")
                })?;
            let jm =
                proto::decode_join(&join).with_context(|| format!("handshaking worker {w}"))?;
            buf_pool.give_back(join);
            anyhow::ensure!(
                jm.version == proto::PROTO_VERSION,
                "worker {w} speaks dist protocol version {}, this aggregator speaks {}",
                jm.version,
                proto::PROTO_VERSION
            );
            // A Join that already carries an identity is a surviving
            // worker's redial landing on a restarted aggregator — the
            // crash-recovery path, not a fresh dial.
            if jm.incarnation != 0 || jm.worker != u32::MAX {
                reconnects += 1;
                crate::info!(
                    "worker slot {w}: a surviving worker reconnected \
                     (incarnation {:#x}, previously worker {}, last step {})",
                    jm.incarnation,
                    jm.worker,
                    jm.last_step
                );
            }
            let msg = InitMsg {
                worker: w,
                spec: spec.clone(),
                lora_rank: cfg.train.lora_rank,
                seed: cfg.train.seed,
                precision: cfg.wire_precision,
                compress: cfg.compress,
                ring: cfg.exchange.is_ring(),
                overlap: cfg.overlap,
                sim_wire_ms_per_mib: cfg.sim_wire_ms_per_mib,
                heartbeat_ms: cfg.heartbeat_ms,
                trace: cfg.trace_out.is_some(),
                clock_anchor_us: trace::now_us(),
                incarnation,
            };
            let mut frame = buf_pool.checkout();
            proto::encode_init(&msg, &mut frame);
            link.send_blob(frame).with_context(|| format!("sending Init to worker {w}"))?;
        }
        for (w, link) in transports.iter_mut().enumerate() {
            link.barrier().with_context(|| format!("handshake barrier with worker {w}"))?;
        }

        // --- split the links; reader threads fan uplinks in -----------
        let liveness = reader_liveness(cfg.heartbeat_ms, cfg.liveness_misses);
        let (arr_tx, arrivals) = mpsc::channel::<Arrival>();
        let trace_sink: Arc<Mutex<Vec<proto::TraceMsg>>> = Arc::new(Mutex::new(Vec::new()));
        let mut links = Vec::with_capacity(k);
        let mut readers = Vec::with_capacity(k);
        for (w, link) in transports.into_iter().enumerate() {
            let (tx, rx) = link.split();
            links.push(Some(tx));
            let fan_in = arr_tx.clone();
            let pool = Arc::clone(&buf_pool);
            let traces = Arc::clone(&trace_sink);
            let handle = thread::Builder::new()
                .name(format!("d2ft-dist-{w}-rx"))
                .spawn(move || reader_loop(w, rx, fan_in, liveness, pool, traces))
                .context("spawning dist reader thread")?;
            readers.push(handle);
        }

        let ema_ms = vec![1.0; k];
        Ok(DistTrainer {
            cfg,
            agg,
            codec,
            partition: setup.partition,
            train: setup.train,
            test: setup.test,
            spec: spec.clone(),
            links,
            arrivals,
            arr_tx,
            listener: held_listener,
            readers,
            worker_threads,
            worker_procs,
            link_stats,
            ema_ms,
            buf_pool,
            shut_down: false,
            bye_fresh: 0,
            bye_reused: 0,
            bye_ring: vec![(0, 0); k],
            ring_dirty: true,
            step: 0,
            cur_batch: 0,
            evictions: 0,
            joins: 0,
            reconnects,
            frames_corrupt: 0,
            resends: 0,
            aggregator_restarts: 0,
            reassigned_micros: 0,
            knapsack_resolves: 0,
            checkpoints_written: 0,
            incarnation,
            membership: Vec::new(),
            membership_dirty: false,
            trace_sink,
        })
    }

    /// The aggregator's replica (authoritative parameters).
    pub fn backend(&self) -> &NativeBackend {
        &self.agg
    }

    /// The model partition this run schedules over.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The gradient codec (wire-layout queries, e.g. dense size).
    pub fn codec(&self) -> &GradCodec {
        &self.codec
    }

    /// Assign each of `n_micro` micro-batches to a *live* worker:
    /// greedy least-finish-time over the measured per-task EMAs, so a
    /// slow worker (real straggler) receives fewer tasks next batch.
    /// Purely a placement decision — replicas are bitwise identical, so
    /// any assignment yields identical numerics.
    fn assign(&self, n_micro: usize) -> Vec<usize> {
        let live: Vec<usize> =
            (0..self.links.len()).filter(|&w| self.links[w].is_some()).collect();
        debug_assert!(!live.is_empty(), "assign() requires at least one live worker");
        let mut load = vec![0.0f64; live.len()];
        let mut out = Vec::with_capacity(n_micro);
        for _ in 0..n_micro {
            let mut best = 0;
            for (i, &w) in live.iter().enumerate().skip(1) {
                if load[i] + self.ema_ms[w] < load[best] + self.ema_ms[live[best]] {
                    best = i;
                }
            }
            load[best] += self.ema_ms[live[best]];
            out.push(live[best]);
        }
        out
    }

    /// Live (non-evicted) worker count.
    fn live_workers(&self) -> usize {
        self.links.iter().filter(|l| l.is_some()).count()
    }

    /// A live worker to (re)run a micro-batch, preferring anyone other
    /// than `not` (the suspect owner) and, among candidates, the one
    /// with the fastest measured EMA.
    fn pick_live(&self, not: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for w in 0..self.links.len() {
            if self.links[w].is_none() {
                continue;
            }
            best = Some(match best {
                None => w,
                Some(b) => {
                    let b_suspect = b == not;
                    let w_suspect = w == not;
                    if (b_suspect && !w_suspect)
                        || (b_suspect == w_suspect && self.ema_ms[w] < self.ema_ms[b])
                    {
                        w
                    } else {
                        b
                    }
                }
            });
        }
        best
    }

    /// Remove `worker` from the live set: best-effort Evict notice,
    /// drop the downlink, record the membership event, and mark the
    /// schedule dirty so the next batch re-solves with fresh EMAs.
    /// Idempotent — a late `Lost` for an already-evicted worker is a
    /// no-op.
    fn evict(&mut self, worker: usize, why: &str) {
        if self.links[worker].is_none() {
            return;
        }
        if let Some(link) = self.links[worker].as_mut() {
            let mut frame = self.buf_pool.checkout();
            proto::encode_evict(worker, &mut frame);
            let _ = link.send_blob(frame);
        }
        self.links[worker] = None;
        self.evictions += 1;
        self.membership.push(MembershipEvent {
            batch: self.cur_batch,
            worker,
            kind: "evict".to_string(),
        });
        self.membership_dirty = true;
        self.ring_dirty = true;
        trace::instant("ctrl", "evict");
        crate::warn_!("dist worker {worker} evicted: {why}");
    }

    /// Broadcast one frame to every worker, checking a pooled copy out
    /// per link (the transport consumes its buffer). Records `payload`
    /// bytes per link into `stats` as downlink traffic.
    ///
    /// The K copies are a deliberate trade for the uniform seam: the
    /// pre-transport code shared one `Arc<Vec<u8>>` across in-process
    /// workers, but any real multi-process transport must materialize
    /// per-link bytes anyway, and one memcpy per worker per batch is
    /// noise next to a batch's gradient compute. Buffers come from the
    /// pool, so the copies add no steady-state allocations.
    /// A failed send evicts that worker instead of failing the batch —
    /// the survivors already have everything they need.
    fn broadcast(&mut self, master: &[u8], payload: usize, stats: &mut WireStats) -> Result<()> {
        let _sp = trace::span("net", "broadcast");
        let mut dead: Vec<(usize, String)> = Vec::new();
        for (w, slot) in self.links.iter_mut().enumerate() {
            let Some(link) = slot else { continue };
            stats.record_down(payload);
            let mut frame = self.buf_pool.checkout();
            frame.extend_from_slice(master);
            if let Err(e) = link.send_blob(frame) {
                dead.push((w, format!("broadcast send failed: {e:#}")));
            }
        }
        for (w, why) in dead {
            self.evict(w, &why);
        }
        anyhow::ensure!(
            self.live_workers() > 0,
            "every dist worker link is gone (all broadcasts failed)"
        );
        Ok(())
    }

    /// Re-encode every unfilled micro-batch of `step` to a live worker.
    /// With `lost = Some(w)` only `w`'s micros move (its link just
    /// died); with `None` (a stall) every unfilled micro is duplicated
    /// onto a preferably-different worker. Recomputed gradients are
    /// bitwise identical on any replica, so duplication cannot change
    /// the numerics — the reducer keeps whichever copy lands first.
    fn redispatch_unfilled(
        &mut self,
        reducer: &OrderedReducer,
        all_jobs: &[MicroJob],
        step: u64,
        owner: &mut [usize],
        lost: Option<usize>,
    ) -> Result<()> {
        for (i, job) in all_jobs.iter().enumerate() {
            if reducer.filled(i) {
                continue;
            }
            if let Some(w) = lost {
                if owner[i] != w {
                    continue;
                }
            }
            let prev = owner[i];
            loop {
                let w = self.pick_live(prev).ok_or_else(|| {
                    anyhow::anyhow!("no live dist workers left to reassign micro-batch {i}")
                })?;
                let mut frame = self.buf_pool.checkout();
                proto::encode_compute(step, std::slice::from_ref(job), &mut frame);
                let sent = self.links[w].as_mut().unwrap().send_blob(frame);
                match sent {
                    Ok(()) => {
                        owner[i] = w;
                        self.reassigned_micros += 1;
                        break;
                    }
                    Err(e) => self.evict(w, &format!("reassignment dispatch failed: {e:#}")),
                }
            }
        }
        Ok(())
    }

    /// Execute one batch: dispatch compute jobs, run the ordered-reduce
    /// barrier, apply the update on the aggregator, broadcast it to the
    /// workers, and account the bytes.
    fn exec_batch(
        &mut self,
        micros: &[(Tensor, Vec<i32>)],
        masks: &[MaskPair],
        stats: &mut WireStats,
    ) -> Result<BatchOut> {
        if self.cfg.exchange.is_ring() {
            return self.exec_batch_ring(micros, masks, stats);
        }
        let n = micros.len();
        assert_eq!(masks.len(), n, "one mask pair per micro-batch");
        let k = self.links.len();
        anyhow::ensure!(self.live_workers() > 0, "no live dist workers left to run a batch");
        let _sp = trace::span("step", "exec_batch");
        self.step += 1;
        let step = self.step;
        // Every job is retained (and shipped one per frame) so a lost
        // worker's share can be re-encoded for a survivor mid-barrier.
        let all_jobs: Vec<MicroJob> = micros
            .iter()
            .enumerate()
            .map(|(i, (x, y))| MicroJob {
                micro: i,
                x: x.clone(),
                y: y.clone(),
                masks: masks[i].clone(),
            })
            .collect();
        let mut owner = self.assign(n);
        let mut tasks_per_worker = vec![0usize; k];
        {
            let _sp = trace::span("agg", "dispatch");
            for i in 0..n {
                loop {
                    let w = owner[i];
                    if self.links[w].is_none() {
                        owner[i] = self.pick_live(w).ok_or_else(|| {
                            anyhow::anyhow!("no live dist workers left to dispatch micro-batch {i}")
                        })?;
                        continue;
                    }
                    let mut frame = self.buf_pool.checkout();
                    proto::encode_compute(step, std::slice::from_ref(&all_jobs[i]), &mut frame);
                    let sent = self.links[w].as_mut().unwrap().send_blob(frame);
                    match sent {
                        Ok(()) => {
                            tasks_per_worker[w] += 1;
                            break;
                        }
                        Err(e) => self.evict(w, &format!("compute dispatch failed: {e:#}")),
                    }
                }
            }
        }
        // Barrier: one gradient message per micro-batch. A lost worker
        // is evicted and its unfilled micros re-run on survivors; a
        // stalled link gets its micros duplicated after
        // `stall_reassign_ms`; the batch deadline turns any leftover
        // silence into a descriptive error — never a hang.
        let mut reducer = OrderedReducer::new(n);
        let mut outs = vec![(0.0f32, 0.0f32); n];
        let mut worker_ms = vec![0.0f64; k];
        let mut micro_ms = vec![0.0f64; n];
        let dense = self.codec.dense_len();
        let deadline = Instant::now() + Duration::from_millis(self.cfg.batch_timeout_ms.max(1));
        let stall = Duration::from_millis(self.cfg.stall_reassign_ms.max(1));
        let barrier_sp = trace::span("agg", "barrier");
        while !reducer.is_complete() {
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "batch deadline ({} ms) passed with incomplete gradients — aborting",
                self.cfg.batch_timeout_ms
            );
            match self.arrivals.recv_timeout(stall.min(deadline - now)) {
                Ok(Arrival::Up { worker, hdr, frame }) => {
                    if hdr.step != step || reducer.filled(hdr.micro) {
                        // Stale (previous batch) or duplicate (a
                        // reassigned micro finishing twice). Duplicates
                        // carry bitwise identical payloads, so dropping
                        // either copy is sound.
                        self.buf_pool.give_back(frame);
                        continue;
                    }
                    worker_ms[worker] += hdr.ms;
                    stats.record_up(frame.len() - proto::UP_GRAD_OFF, dense);
                    reducer.push(hdr.micro, frame, proto::UP_GRAD_OFF)?;
                    outs[hdr.micro] = (hdr.loss, hdr.n_correct);
                    micro_ms[hdr.micro] = hdr.ms;
                }
                Ok(Arrival::Lost { worker, error }) => {
                    let was_live = self.links[worker].is_some();
                    if was_live && self.try_reconnect(worker, &error) {
                        // The returning session lost whatever was in
                        // flight on the old link; its share of the
                        // barrier re-dispatches (possibly right back to
                        // it — bitwise identical either way).
                        self.redispatch_unfilled(
                            &reducer,
                            &all_jobs,
                            step,
                            &mut owner,
                            Some(worker),
                        )?;
                        continue;
                    }
                    self.evict(worker, &error);
                    if self.live_workers() == 0 {
                        anyhow::bail!(
                            "dist worker {worker} lost mid-batch with no survivors: {error}"
                        );
                    }
                    if was_live {
                        self.redispatch_unfilled(
                            &reducer,
                            &all_jobs,
                            step,
                            &mut owner,
                            Some(worker),
                        )?;
                    }
                }
                Ok(Arrival::Corrupt { worker }) => {
                    // A damaged frame (CRC trailer mismatch). The link
                    // is alive and framed — ask the worker to resend
                    // its retained gradient; the step stamp makes any
                    // duplicate idempotent, and the stall-reassign path
                    // backstops a resend that cannot fill the hole.
                    self.frames_corrupt += 1;
                    trace::instant("ctrl", "nack");
                    let mut nack_err: Option<String> = None;
                    if let Some(link) = self.links[worker].as_mut() {
                        let mut frame = self.buf_pool.checkout();
                        proto::encode_nack(step, &mut frame);
                        match link.send_blob(frame) {
                            Ok(()) => self.resends += 1,
                            Err(e) => nack_err = Some(format!("NACK send failed: {e:#}")),
                        }
                    }
                    if let Some(why) = nack_err {
                        self.evict(worker, &why);
                        if self.live_workers() == 0 {
                            anyhow::bail!(
                                "dist worker {worker} lost mid-batch with no survivors: {why}"
                            );
                        }
                        self.redispatch_unfilled(
                            &reducer,
                            &all_jobs,
                            step,
                            &mut owner,
                            Some(worker),
                        )?;
                    }
                }
                Ok(Arrival::Ring { frame, .. }) => {
                    // A straggling ring frame from a previous mode or
                    // attempt — nothing waits on it here.
                    self.buf_pool.give_back(frame);
                }
                Ok(Arrival::Bye { worker, .. }) => {
                    anyhow::bail!("dist worker {worker} sent an unexpected Bye mid-batch")
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // Quiet past the stall window: duplicate every
                    // unfilled micro onto (preferably) another live
                    // worker. The slow copy, if it ever lands, is
                    // dropped above.
                    self.redispatch_unfilled(&reducer, &all_jobs, step, &mut owner, None)?;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("every dist worker link closed mid-batch")
                }
            }
        }
        drop(barrier_sp);
        // Straggler feedback: EMA of measured ms per task. Only workers
        // that actually delivered gradients update — a silent worker
        // (stalled, dying) measured 0 ms, which would read as *fast*.
        for w in 0..k {
            if tasks_per_worker[w] > 0 && worker_ms[w] > 0.0 {
                let per_task = worker_ms[w] / tasks_per_worker[w] as f64;
                self.ema_ms[w] = 0.8 * self.ema_ms[w] + 0.2 * per_task;
            }
        }
        // Fixed-order reduction -> batch-mean gradient.
        let mut acc = self.agg.zeros_like_params();
        reducer.reduce(&self.codec, masks, &mut acc)?;
        // Recycle the message buffers: with the workers' checkout this
        // closes the loop that makes the steady-state encode path
        // allocation-free.
        for blob in reducer.into_blobs() {
            self.buf_pool.give_back(blob);
        }
        let _apply_sp = trace::span("agg", "apply");
        let lr = self.cfg.train.lr;
        match self.cfg.exchange {
            ExchangeMode::MaskedAllReduce => {
                let union = MaskPair::union(masks);
                let mut gbuf = self.buf_pool.checkout();
                self.codec.encode_into(0, &union, &acc, &mut gbuf);
                if self.codec.precision() == WirePrecision::F32
                    && self.codec.compression() == WireCompression::None
                {
                    self.agg.apply_grads(&acc, lr)?;
                } else {
                    // Lossy wire: every replica must apply the exact
                    // bits that crossed it, the aggregator included —
                    // decode our own broadcast so all K+1 replicas stay
                    // mutually bitwise identical.
                    let mut quantized = self.agg.zeros_like_params();
                    self.codec.decode_add(&gbuf, &union, &mut quantized)?;
                    self.agg.apply_grads(&quantized, lr)?;
                }
                let mut master = self.buf_pool.checkout();
                let grad_off = proto::encode_apply(lr, &union, &gbuf, &mut master);
                let payload = master.len() - grad_off;
                self.buf_pool.give_back(gbuf);
                self.broadcast(&master, payload, stats)?;
                self.buf_pool.give_back(master);
            }
            ExchangeMode::ParamServer => {
                let deltas = self.agg.update_capture(&acc, lr);
                let mut master = self.buf_pool.checkout();
                let off = proto::encode_deltas_header(&mut master);
                self.codec.encode_dense_append(&deltas, &mut master);
                let payload = master.len() - off;
                self.broadcast(&master, payload, stats)?;
                self.buf_pool.give_back(master);
            }
            ExchangeMode::Ring | ExchangeMode::Hierarchical => {
                unreachable!("ring modes are dispatched to exec_batch_ring above")
            }
        }
        Ok(BatchOut { outs, worker_ms, micro_ms })
    }

    /// One bounded wait on the arrival queue while running a ring
    /// barrier. Gradient uplinks are stale here (recycled), losses
    /// evict inline, and the batch `deadline` turns silence into a
    /// descriptive error — a ring barrier can never hang the trainer.
    fn ring_ctrl_recv(&mut self, until: Instant, deadline: Instant) -> Result<RingCtrl> {
        loop {
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "batch deadline ({} ms) passed mid-ring-exchange — aborting",
                self.cfg.batch_timeout_ms
            );
            if now >= until {
                return Ok(RingCtrl::TimedOut);
            }
            let wait = (until - now).min(Duration::from_millis(100)).min(deadline - now);
            match self.arrivals.recv_timeout(wait) {
                Ok(Arrival::Ring { worker, frame }) => return Ok(RingCtrl::Frame(worker, frame)),
                Ok(Arrival::Up { frame, .. }) => self.buf_pool.give_back(frame),
                Ok(Arrival::Corrupt { .. }) => {
                    // Counted only: the ring exchange re-delivers its
                    // own frames (Reset + restart), so no NACK here.
                    self.frames_corrupt += 1;
                }
                Ok(Arrival::Lost { worker, error }) => {
                    let was_live = self.links[worker].is_some();
                    self.evict(worker, &error);
                    anyhow::ensure!(
                        self.live_workers() > 0,
                        "dist worker {worker} lost mid-ring-exchange with no survivors: {error}"
                    );
                    if was_live {
                        return Ok(RingCtrl::LostLive);
                    }
                }
                Ok(Arrival::Bye { worker, .. }) => {
                    anyhow::bail!("dist worker {worker} sent an unexpected Bye mid-ring-exchange")
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("every dist worker link closed mid-ring-exchange")
                }
            }
        }
    }

    /// Best-effort control send on `w`'s downlink; a failure evicts the
    /// worker. Returns whether the frame reached the transport.
    fn ring_send(&mut self, w: usize, frame: Vec<u8>, what: &str) -> bool {
        let Some(link) = self.links[w].as_mut() else {
            self.buf_pool.give_back(frame);
            return false;
        };
        match link.send_blob(frame) {
            Ok(()) => true,
            Err(e) => {
                self.evict(w, &format!("{what} send failed: {e:#}"));
                false
            }
        }
    }

    /// Abort the in-flight exchange attempt: Reset(`step`) to every
    /// live worker (anyone blocked inside the exchange falls back to
    /// its main loop) and force a link renegotiation before the next
    /// attempt.
    fn ring_reset_live(&mut self, step: u64) -> Result<()> {
        self.ring_dirty = true;
        for w in 0..self.links.len() {
            if self.links[w].is_none() {
                continue;
            }
            let mut frame = self.buf_pool.checkout();
            proto::encode_ring_reset(step, &mut frame);
            self.ring_send(w, frame, "ring reset");
        }
        anyhow::ensure!(
            self.live_workers() > 0,
            "every dist worker link is gone (all ring resets failed)"
        );
        Ok(())
    }

    /// (Re)build the worker↔worker ring links over `live` (chain
    /// order). Each worker opens a listener (Addr), learns its
    /// successor (Peers), dials/accepts, and confirms (Ready). Every
    /// frame echoes this round's nonce, so stragglers from an aborted
    /// round can never satisfy this one. Returns `false` when
    /// membership changed mid-round — the caller restarts the attempt
    /// over the new live set.
    fn ring_negotiate(&mut self, live: &[usize], deadline: Instant) -> Result<bool> {
        let _sp = trace::span("ring", "negotiate");
        self.step += 1;
        let nonce = self.step;
        let tcp = !matches!(self.cfg.transport, TransportKind::Channel);
        for &w in live {
            let mut frame = self.buf_pool.checkout();
            proto::encode_ring_listen(tcp, nonce, &mut frame);
            if !self.ring_send(w, frame, "ring listen") {
                return Ok(false);
            }
        }
        let mut addrs: Vec<Option<String>> = vec![None; self.links.len()];
        let mut pending = live.len();
        while pending > 0 {
            match self.ring_ctrl_recv(deadline, deadline)? {
                RingCtrl::Frame(w, frame) => {
                    let parsed = proto::decode_ring_addr(&frame);
                    self.buf_pool.give_back(frame);
                    // Anything that is not this round's Addr (a stale
                    // Ready, a Final from an aborted exchange) is noise.
                    if let Ok((n, addr)) = parsed {
                        if n == nonce && addrs[w].is_none() {
                            addrs[w] = Some(addr);
                            pending -= 1;
                        }
                    }
                }
                RingCtrl::LostLive => return Ok(false),
                RingCtrl::TimedOut => {}
            }
        }
        let m = live.len();
        let hier = self.cfg.exchange == ExchangeMode::Hierarchical;
        for (p, &w) in live.iter().enumerate() {
            let (succ, accept) = if m == 1 {
                (String::new(), false)
            } else if hier {
                // Reduce runs the full chain; the tail has no wrap
                // link (the aggregator gates the distribute leg) and
                // the head accepts no dial-in.
                let succ = if p + 1 < m {
                    addrs[live[p + 1]].clone().unwrap_or_default()
                } else {
                    String::new()
                };
                (succ, p > 0)
            } else {
                // Plain ring: the wrap link (tail -> head) carries the
                // distribute cast, so everyone dials and accepts.
                (addrs[live[(p + 1) % m]].clone().unwrap_or_default(), true)
            };
            let mut frame = self.buf_pool.checkout();
            proto::encode_ring_peers(nonce, &succ, accept, &mut frame);
            if !self.ring_send(w, frame, "ring peers") {
                return Ok(false);
            }
        }
        let mut ready = vec![false; self.links.len()];
        let mut pending = m;
        while pending > 0 {
            match self.ring_ctrl_recv(deadline, deadline)? {
                RingCtrl::Frame(w, frame) => {
                    let seq = proto::decode_ring_ready(&frame);
                    self.buf_pool.give_back(frame);
                    if matches!(seq, Ok(s) if s == nonce) && !ready[w] {
                        ready[w] = true;
                        pending -= 1;
                    }
                }
                RingCtrl::LostLive => return Ok(false),
                RingCtrl::TimedOut => {}
            }
        }
        self.ring_dirty = false;
        Ok(true)
    }

    /// Execute one batch in ring mode: dispatch each live worker its
    /// whole contiguous micro block, collect the per-micro metrics,
    /// (re)negotiate the worker↔worker links if membership changed,
    /// then run the chain reduce and distribute the result — the plain
    /// ring casts from the chain tail around the wrap link; the
    /// hierarchical variant routes the final sum through the
    /// aggregator to each group leader, which casts intra-group.
    ///
    /// Any membership change before the tail produces its Final aborts
    /// the attempt with a Reset and restarts it over the survivors —
    /// sound because no replica applies anything until the distribute
    /// leg begins. After that point the applied bytes are pinned:
    /// recovery re-delivers exactly them (idempotently, keyed by step)
    /// instead of recomputing.
    fn exec_batch_ring(
        &mut self,
        micros: &[(Tensor, Vec<i32>)],
        masks: &[MaskPair],
        stats: &mut WireStats,
    ) -> Result<BatchOut> {
        let n = micros.len();
        assert_eq!(masks.len(), n, "one mask pair per micro-batch");
        let _sp = trace::span("step", "exec_batch_ring");
        let k = self.links.len();
        let union = MaskPair::union(masks);
        let lr = self.cfg.train.lr;
        let dense = self.codec.dense_len();
        let deadline = Instant::now() + Duration::from_millis(self.cfg.batch_timeout_ms.max(1));
        let stall = Duration::from_millis(self.cfg.stall_reassign_ms.max(1));
        let grace = stall;
        let hier = self.cfg.exchange == ExchangeMode::Hierarchical;
        'attempt: loop {
            anyhow::ensure!(
                Instant::now() < deadline,
                "batch deadline ({} ms) passed with the ring exchange incomplete — aborting",
                self.cfg.batch_timeout_ms
            );
            anyhow::ensure!(self.live_workers() > 0, "no live dist workers left to run a batch");
            let mut outs = vec![(0.0f32, 0.0f32); n];
            let mut worker_ms = vec![0.0f64; k];
            let mut micro_ms = vec![0.0f64; n];
            self.step += 1;
            let step = self.step;
            let live: Vec<usize> = (0..k).filter(|&w| self.links[w].is_some()).collect();
            let m = live.len();
            let tail = live[m - 1];
            let blocks = ring_blocks(m, n);
            // One Compute frame per worker carrying its whole block
            // (possibly empty — the worker still relays the chain). A
            // worker *replaces* its held gradients per frame, so a
            // restarted attempt with re-balanced blocks
            // self-corrects.
            let mut owner = vec![usize::MAX; n];
            for (&w, &(s, e)) in live.iter().zip(&blocks) {
                owner[s..e].fill(w);
                let jobs: Vec<MicroJob> = (s..e)
                    .map(|i| MicroJob {
                        micro: i,
                        x: micros[i].0.clone(),
                        y: micros[i].1.clone(),
                        masks: masks[i].clone(),
                    })
                    .collect();
                let mut frame = self.buf_pool.checkout();
                proto::encode_compute(step, &jobs, &mut frame);
                if !self.ring_send(w, frame, "ring compute dispatch") {
                    continue 'attempt;
                }
            }
            // Metric barrier: one metric-only Up per micro (gradients
            // stay on the workers). A loss or stall evicts and
            // restarts the attempt — blocks are contiguous chain
            // shares, so there is no per-micro reassignment here.
            let mut arrived = vec![false; n];
            let mut n_arrived = 0;
            while n_arrived < n {
                let now = Instant::now();
                anyhow::ensure!(
                    now < deadline,
                    "batch deadline ({} ms) passed with incomplete metrics — aborting",
                    self.cfg.batch_timeout_ms
                );
                match self.arrivals.recv_timeout(stall.min(deadline - now)) {
                    Ok(Arrival::Up { worker, hdr, frame }) => {
                        self.buf_pool.give_back(frame);
                        if hdr.step != step || arrived[hdr.micro] {
                            continue;
                        }
                        arrived[hdr.micro] = true;
                        n_arrived += 1;
                        worker_ms[worker] += hdr.ms;
                        outs[hdr.micro] = (hdr.loss, hdr.n_correct);
                        micro_ms[hdr.micro] = hdr.ms;
                    }
                    Ok(Arrival::Ring { frame, .. }) => self.buf_pool.give_back(frame),
                    Ok(Arrival::Corrupt { .. }) => {
                        // Metric Ups re-arrive with the attempt restart
                        // if needed; count and keep waiting.
                        self.frames_corrupt += 1;
                    }
                    Ok(Arrival::Lost { worker, error }) => {
                        let was_live = self.links[worker].is_some();
                        self.evict(worker, &error);
                        anyhow::ensure!(
                            self.live_workers() > 0,
                            "dist worker {worker} lost mid-batch with no survivors: {error}"
                        );
                        if was_live {
                            self.reassigned_micros +=
                                (0..n).filter(|&i| owner[i] == worker && !arrived[i]).count();
                            continue 'attempt;
                        }
                    }
                    Ok(Arrival::Bye { worker, .. }) => {
                        anyhow::bail!("dist worker {worker} sent an unexpected Bye mid-batch")
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // Quiet past the stall window: evict the owners
                        // of every missing micro and restart on the
                        // survivors.
                        let mut missing = 0;
                        for (&w, &(s, e)) in live.iter().zip(&blocks) {
                            let miss = (s..e).filter(|&i| !arrived[i]).count();
                            if miss > 0 && self.links[w].is_some() {
                                missing += miss;
                                self.evict(
                                    w,
                                    &format!(
                                        "silent past the {} ms stall window with {miss} \
                                         micro-batch(es) outstanding in a ring exchange",
                                        self.cfg.stall_reassign_ms
                                    ),
                                );
                            }
                        }
                        anyhow::ensure!(
                            self.live_workers() > 0,
                            "every dist worker stalled mid-ring-exchange"
                        );
                        self.reassigned_micros += missing;
                        continue 'attempt;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        anyhow::bail!("every dist worker link closed mid-batch")
                    }
                }
            }
            // Straggler feedback (same EMA as the star path): only
            // workers that delivered metrics update.
            for (&w, &(s, e)) in live.iter().zip(&blocks) {
                if e > s && worker_ms[w] > 0.0 {
                    let per_task = worker_ms[w] / (e - s) as f64;
                    self.ema_ms[w] = 0.8 * self.ema_ms[w] + 0.2 * per_task;
                }
            }
            if self.ring_dirty && !self.ring_negotiate(&live, deadline)? {
                continue 'attempt;
            }
            // Role assignment. The chain runs 0 -> m-1 in both modes;
            // the distribute leg differs (see the method docs). The
            // tail is dispatched *last*: an Exec send failure therefore
            // guarantees no Final was produced, so Reset + restart is
            // sound.
            let groups = if hier { ring_groups(m, self.cfg.ring_group) } else { vec![(0, m)] };
            let mut leaders: Vec<(usize, u32)> = Vec::new();
            let mut execs: Vec<(usize, RingExec)> = Vec::with_capacity(m);
            for (p, &w) in live.iter().enumerate() {
                let cast = if m == 1 {
                    CastRole::Origin { hops: 0 }
                } else if hier {
                    let (gs, ge) = *groups
                        .iter()
                        .find(|&&(gs, ge)| p >= gs && p < ge)
                        .expect("ring groups cover every chain position");
                    if p == gs {
                        let hops = (ge - gs - 1) as u32;
                        leaders.push((w, hops));
                        CastRole::Leader { hops }
                    } else {
                        CastRole::Member
                    }
                } else if p == m - 1 {
                    CastRole::Origin { hops: (m - 1) as u32 }
                } else {
                    CastRole::Member
                };
                let exec = RingExec {
                    step,
                    lr,
                    n_micros: n as u32,
                    has_in: p > 0,
                    is_last: p == m - 1,
                    cast,
                    union: union.clone(),
                };
                execs.push((w, exec));
            }
            for (w, exec) in execs {
                let mut frame = self.buf_pool.checkout();
                proto::encode_ring_exec(&exec, &mut frame);
                if !self.ring_send(w, frame, "ring exec dispatch") {
                    self.ring_reset_live(step)?;
                    continue 'attempt;
                }
            }
            // Wait for the chain tail's Final. Apply acks
            // (Ready(step)) can already arrive here — the plain ring's
            // cast leg overlaps the Final's trip to the aggregator.
            let mut acked = vec![false; k];
            let mut until = deadline;
            let (fin_frame, fin_off) = loop {
                match self.ring_ctrl_recv(until, deadline)? {
                    RingCtrl::Frame(w, frame) => match proto::peek_tag(&frame) {
                        Ok(proto::TAG_RING_FINAL) => {
                            if let Ok((s, off)) = proto::decode_ring_final(&frame) {
                                if s == step {
                                    break (frame, off);
                                }
                            }
                            self.buf_pool.give_back(frame);
                        }
                        Ok(proto::TAG_RING_READY) => {
                            let seq = proto::decode_ring_ready(&frame);
                            self.buf_pool.give_back(frame);
                            if matches!(seq, Ok(s) if s == step) {
                                acked[w] = true;
                            }
                        }
                        _ => self.buf_pool.give_back(frame),
                    },
                    RingCtrl::LostLive => {
                        // The chain may already have completed past the
                        // lost worker — give the Final a grace window
                        // before deciding.
                        until = Instant::now() + grace;
                    }
                    RingCtrl::TimedOut => {
                        // No Final within the grace window. If the tail
                        // is gone in plain-ring mode it may have cast
                        // the update before dying — bail rather than
                        // diverge. Otherwise nothing was applied
                        // anywhere (the tail gates the plain-ring cast;
                        // the aggregator gates the hierarchical one),
                        // so a full redo is sound.
                        anyhow::ensure!(
                            hier || self.links[tail].is_some(),
                            "ring chain tail (worker {tail}) was lost mid-exchange; the update \
                             may have been partially distributed — aborting instead of diverging"
                        );
                        self.ring_reset_live(step)?;
                        continue 'attempt;
                    }
                }
            };
            let payload = fin_frame.len() - fin_off;
            stats.record_up(payload, dense);
            // Apply on the aggregator replica: decode the *exact*
            // bytes every worker decodes (this is what keeps lossy
            // wires mutually consistent), scale by 1/n, apply.
            let mut acc = self.agg.zeros_like_params();
            self.codec.decode_add(&fin_frame[fin_off..], &union, &mut acc)?;
            let scale = 1.0 / n as f32;
            for t in acc.iter_mut() {
                t.scale(scale);
            }
            self.agg.apply_grads(&acc, lr)?;
            // Hierarchical distribute: the same final bytes to every
            // group leader, which casts them intra-group.
            if hier && m > 1 {
                for &(w, hops) in &leaders {
                    if self.links[w].is_none() {
                        continue;
                    }
                    let mut frame = self.buf_pool.checkout();
                    proto::encode_ring_castd_header(step, hops, &mut frame);
                    frame.extend_from_slice(&fin_frame[fin_off..]);
                    stats.record_down(payload);
                    self.ring_send(w, frame, "ring cast-down");
                }
            }
            // Ack barrier: every live replica confirms the applied
            // step. A broken cast chain (loss, stall) is healed by
            // re-delivering the pinned bytes directly — Reset first so
            // anyone still inside the exchange falls back to the main
            // loop (per-link FIFO orders the direct CastDown after
            // it); applies are idempotent per step.
            let mut until = Instant::now() + grace;
            loop {
                let pending = (0..k).filter(|&w| self.links[w].is_some() && !acked[w]).count();
                if pending == 0 {
                    break;
                }
                match self.ring_ctrl_recv(until, deadline)? {
                    RingCtrl::Frame(w, frame) => {
                        if matches!(proto::peek_tag(&frame), Ok(proto::TAG_RING_READY)) {
                            if let Ok(s) = proto::decode_ring_ready(&frame) {
                                if s == step {
                                    acked[w] = true;
                                }
                            }
                        }
                        self.buf_pool.give_back(frame);
                    }
                    RingCtrl::LostLive | RingCtrl::TimedOut => {
                        self.ring_reset_live(step)?;
                        for w in 0..k {
                            if self.links[w].is_none() || acked[w] {
                                continue;
                            }
                            let mut frame = self.buf_pool.checkout();
                            proto::encode_ring_castd_header(step, 0, &mut frame);
                            frame.extend_from_slice(&fin_frame[fin_off..]);
                            stats.record_down(payload);
                            self.ring_send(w, frame, "ring cast-down retry");
                        }
                        until = Instant::now() + grace;
                    }
                }
            }
            self.buf_pool.give_back(fin_frame);
            return Ok(BatchOut { outs, worker_ms, micro_ms });
        }
    }

    /// Distributed synthetic pre-training (all-ones masks), mirroring
    /// the serial trainer's pretrain arithmetic exactly.
    fn pretrain(&mut self, stats: &mut WireStats) -> Result<()> {
        let cfg = self.cfg.train.clone();
        if cfg.pretrain_batches == 0 {
            return Ok(());
        }
        let mc = self.agg.config().clone();
        let mb = self.agg.micro_batch();
        let n = cfg.pretrain_batches * cfg.micros_per_batch * mb;
        let pre = DatasetSpec::preset(SyntheticKind::Pretrain, mc.img_size, n, cfg.seed ^ 0x5A)
            .generate("train");
        let mut batcher = Batcher::new(&pre, mb, cfg.micros_per_batch, cfg.seed);
        while let Some(micros) = batcher.next_batch() {
            let masks: Vec<MaskPair> =
                (0..micros.len()).map(|_| MaskPair::ones(mc.depth, mc.heads)).collect();
            self.exec_batch(&micros, &masks, stats)?;
        }
        self.agg.reset_momentum()?;
        for (w, slot) in self.links.iter_mut().enumerate() {
            let Some(link) = slot else { continue };
            let mut frame = self.buf_pool.checkout();
            proto::encode_ctrl(proto::TAG_RESET, &mut frame);
            link.send_blob(frame)
                .with_context(|| format!("sending momentum reset to worker {w}"))?;
        }
        Ok(())
    }

    /// Evaluate test top-1 on the aggregator replica (full forward).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mb = self.agg.eval_micro_batch();
        let mut meter = Meter::new();
        let mut i = 0;
        while i + mb <= self.test.len() {
            let idxs: Vec<usize> = (i..i + mb).collect();
            let (x, y) = self.test.gather(&idxs);
            let out = self.agg.eval(&x, &y, None)?;
            meter.push(out.loss, out.n_correct, mb);
            i += mb;
        }
        Ok((meter.top1(), meter.mean_loss()))
    }

    /// Graceful cluster teardown: send every worker a shutdown frame,
    /// collect their Bye acknowledgments (local pool counters), and
    /// join reader threads, worker threads, and worker subprocesses.
    /// Idempotent; run at the end of [`DistTrainer::run`] so the report
    /// can include worker-side counters, and again (as a no-op) on
    /// drop.
    fn shutdown_workers(&mut self) -> Result<()> {
        if self.shut_down {
            return Ok(());
        }
        self.shut_down = true;
        let mut awaiting: Vec<usize> = Vec::new();
        for (w, slot) in self.links.iter_mut().enumerate() {
            let Some(link) = slot else { continue };
            let mut frame = self.buf_pool.checkout();
            proto::encode_ctrl(proto::TAG_SHUTDOWN, &mut frame);
            if link.send_blob(frame).is_ok() {
                awaiting.push(w);
            } else {
                // The link died between the last batch and now; drop it
                // rather than waiting for a Bye that cannot come.
                *slot = None;
            }
        }
        while !awaiting.is_empty() {
            match self.arrivals.recv_timeout(Duration::from_secs(60)) {
                Ok(Arrival::Bye { worker, msg }) => {
                    awaiting.retain(|&w| w != worker);
                    self.bye_fresh += msg.fresh;
                    self.bye_reused += msg.reused;
                    if let Some(slot) = self.bye_ring.get_mut(worker) {
                        slot.0 += msg.ring_sent;
                        slot.1 += msg.ring_recv;
                    }
                }
                Ok(Arrival::Up { frame, .. }) | Ok(Arrival::Ring { frame, .. }) => {
                    // A straggling duplicate from a reassignment (or a
                    // ring ack) racing the shutdown: stale by
                    // construction, recycle it.
                    self.buf_pool.give_back(frame);
                }
                Ok(Arrival::Corrupt { .. }) => {
                    // Nothing left to resend during teardown; count it.
                    self.frames_corrupt += 1;
                }
                Ok(Arrival::Lost { worker, error }) => {
                    if awaiting.contains(&worker) {
                        crate::warn_!("dist worker {worker} died during shutdown: {error}");
                        awaiting.retain(|&w| w != worker);
                        self.links[worker] = None;
                    }
                    // Lost from an already-evicted worker's reader
                    // winding down is expected noise.
                }
                Err(_) => anyhow::bail!(
                    "timed out waiting for worker Bye frames ({} still pending)",
                    awaiting.len()
                ),
            }
        }
        for handle in self.readers.drain(..) {
            let _ = handle.join();
        }
        for handle in self.worker_threads.drain(..) {
            let _ = handle.join();
        }
        for mut child in self.worker_procs.drain(..) {
            let _ = child.wait();
        }
        Ok(())
    }

    /// Epoch-boundary liveness echo: a Pong (seq = completed epochs) to
    /// every live worker. Cheap downlink canary — a dead link surfaces
    /// here as an eviction instead of during the next batch.
    fn broadcast_pong(&mut self, seq: u64) {
        let mut dead: Vec<(usize, String)> = Vec::new();
        for (w, slot) in self.links.iter_mut().enumerate() {
            let Some(link) = slot else { continue };
            let mut frame = self.buf_pool.checkout();
            proto::encode_pong(seq, &mut frame);
            if let Err(e) = link.send_blob(frame) {
                dead.push((w, format!("epoch pong send failed: {e:#}")));
            }
        }
        for (w, why) in dead {
            self.evict(w, &why);
        }
    }

    /// Act on any [`FaultAction::RejoinAtEpoch`] plans scheduled for
    /// the epoch that just started (`epoch` = completed-epoch count).
    fn maybe_rejoin(&mut self, epoch: usize) -> Result<()> {
        let plans = self.cfg.faults.clone();
        for (w, plan) in plans {
            if w >= self.links.len() || self.links[w].is_some() {
                continue;
            }
            let due = plan
                .actions
                .iter()
                .any(|a| matches!(*a, FaultAction::RejoinAtEpoch(e) if e == epoch));
            if due {
                self.rejoin(w)?;
            }
        }
        Ok(())
    }

    /// Elastic rejoin: bring a fresh worker up on slot `w`, run the
    /// Join→Init→barrier handshake, ship the aggregator's current
    /// parameter + momentum state (the rejoiner's deterministic init is
    /// epochs behind), and attach a reader thread. The next batch's
    /// schedule re-solves with the restored worker in the live set.
    fn rejoin(&mut self, w: usize) -> Result<()> {
        let mut transport: Box<dyn Transport> = match self.cfg.transport.clone() {
            TransportKind::Channel => {
                let (agg_end, worker_end) = channel_pair();
                let pool = Arc::clone(&self.buf_pool);
                let handle = thread::Builder::new()
                    .name(format!("d2ft-dist-{w}"))
                    .spawn(move || {
                        if let Err(e) = run_worker(Box::new(worker_end), pool) {
                            crate::warn_!("rejoined dist worker {w} exited with error: {e:#}");
                        }
                    })
                    .context("spawning rejoined dist worker thread")?;
                self.worker_threads.push(handle);
                self.link_stats.push(agg_end.stats_cell());
                Box::new(agg_end)
            }
            TransportKind::Tcp { spawn, .. } => {
                anyhow::ensure!(
                    matches!(spawn, SpawnMode::Threads),
                    "scripted worker rejoin over TCP is supported for thread-spawned \
                     workers only (subprocess/external workers rejoin by relaunching \
                     `repro dist-worker` against a fresh run)"
                );
                let local = self
                    .listener
                    .as_ref()
                    .map(|(_, a)| *a)
                    .ok_or_else(|| anyhow::anyhow!("worker rejoin needs the TCP listener"))?;
                let dial = local.to_string();
                let handle = thread::Builder::new()
                    .name(format!("d2ft-dist-{w}"))
                    .spawn(move || {
                        let pool = Arc::new(BufPool::new());
                        let res = TcpTransport::connect(
                            &dial,
                            Duration::from_secs(30),
                            Arc::clone(&pool),
                        )
                        .and_then(|t| run_worker(Box::new(t), pool));
                        if let Err(e) = res {
                            crate::warn_!("rejoined dist worker {w} exited with error: {e:#}");
                        }
                    })
                    .context("spawning rejoined tcp dist worker thread")?;
                self.worker_threads.push(handle);
                let (listener, _) = self.listener.as_ref().unwrap();
                let stream = accept_workers(listener, 1, Duration::from_secs(60))?
                    .pop()
                    .expect("accept_workers(1) returns one stream");
                let t = TcpTransport::from_stream(stream, Arc::clone(&self.buf_pool))?;
                self.link_stats.push(t.stats_cell());
                Box::new(t)
            }
        };
        self.handshake_and_attach(w, transport)?;
        self.joins += 1;
        self.membership.push(MembershipEvent {
            batch: self.cur_batch,
            worker: w,
            kind: "join".to_string(),
        });
        crate::info!("dist worker {w} rejoined at batch {}", self.cur_batch);
        Ok(())
    }

    /// Shared tail of the elastic rejoin and the mid-run reconnect:
    /// Join in (version-checked), Init out, handshake barrier, then the
    /// authoritative State snapshot — the returning replica
    /// re-synchronizes to the aggregator's current (start-of-batch)
    /// parameters, so re-attachment is bitwise neutral. Splits the link
    /// into slot `w` and attaches a reader thread.
    fn handshake_and_attach(&mut self, w: usize, mut transport: Box<dyn Transport>) -> Result<()> {
        let join = transport
            .recv_blob_timeout(Duration::from_secs(60))
            .with_context(|| format!("waiting for Join from returning worker {w}"))?
            .ok_or_else(|| {
                anyhow::anyhow!("returning worker {w} sent no Join within the 60s deadline")
            })?;
        let jm = proto::decode_join(&join)
            .with_context(|| format!("handshaking returning worker {w}"))?;
        self.buf_pool.give_back(join);
        anyhow::ensure!(
            jm.version == proto::PROTO_VERSION,
            "returning worker {w} speaks dist protocol version {}, \
             this aggregator speaks {}",
            jm.version,
            proto::PROTO_VERSION
        );
        let msg = InitMsg {
            worker: w,
            spec: self.spec.clone(),
            lora_rank: self.cfg.train.lora_rank,
            seed: self.cfg.train.seed,
            precision: self.cfg.wire_precision,
            compress: self.cfg.compress,
            ring: self.cfg.exchange.is_ring(),
            overlap: self.cfg.overlap,
            sim_wire_ms_per_mib: self.cfg.sim_wire_ms_per_mib,
            heartbeat_ms: self.cfg.heartbeat_ms,
            trace: self.cfg.trace_out.is_some(),
            clock_anchor_us: trace::now_us(),
            incarnation: self.incarnation,
        };
        let mut frame = self.buf_pool.checkout();
        proto::encode_init(&msg, &mut frame);
        transport
            .send_blob(frame)
            .with_context(|| format!("sending Init to returning worker {w}"))?;
        transport
            .barrier()
            .with_context(|| format!("handshake barrier with returning worker {w}"))?;
        let (params, momentum) = self.agg.export_state_flat();
        let mut frame = self.buf_pool.checkout();
        proto::encode_state(&params, &momentum, &mut frame);
        transport
            .send_blob(frame)
            .with_context(|| format!("sending State to returning worker {w}"))?;
        let (tx, rx) = transport.split();
        let fan_in = self.arr_tx.clone();
        let liveness = reader_liveness(self.cfg.heartbeat_ms, self.cfg.liveness_misses);
        let pool = Arc::clone(&self.buf_pool);
        let traces = Arc::clone(&self.trace_sink);
        let handle = thread::Builder::new()
            .name(format!("d2ft-dist-{w}-rx"))
            .spawn(move || reader_loop(w, rx, fan_in, liveness, pool, traces))
            .context("spawning dist reader thread for a returning worker")?;
        self.readers.push(handle);
        self.links[w] = Some(tx);
        self.ema_ms[w] = 1.0;
        self.membership_dirty = true;
        self.ring_dirty = true;
        Ok(())
    }

    /// Mid-run link recovery: a `Lost` worker whose process may still
    /// be alive (the TCP redial loop) gets one chance to re-attach
    /// before eviction. Holds the accept window open briefly — the
    /// worker's capped backoff redials well inside it — then replays
    /// the rejoin handshake so the returning replica re-synchronizes.
    /// Returns `false` (the caller evicts) on the channel transport,
    /// without a held listener, or when no redial lands in time. A
    /// transient drop inside the liveness window therefore heals with
    /// **zero evictions** and `reconnects + 1`.
    fn try_reconnect(&mut self, w: usize, why: &str) -> bool {
        if !matches!(self.cfg.transport, TransportKind::Tcp { .. }) {
            return false;
        }
        let window = reader_liveness(self.cfg.heartbeat_ms, self.cfg.liveness_misses)
            .min(Duration::from_secs(10));
        if self.listener.is_none() {
            return false;
        }
        crate::warn_!(
            "dist worker {w} link dropped ({why}); holding the accept window {window:?} \
             for a redial"
        );
        // A redial *window*, not a single accept: a worker riding out a
        // partition dials, fails its Join mid-partition, drops the
        // socket, and dials again after backoff — every failed attempt
        // burns one accepted stream, so keep accepting until the
        // deadline instead of giving up on the first corpse.
        let deadline = Instant::now() + window;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return false;
            }
            let Some((listener, _)) = self.listener.as_ref() else {
                return false;
            };
            let stream = match accept_workers(listener, 1, remaining) {
                Ok(mut v) => match v.pop() {
                    Some(s) => s,
                    None => return false,
                },
                Err(_) => return false,
            };
            let transport: Box<dyn Transport> =
                match TcpTransport::from_stream(stream, Arc::clone(&self.buf_pool)) {
                    Ok(t) => {
                        self.link_stats.push(t.stats_cell());
                        Box::new(t)
                    }
                    Err(e) => {
                        crate::warn_!("dist worker {w} redial produced a bad stream: {e:#}");
                        continue;
                    }
                };
            match self.handshake_and_attach(w, transport) {
                Ok(()) => {
                    self.reconnects += 1;
                    self.membership.push(MembershipEvent {
                        batch: self.cur_batch,
                        worker: w,
                        kind: "reconnect".to_string(),
                    });
                    trace::instant("ctrl", "reconnect");
                    crate::info!("dist worker {w} reconnected at batch {}", self.cur_batch);
                    return true;
                }
                Err(e) => {
                    crate::warn_!(
                        "dist worker {w} reconnect handshake failed ({e:#}); \
                         holding the window for another redial"
                    );
                    continue;
                }
            }
        }
    }

    /// Write the epoch-boundary checkpoint when configured.
    fn write_checkpoint(
        &mut self,
        epoch: usize,
        batch: usize,
        score_cache: &[Option<ScoreBook>],
    ) -> Result<()> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(());
        };
        if epoch % self.cfg.checkpoint_every.max(1) != 0 {
            return Ok(());
        }
        let _sp = trace::span("ckpt", "write");
        let (params, momentum) = self.agg.export_state_flat();
        let ck = Checkpoint { epoch, batch, params, momentum, score_books: score_cache.to_vec() };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        // Atomic replace (tmp + fsync + rename): a crash mid-write can
        // never leave a truncated newest checkpoint shadowing a good
        // older one — `latest_valid` always finds something loadable.
        ck.save_atomic(&ckpt_path(&dir, epoch))?;
        rotate(&dir, self.cfg.checkpoint_retain)?;
        self.checkpoints_written += 1;
        Ok(())
    }

    /// Rewrite the step-granular progress record (atomic replace) after
    /// a completed batch — the breadcrumb `--resume` uses to count
    /// aggregator generations and report where the crash landed. No-op
    /// without a checkpoint directory.
    fn write_progress(&self, epoch: usize, batch: usize) -> Result<()> {
        let Some(dir) = self.cfg.checkpoint_dir.as_ref() else {
            return Ok(());
        };
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let pr = Progress {
            epoch,
            batch,
            step: self.step,
            restarts: self.aggregator_restarts as u32,
        };
        pr.save_atomic(dir)
    }

    /// Publish the run's live counters into `reg`. Every series is a
    /// snapshot store (not an increment), so republishing after every
    /// batch is idempotent and cheap — the registry is lock-per-lookup,
    /// the values are relaxed atomics.
    fn publish_metrics(
        &self,
        reg: &Registry,
        stats: &WireStats,
        pretrain: &WireStats,
        epochs: usize,
    ) {
        reg.store("d2ft_wire_up_bytes", stats.up_bytes);
        reg.store("d2ft_wire_down_bytes", stats.down_bytes);
        reg.store("d2ft_wire_dense_up_bytes", stats.dense_up_bytes);
        reg.store("d2ft_wire_up_msgs", stats.up_msgs);
        reg.store("d2ft_wire_down_msgs", stats.down_msgs);
        reg.store("d2ft_pretrain_wire_up_bytes", pretrain.up_bytes);
        reg.store("d2ft_pretrain_wire_down_bytes", pretrain.down_bytes);
        let mut socket = TransportStats::default();
        for cell in &self.link_stats {
            socket.merge(&cell.snapshot());
        }
        reg.store("d2ft_socket_bytes_sent", socket.bytes_sent);
        reg.store("d2ft_socket_bytes_recv", socket.bytes_recv);
        reg.store("d2ft_socket_frames_sent", socket.frames_sent);
        reg.store("d2ft_socket_frames_recv", socket.frames_recv);
        for (name, sent, recv) in socket.classes() {
            reg.store(&format!("d2ft_socket_class_sent_bytes{{class=\"{name}\"}}"), sent);
            reg.store(&format!("d2ft_socket_class_recv_bytes{{class=\"{name}\"}}"), recv);
        }
        reg.store("d2ft_evictions_total", self.evictions as u64);
        reg.store("d2ft_joins_total", self.joins as u64);
        reg.store("d2ft_reconnects_total", self.reconnects as u64);
        reg.store("d2ft_frames_corrupt_total", self.frames_corrupt as u64);
        reg.store("d2ft_resends_total", self.resends as u64);
        reg.store("d2ft_aggregator_restarts_total", self.aggregator_restarts as u64);
        reg.store("d2ft_reassigned_micros_total", self.reassigned_micros as u64);
        reg.store("d2ft_knapsack_resolves_total", self.knapsack_resolves as u64);
        reg.store("d2ft_checkpoints_written_total", self.checkpoints_written as u64);
        reg.store("d2ft_epochs_total", epochs as u64);
        reg.set("d2ft_workers_live", self.live_workers() as f64);
        reg.set("d2ft_workers_total", self.links.len() as f64);
        reg.store("d2ft_encode_buf_fresh", self.buf_pool.fresh_allocs());
        reg.store("d2ft_encode_buf_reused", self.buf_pool.reuses());
    }

    /// Merge the aggregator's own drained rings with every worker trace
    /// batch shipped over `TAG_TRACE` and write the Chrome trace-event
    /// JSON to `cfg.trace_out`. Worker clocks are normalized onto the
    /// aggregator timeline with the per-worker offset measured at the
    /// Init handshake. No-op when tracing is off.
    fn write_trace_artifact(&mut self) -> Result<()> {
        let Some(path) = self.cfg.trace_out.clone() else {
            return Ok(());
        };
        let local = trace::drain();
        let mut truncated = local.truncated;
        let mut events: Vec<trace::WireEvent> =
            local.events.iter().map(|e| e.to_wire()).collect();
        let msgs = {
            let mut sink = match self.trace_sink.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            std::mem::take(&mut *sink)
        };
        for msg in msgs {
            truncated += msg.truncated;
            for mut e in msg.events {
                // Channel-mode workers share this process's recorder, so
                // a worker's drain may carry lane-0 (aggregator) events —
                // those are already on the aggregator clock and must not
                // be shifted.
                if e.lane != 0 {
                    e.ts_us = (e.ts_us as i64 + msg.offset_us).max(0) as u64;
                }
                events.push(e);
            }
        }
        events.sort_by_key(|e| e.ts_us);
        let doc = trace::chrome_trace_json(&events, truncated);
        std::fs::write(&path, doc.to_string_compact())
            .with_context(|| format!("writing trace artifact to {}", path.display()))?;
        crate::info!("wrote {} trace events to {}", events.len(), path.display());
        trace::set_enabled(false);
        Ok(())
    }

    /// Run the full distributed fine-tuning loop.
    pub fn run(&mut self) -> Result<DistReport> {
        let cfg = self.cfg.train.clone();
        let mb = self.agg.micro_batch();
        let k = self.links.len();
        // Publish a zeroed snapshot up front so an early scrape of the
        // live endpoint sees the full metric schema, not whatever
        // happened to be touched yet.
        let reg = self.cfg.metrics.clone();
        if let Some(reg) = &reg {
            self.publish_metrics(reg, &WireStats::default(), &WireStats::default(), 0);
            reg.observe("d2ft_step_latency_ms", f64::NAN); // create the series, record nothing
        }
        // Resume, if configured: install the checkpoint's parameters,
        // momentum, and score cache on the aggregator, ship the same
        // bits to every worker as a State frame, and skip pretraining
        // (checkpoints are taken after it). Checkpoints land only at
        // epoch boundaries, so restarting the batcher at the recorded
        // batch index reproduces the uninterrupted run bitwise.
        let mut start_batch = 0usize;
        let mut epochs_done = 0usize;
        let mut resumed_scores: Vec<Option<ScoreBook>> = Vec::new();
        let resuming = self.cfg.resume_from.is_some();
        if let Some(path) = self.cfg.resume_from.clone() {
            // A directory is the crash-recovery form: scan it for the
            // newest *loadable* epoch checkpoint (a corrupt or
            // half-written newest file is skipped, not fatal) and read
            // the progress record for the restart counter. A file path
            // is the legacy exact-checkpoint form. Either way the run
            // re-executes from the checkpoint's batch; that replay is
            // deterministic, so the trajectory converges bitwise to
            // the uninterrupted run's.
            let ck = if path.is_dir() {
                let (found, ck) = latest_valid(&path)?.ok_or_else(|| {
                    anyhow::anyhow!("no loadable checkpoint in {}", path.display())
                })?;
                match Progress::load(&path)? {
                    Some(pr) => {
                        self.aggregator_restarts = pr.restarts as usize + 1;
                        crate::info!(
                            "progress record: crashed at epoch {}, batch {}, step {} — \
                             this is aggregator generation {}",
                            pr.epoch,
                            pr.batch,
                            pr.step,
                            self.aggregator_restarts + 1
                        );
                    }
                    None => self.aggregator_restarts = 1,
                }
                crate::info!("resuming from {}", found.display());
                ck
            } else {
                self.aggregator_restarts = 1;
                Checkpoint::load(&path)?
            };
            self.agg
                .import_state_flat(&ck.params, &ck.momentum)
                .context("installing checkpoint state on the aggregator")?;
            for (w, slot) in self.links.iter_mut().enumerate() {
                let Some(link) = slot else { continue };
                let mut frame = self.buf_pool.checkout();
                proto::encode_state(&ck.params, &ck.momentum, &mut frame);
                link.send_blob(frame)
                    .with_context(|| format!("sending resume state to worker {w}"))?;
            }
            start_batch = ck.batch;
            epochs_done = ck.epoch;
            resumed_scores = ck.score_books;
            crate::info!(
                "resumed from {} (epoch {}, batch {})",
                path.display(),
                epochs_done,
                start_batch
            );
        }
        // Pretrain traffic is accounted separately: its all-ones masks
        // ship dense messages, which would dilute the fine-tuning
        // savings headline if folded in.
        let mut pretrain_stats = WireStats::default();
        if !resuming {
            self.pretrain(&mut pretrain_stats)?;
        }
        let mut stats = WireStats::default();

        let mut scheduler = build_scheduler(cfg.scheduler, cfg.scores, cfg.seed);
        let budget = match &cfg.hetero {
            Some(h) => h.budget(cfg.budget.clone(), self.partition.n_subnets()),
            None => cfg.budget.clone(),
        };
        let cost = CostModel::paper();
        let n_devices = self.partition.n_subnets();
        let mut workloads = WorkloadTracker::new(cost, n_devices);
        // The simulated engine still runs for the modeled accounting —
        // that is exactly what the measured numbers are compared
        // against. Its exec-time model starts at the paper's V100 table
        // and, when calibration is on, is rescaled at every epoch
        // boundary from *this* run's measured per-task times.
        let mut ecfg = EngineConfig::accounting(cfg.exec, cfg.seed);
        ecfg.bytes_per_fullop = self.codec.dense_len() as u64;
        let mut exec_model = ExecTimeModel::paper();
        let mut engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
        // Calibration state. Two signals per epoch: (a) the per-task
        // least-squares system that splits the measured times into p_f
        // vs p_o factors, and (b) per-batch modeled device rows, so the
        // split factors can be renormalized to keep the modeled
        // makespan matched to the measured straggler (the drift
        // anchor). After the first calibration, each further epoch
        // contributes a modeled-vs-measured drift sample.
        let mut op_cal = OpCalibrator::new();
        let mut ep_rows: Vec<Vec<(f64, f64)>> = Vec::new();
        let mut calib_scale_full = 1.0f64;
        let mut calib_scale_fwd = 1.0f64;
        let mut calib_epochs = 0usize;
        let mut drift_sum = 0.0f64;
        let mut drift_n = 0usize;
        let mut ep_meas = 0.0f64;
        let mut ep_model = 0.0f64;
        let mut ep_batches = 0usize;
        let mut usage = DeviceUsage::new(n_devices);
        let mut worker_usage = DeviceUsage::new(k);
        let mut loss_curve = Vec::with_capacity(cfg.batches);
        let mut eval_curve = Vec::new();
        let mut score_cache: Vec<Option<ScoreBook>> = resumed_scores;
        let mut exec_ms_sum = 0.0;
        let mut makespan_sum = 0.0;
        let mut modeled_wire_bytes = 0u64;
        let mut step_ms_sum = 0.0;
        let mut meter = Meter::new();

        // Cloned so the epoch iterator does not hold a borrow of `self`
        // across the `exec_batch` calls.
        let train_data = self.train.clone();
        let t0 = Instant::now();
        let mut batch_idx = start_batch;
        'outer: while batch_idx < cfg.batches {
            let mut batcher = Batcher::new(&train_data, mb, cfg.micros_per_batch, cfg.seed);
            let mut epoch_pos = 0usize;
            while let Some(micros) = batcher.next_batch() {
                if batch_idx >= cfg.batches {
                    break 'outer;
                }
                self.cur_batch = batch_idx;
                // Membership changed since the last schedule: this
                // batch's knapsack solve is the membership re-solve,
                // with the straggler EMAs restarted for the new live
                // set. The budget is unchanged, so the masks — and the
                // numerics — are too.
                if self.membership_dirty {
                    self.membership_dirty = false;
                    self.knapsack_resolves += 1;
                    for w in 0..k {
                        if self.links[w].is_some() {
                            self.ema_ms[w] = 1.0;
                        }
                    }
                }
                // --- contribution scores (cached, aggregator-side) --------
                if score_cache.len() <= epoch_pos {
                    score_cache.resize(epoch_pos + 1, None);
                }
                if score_cache[epoch_pos].is_none() {
                    // Keep this guard in lockstep with the serial
                    // trainer's score-cache block — the bitwise
                    // serial ≡ dist contract depends on it.
                    let can_probe = self.agg.supports_probe();
                    score_cache[epoch_pos] = Some(if scheduler.needs_scores() && can_probe {
                        let probes: Vec<Tensor> = micros
                            .iter()
                            .map(|(x, y)| self.agg.score_probe(x, y))
                            .collect::<Result<_>>()?;
                        ScoreBook::from_probes(&self.partition, &probes)
                    } else {
                        ScoreBook::zeros(self.partition.n_subnets(), micros.len())
                    });
                }
                let book = score_cache[epoch_pos].as_ref().unwrap();
                // --- schedule + distributed execution ---------------------
                let table = scheduler.schedule(book, &budget);
                let masks = table.all_masks(&self.partition);
                let ts = Instant::now();
                let out = self.exec_batch(&micros, &masks, &mut stats)?;
                let step_ms = ts.elapsed().as_secs_f64() * 1e3;
                step_ms_sum += step_ms;
                if let Some(reg) = &reg {
                    reg.observe("d2ft_step_latency_ms", step_ms);
                    self.publish_metrics(reg, &stats, &pretrain_stats, epochs_done);
                }
                for &(loss, n_correct) in &out.outs {
                    meter.push(loss, n_correct, mb);
                    loss_curve.push(loss);
                }
                worker_usage.record(&out.worker_ms);
                // --- modeled accounting (the comparison baseline) ---------
                let cluster = engine.execute(&table);
                workloads.record(&table);
                workloads.record_measured(&cluster.measured_ms());
                usage.record(&cluster.finish_ms());
                exec_ms_sum += cluster.mean_device_ms;
                makespan_sum += cluster.makespan_ms;
                modeled_wire_bytes += cluster.wire_bytes;
                // Calibration samples: each task's measured compute
                // against its modeled p_f/p_o components (for the op
                // split), the batch's measured straggler against the
                // modeled makespan (for the drift anchor), and the
                // modeled device rows (for the renormalization).
                for (i, &ms) in out.micro_ms.iter().enumerate() {
                    let (mf, mo) = exec_model.micro_components(&table, i);
                    op_cal.observe(mf, mo, ms);
                }
                ep_rows.push(
                    (0..n_devices).map(|d| exec_model.device_row_components(&table, d)).collect(),
                );
                ep_meas += out.worker_ms.iter().copied().fold(0.0, f64::max);
                ep_model += cluster.makespan_ms;
                ep_batches += 1;
                if cfg.eval_every > 0 && (batch_idx + 1) % cfg.eval_every == 0 {
                    let (top1, _) = self.evaluate()?;
                    eval_curve.push((batch_idx + 1, top1));
                }
                batch_idx += 1;
                epoch_pos += 1;
                // Step-granular breadcrumb between epoch checkpoints —
                // after the batch, so a crash right here resumes with
                // this batch recorded as done.
                self.write_progress(epochs_done, batch_idx)?;
                if let Some(halt) = self.cfg.halt_after_batch {
                    if batch_idx >= halt {
                        // Crash simulation: die with the progress
                        // record on disk and no shutdown handshake
                        // (Drop tears the cluster down) — the
                        // deterministic in-process stand-in for
                        // SIGKILLing the aggregator.
                        anyhow::bail!(
                            "halted after batch {batch_idx} (halt_after_batch crash simulation)"
                        );
                    }
                }
            }
            // ---- epoch boundary: drift report + recalibration --------
            // Means over the epoch (not single batches) so host noise
            // averages out of both the drift metric and the scale.
            if ep_batches > 0 {
                let meas = ep_meas / ep_batches as f64;
                let model = ep_model / ep_batches as f64;
                if calib_epochs > 0 {
                    drift_sum += rel_drift(model, meas);
                    drift_n += 1;
                }
                if self.cfg.calibrate && meas > 0.0 && model > 0.0 {
                    // Two-stage feedback: the least-squares solve gives
                    // the p_f : p_o *shape* from per-task measurements;
                    // the factors are then renormalized so the epoch's
                    // mean modeled makespan under the new tables equals
                    // the measured straggler mean — the same fixed
                    // point the uniform calibration converged to, now
                    // with per-op structure. A degenerate system (e.g.
                    // a schedule with no p_o tasks) falls back to the
                    // uniform measured/modeled ratio.
                    let uniform = meas / model;
                    let (pf, po) = match op_cal.solve() {
                        Some((pf_raw, po_raw)) => {
                            let renorm: f64 = ep_rows
                                .iter()
                                .map(|rows| {
                                    rows.iter()
                                        .map(|&(f, o)| pf_raw * f + po_raw * o)
                                        .fold(0.0, f64::max)
                                })
                                .sum::<f64>()
                                / ep_rows.len() as f64;
                            if renorm > 0.0 {
                                let u = meas / renorm;
                                (pf_raw * u, po_raw * u)
                            } else {
                                (uniform, uniform)
                            }
                        }
                        None => (uniform, uniform),
                    };
                    exec_model = exec_model.scaled_per_op(pf, po);
                    calib_scale_full *= pf;
                    calib_scale_fwd *= po;
                    engine = Engine::with_models(ecfg, n_devices, exec_model.clone(), cost);
                    calib_epochs += 1;
                }
                op_cal.reset();
                ep_rows.clear();
                ep_meas = 0.0;
                ep_model = 0.0;
                ep_batches = 0;
            }
            // ---- epoch boundary: control-plane actions ----------------
            // Pong echo to live workers, checkpoint, and any scripted
            // rejoins due at the start of the next epoch.
            epochs_done += 1;
            self.broadcast_pong(epochs_done as u64);
            self.write_checkpoint(epochs_done, batch_idx, &score_cache)?;
            self.maybe_rejoin(epochs_done)?;
            if let Some(reg) = &reg {
                reg.set("d2ft_calib_scale_full", calib_scale_full);
                reg.set("d2ft_calib_scale_fwd", calib_scale_fwd);
                reg.set(
                    "d2ft_makespan_drift",
                    if drift_n > 0 { drift_sum / drift_n as f64 } else { 0.0 },
                );
                self.publish_metrics(reg, &stats, &pretrain_stats, epochs_done);
            }
        }
        // A run that ends mid-epoch still reports the partial epoch's
        // drift (it just never feeds another calibration).
        if ep_batches > 0 && calib_epochs > 0 {
            drift_sum += rel_drift(ep_model / ep_batches as f64, ep_meas / ep_batches as f64);
            drift_n += 1;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let (test_top1, test_loss) = self.evaluate()?;
        // Tear the cluster down *inside* run so the report can fold in
        // the worker-side pool counters and the final socket totals.
        self.shutdown_workers()?;
        // Every worker's Bye has been seen, so (per-link FIFO) every
        // shipped trace batch is already in the sink: merge and write.
        self.write_trace_artifact()?;
        if let Some(reg) = &reg {
            self.publish_metrics(reg, &stats, &pretrain_stats, epochs_done);
        }
        let mut socket = TransportStats::default();
        let mut socket_links = Vec::with_capacity(self.link_stats.len());
        for cell in &self.link_stats {
            let snap = cell.snapshot();
            socket.merge(&snap);
            socket_links.push(snap);
        }
        // In channel mode every party shares the aggregator's pool (one
        // set of counters); in TCP mode each process pools locally and
        // reports its counters in its Bye frame.
        let (buf_fresh, buf_reused) = match self.cfg.transport {
            TransportKind::Channel => (self.buf_pool.fresh_allocs(), self.buf_pool.reuses()),
            TransportKind::Tcp { .. } => (
                self.buf_pool.fresh_allocs() + self.bye_fresh,
                self.buf_pool.reuses() + self.bye_reused,
            ),
        };
        let b = workloads.batches().max(1) as f64;
        let train = TrainReport {
            scheduler: cfg.scheduler.label().to_string(),
            backend: self.agg.label().to_string(),
            final_train_loss: meter.mean_loss(),
            test_top1,
            test_loss,
            loss_curve,
            eval_curve,
            compute_fraction: workloads.total_compute_fraction(),
            comm_fraction: workloads.total_comm_fraction(),
            workload_variance: workloads.workload_variance(),
            sample_count_variance: workloads.sample_count_variance(),
            mean_exec_ms: exec_ms_sum / b,
            makespan_ms: makespan_sum / b,
            engine: format!(
                "dist({k} workers, {}, {})",
                self.cfg.exchange.label(),
                self.cfg.transport.label()
            ),
            utilization: usage.mean_utilization(),
            imbalance: usage.imbalance(),
            // Real straggler: slowest worker's measured time per batch.
            straggler_ms: worker_usage.total_makespan_ms() / worker_usage.steps().max(1) as f64,
            wall_s,
            batches: batch_idx,
            calib_scale: (calib_scale_full * calib_scale_fwd).sqrt(),
            calib_scale_full,
            calib_scale_fwd,
            calib_epochs,
            makespan_drift: if drift_n > 0 { drift_sum / drift_n as f64 } else { 0.0 },
        };
        let n_batches = worker_usage.steps().max(1) as f64;
        Ok(DistReport {
            grad_savings: stats.grad_savings(),
            n_workers: k,
            exchange: self.cfg.exchange.label().to_string(),
            transport: self.cfg.transport.label().to_string(),
            compress: self.cfg.compress.label(),
            wire: stats,
            pretrain_wire: pretrain_stats,
            socket,
            socket_links,
            ring_bytes: self.bye_ring.clone(),
            modeled_wire_bytes,
            mean_step_ms: step_ms_sum / n_batches,
            worker_busy_ms: worker_usage.busy_ms().to_vec(),
            worker_utilization: worker_usage.mean_utilization(),
            worker_imbalance: worker_usage.imbalance(),
            encode_buf_fresh: buf_fresh,
            encode_buf_reused: buf_reused,
            live_workers: self.live_workers(),
            evictions: self.evictions,
            joins: self.joins,
            reconnects: self.reconnects,
            frames_corrupt: self.frames_corrupt,
            resends: self.resends,
            aggregator_restarts: self.aggregator_restarts,
            reassigned_micros: self.reassigned_micros,
            knapsack_resolves: self.knapsack_resolves,
            epochs: epochs_done,
            checkpoints_written: self.checkpoints_written,
            membership: self.membership.clone(),
            train,
        })
    }
}

impl Drop for DistTrainer {
    fn drop(&mut self) {
        if !self.shut_down {
            // Best effort: a shutdown frame lets live workers exit
            // cleanly; closing the links afterwards unblocks any that
            // missed it.
            for slot in &mut self.links {
                let Some(link) = slot else { continue };
                let mut frame = Vec::new();
                proto::encode_ctrl(proto::TAG_SHUTDOWN, &mut frame);
                let _ = link.send_blob(frame);
            }
        }
        self.links.clear();
        for handle in self.readers.drain(..) {
            let _ = handle.join();
        }
        for handle in self.worker_threads.drain(..) {
            let _ = handle.join();
        }
        for mut child in self.worker_procs.drain(..) {
            let _ = child.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeSpec;
    use crate::runtime::ModelConfig;

    fn small_provider() -> NativeProvider {
        NativeProvider::new(NativeSpec {
            config: ModelConfig {
                img_size: 8,
                patch: 4,
                dim: 16,
                depth: 2,
                heads: 2,
                mlp_ratio: 2,
                classes: 10,
                lora_rank: 0,
                head_dim: 8,
                tokens: 5,
            },
            micro_batch: 2,
            mb_variants: vec![],
            lora_ranks: vec![2],
            lora_standard_rank: 2,
            init_seed: 0xBEEF,
            threads: 1,
        })
    }

    fn quick_cfg() -> TrainerConfig {
        // Builder defaults are the quick-run defaults (cifar10-like,
        // D2FT, 3+1-of-5 budget); only the run length shrinks.
        TrainerConfig::builder()
            .train_size(60)
            .test_size(12)
            .batches(2)
            .pretrain_batches(1)
            .build()
            .unwrap()
    }

    #[test]
    fn dist_trainer_runs_and_counts_bytes() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        let r = dt.run().unwrap();
        assert_eq!(r.n_workers, 2);
        assert_eq!(r.transport, "channel");
        assert_eq!(r.train.batches, 2);
        assert_eq!(r.train.loss_curve.len(), 10);
        assert!(r.train.final_train_loss.is_finite());
        assert!(r.wire.up_bytes > 0 && r.wire.down_bytes > 0);
        // 3 p_f + 1 p_o of 5 leaves head slices off the wire.
        assert!(r.grad_savings > 0.0, "masked schedule must save bytes");
        assert!(r.wire.up_bytes < r.wire.dense_up_bytes);
        assert_eq!(r.worker_busy_ms.len(), 2);
        // The transport layer saw every gradient frame plus the control
        // traffic (init/jobs/broadcasts), in both directions.
        assert!(r.socket.bytes_sent > 0 && r.socket.bytes_recv > 0);
        assert!(r.socket.bytes_recv >= r.wire.up_bytes + r.pretrain_wire.up_bytes);
        assert!(r.socket.frames_recv >= r.wire.up_msgs + r.pretrain_wire.up_msgs);
    }

    #[test]
    fn overlap_off_matches_overlap_on_bitwise() {
        // The pipelined sender changes *when* bytes move, never which
        // bytes or how they reduce: trajectories and parameters must be
        // bit-equal with the pipeline on and off.
        let provider = small_provider();
        let run = |overlap: bool| {
            let dcfg = DistConfig::builder(quick_cfg(), 3).overlap(overlap).build().unwrap();
            let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
            let r = dt.run().unwrap();
            let w = dt.backend().param("b00_wqkv").unwrap();
            (r, w)
        };
        let (on, w_on) = run(true);
        let (off, w_off) = run(false);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&on.train.loss_curve), bits(&off.train.loss_curve));
        assert_eq!(w_on, w_off, "overlap must not move a single parameter bit");
        assert_eq!(on.wire.up_bytes, off.wire.up_bytes, "same bytes either way");
    }

    #[test]
    fn encode_buffers_recycle_in_steady_state() {
        // Zero per-task allocations after warmup: fresh buffer count is
        // bounded by what can be in flight at once (job frames, double
        // buffers, one batch's gradient messages, broadcast copies),
        // never by how many batches ran.
        let provider = small_provider();
        let mut cfg = quick_cfg();
        cfg.batches = 8;
        let workers = 2u64;
        let micros = 5u64;
        let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, workers as usize)).unwrap();
        let r = dt.run().unwrap();
        let in_flight_bound = 2 * micros + 6 * workers + 8;
        assert!(
            r.encode_buf_fresh <= in_flight_bound,
            "fresh allocations ({}) exceed the in-flight bound ({in_flight_bound}) — \
             the recycle loop is broken",
            r.encode_buf_fresh
        );
        assert!(
            r.encode_buf_reused > r.encode_buf_fresh,
            "most checkouts must be recycled: fresh {} vs reused {}",
            r.encode_buf_fresh,
            r.encode_buf_reused
        );
        // Every gradient message took exactly one checkout on its way
        // out of a worker (plus control traffic on top).
        assert!(
            r.encode_buf_fresh + r.encode_buf_reused
                >= r.wire.up_msgs + r.pretrain_wire.up_msgs,
            "pool counters must cover every uplink message"
        );
    }

    #[test]
    fn f16_wire_halves_measured_bytes_and_trains() {
        let provider = small_provider();
        let run = |prec| {
            let dcfg = DistConfig::builder(quick_cfg(), 2).wire_precision(prec).build().unwrap();
            DistTrainer::new(&provider, dcfg).unwrap().run().unwrap()
        };
        let r32 = run(WirePrecision::F32);
        let r16 = run(WirePrecision::F16);
        assert!(r16.train.final_train_loss.is_finite());
        assert_eq!(r32.wire.up_msgs, r16.wire.up_msgs);
        let ratio = r16.wire.up_bytes as f64 / r32.wire.up_bytes as f64;
        assert!(
            ratio < 0.52,
            "f16 must roughly halve the measured uplink, got {ratio:.3}"
        );
        // f16 + parameter server is rejected up front.
        let bad = DistConfig::builder(quick_cfg(), 2)
            .wire_precision(WirePrecision::F16)
            .exchange(ExchangeMode::ParamServer)
            .build()
            .unwrap();
        assert!(DistTrainer::new(&provider, bad).is_err());
    }

    #[test]
    fn worker_count_must_be_positive() {
        let provider = small_provider();
        assert!(DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 0)).is_err());
    }

    #[test]
    fn ring_blocks_and_groups_partition_cleanly() {
        for k in 1..=9 {
            for n in 0..=13 {
                let b = ring_blocks(k, n);
                assert_eq!(b.len(), k);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[k - 1].1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "blocks must be contiguous");
                }
                let sizes: Vec<usize> = b.iter().map(|&(s, e)| e - s).collect();
                let min = *sizes.iter().min().unwrap();
                let max = *sizes.iter().max().unwrap();
                assert!(max - min <= 1, "near-equal blocks, got {sizes:?}");
            }
            for group in 0..=k {
                let g = ring_groups(k, group);
                assert_eq!(g[0].0, 0);
                assert_eq!(g[g.len() - 1].1, k);
                for w in g.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "groups must be contiguous");
                }
            }
        }
        assert_eq!(ring_groups(4, 0), vec![(0, 2), (2, 4)]);
        assert_eq!(ring_groups(5, 0), vec![(0, 3), (3, 5)]);
        assert_eq!(ring_groups(3, 5), vec![(0, 3)]);
    }

    #[test]
    fn ring_exchange_matches_star_bitwise() {
        // The chain fold adds the same f32 values in the same ascending
        // micro order as the ordered star reduce, and the uncompressed
        // codec round-trips bits exactly — trajectories and parameters
        // must be identical across all three topologies.
        let provider = small_provider();
        let run = |exchange| {
            let dcfg = DistConfig::builder(quick_cfg(), 2).exchange(exchange).build().unwrap();
            let mut dt = DistTrainer::new(&provider, dcfg).unwrap();
            let r = dt.run().unwrap();
            let w = dt.backend().param("b00_wqkv").unwrap();
            (r, w)
        };
        let (star, w_star) = run(ExchangeMode::MaskedAllReduce);
        let (ring, w_ring) = run(ExchangeMode::Ring);
        let (hier, w_hier) = run(ExchangeMode::Hierarchical);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&star.train.loss_curve), bits(&ring.train.loss_curve));
        assert_eq!(bits(&star.train.loss_curve), bits(&hier.train.loss_curve));
        assert_eq!(w_star, w_ring, "ring must not move a single parameter bit");
        assert_eq!(w_star, w_hier, "hierarchical must not move a single parameter bit");
        assert_eq!(ring.exchange, "ring");
        assert_eq!(ring.compress, "none");
        assert_eq!(ring.ring_bytes.len(), 2);
    }

    #[test]
    fn int8_wire_trains_and_shrinks_uplink() {
        let provider = small_provider();
        let run = |compress| {
            let dcfg = DistConfig::builder(quick_cfg(), 2).compress(compress).build().unwrap();
            DistTrainer::new(&provider, dcfg).unwrap().run().unwrap()
        };
        let dense = run(WireCompression::None);
        let q8 = run(WireCompression::Int8);
        assert!(q8.train.final_train_loss.is_finite());
        assert_eq!(q8.compress, "int8");
        let ratio = dense.wire.up_bytes as f64 / q8.wire.up_bytes as f64;
        assert!(ratio > 3.0, "int8 must shrink the uplink roughly 4x, got {ratio:.2}");
    }

    #[test]
    fn compression_guards_reject_inconsistent_configs() {
        let provider = small_provider();
        let bad = DistConfig::builder(quick_cfg(), 2)
            .compress(WireCompression::Int8)
            .exchange(ExchangeMode::ParamServer)
            .build()
            .unwrap();
        assert!(DistTrainer::new(&provider, bad).is_err(), "compression needs grad exchange");
        let bad = DistConfig::builder(quick_cfg(), 2)
            .compress(WireCompression::Int4)
            .wire_precision(WirePrecision::F16)
            .build()
            .unwrap();
        assert!(DistTrainer::new(&provider, bad).is_err(), "int4 cannot stack on f16");
        let ok = DistConfig::builder(quick_cfg(), 2)
            .compress(WireCompression::TopK { pct: 10 })
            .wire_precision(WirePrecision::F16)
            .build()
            .unwrap();
        assert!(DistTrainer::new(&provider, ok).is_ok(), "top-k composes with the f16 wire");
    }

    #[test]
    fn assignment_balances_by_measured_ema() {
        let provider = small_provider();
        let mut dt = DistTrainer::new(&provider, DistConfig::new(quick_cfg(), 2)).unwrap();
        // Pretend worker 1 is 3x slower than worker 0.
        dt.ema_ms = vec![1.0, 3.0];
        let a = dt.assign(4);
        let w0 = a.iter().filter(|&&w| w == 0).count();
        let w1 = a.iter().filter(|&&w| w == 1).count();
        assert!(w0 > w1, "fast worker takes more micro-batches: {a:?}");
        assert_eq!(w0 + w1, 4);
    }

    #[test]
    fn per_op_calibration_converges_and_reports_split_factors() {
        // Two epochs over a mixed p_f/p_o schedule: the epoch boundary
        // must produce at least one calibration with finite positive
        // split factors, and the geometric-mean scale must agree with
        // the reported per-op factors.
        let provider = small_provider();
        let mut cfg = quick_cfg();
        cfg.train_size = 40; // 4 batches/epoch at mb 2 x 5 micros
        cfg.batches = 8;
        let mut dt = DistTrainer::new(&provider, DistConfig::new(cfg, 2)).unwrap();
        let r = dt.run().unwrap();
        assert!(r.train.calib_epochs >= 1, "two epochs must calibrate at least once");
        assert!(r.train.calib_scale_full.is_finite() && r.train.calib_scale_full > 0.0);
        assert!(r.train.calib_scale_fwd.is_finite() && r.train.calib_scale_fwd > 0.0);
        let geo = (r.train.calib_scale_full * r.train.calib_scale_fwd).sqrt();
        assert!((r.train.calib_scale - geo).abs() < 1e-12);
    }
}
