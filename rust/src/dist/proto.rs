//! The aggregator ↔ worker control protocol: every message that used
//! to be an in-process `enum` moved over an mpsc channel, now defined
//! as explicit little-endian frames so the same bytes drive a thread
//! over a channel, a forked subprocess over loopback TCP, or a worker
//! on another host.
//!
//! One frame (see [`super::transport`]) carries one message: a `u32`
//! tag followed by tag-specific fields. Gradient payloads embedded in
//! [`TAG_UP`] / [`TAG_APPLY`] / [`TAG_DELTAS`] frames are the
//! **unchanged** [`super::grads::GradCodec`] wire format (28-byte
//! header + packed slices), appended as the frame's tail so the
//! receiver can decode them in place — the codec's own magic, mask
//! fingerprint, and length checks still guard every gradient byte.
//!
//! Decoding is defensive end to end: a truncated or malformed frame
//! (from a corrupt link or a confused peer) produces a descriptive
//! error, never a panic or an out-of-bounds read — `tests/dist_tcp.rs`
//! pins this for frames mangled at the socket level.

use anyhow::Result;

use crate::backend::native::NativeSpec;
use crate::runtime::ModelConfig;
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

use super::grads::{WireCompression, WirePrecision};

/// Aggregator → worker: build your replica (sent once, first).
pub const TAG_INIT: u32 = 0x4401;
/// Aggregator → worker: compute masked gradients for these micros.
pub const TAG_COMPUTE: u32 = 0x4402;
/// Aggregator → worker: apply the reduced masked gradient (allreduce).
pub const TAG_APPLY: u32 = 0x4403;
/// Aggregator → worker: install dense update deltas (param-server).
pub const TAG_DELTAS: u32 = 0x4404;
/// Aggregator → worker: zero the momentum buffers.
pub const TAG_RESET: u32 = 0x4405;
/// Aggregator → worker: clean shutdown; reply with [`TAG_BYE`].
pub const TAG_SHUTDOWN: u32 = 0x4406;
/// Worker → aggregator: one computed micro-batch gradient.
pub const TAG_UP: u32 = 0x4411;
/// Worker → aggregator: shutdown acknowledgment + local pool stats.
pub const TAG_BYE: u32 = 0x4412;
/// Worker → aggregator: heartbeat — "I am alive" (carries a sequence
/// number; arrival resets the aggregator's liveness timer).
pub const TAG_PING: u32 = 0x4421;
/// Aggregator → worker: heartbeat acknowledgment / epoch beacon.
pub const TAG_PONG: u32 = 0x4422;
/// Worker → aggregator: membership request, sent first on connect
/// (carries the worker's protocol version for handshake validation).
pub const TAG_JOIN: u32 = 0x4423;
/// Aggregator → worker: you have been evicted from the membership
/// (missed liveness deadline or broken link); exit without a Bye.
pub const TAG_EVICT: u32 = 0x4424;
/// Aggregator → worker: install this optimizer state (flattened
/// params + momentum) — sent on rejoin and on checkpoint resume so a
/// late worker becomes a bitwise replica of the aggregator.
pub const TAG_STATE: u32 = 0x4425;
/// Aggregator → worker: your last frame arrived corrupt (CRC trailer
/// mismatch) — resend the retained Up for the named step. Corruption
/// thus degrades to a retry instead of an eviction; the step stamp
/// keeps a duplicate resend idempotent at the reducer.
pub const TAG_NACK: u32 = 0x4426;
/// Aggregator → worker: open a ring listener (ring-link negotiation,
/// step 1); reply with [`TAG_RING_ADDR`].
pub const TAG_RING_LISTEN: u32 = 0x4431;
/// Aggregator → worker: your ring successor's address (negotiation,
/// step 2) — connect to it, accept your predecessor, reply with
/// [`TAG_RING_READY`].
pub const TAG_RING_PEERS: u32 = 0x4432;
/// Aggregator → worker: run one ring exchange for this step (roles,
/// scale, union mask).
pub const TAG_RING_EXEC: u32 = 0x4433;
/// Aggregator → worker: abandon the in-flight ring exchange (a member
/// died or stalled); drop partials and await re-dispatch.
pub const TAG_RING_RESET: u32 = 0x4434;
/// Aggregator → group leader: the final reduced gradient to apply and
/// cast intra-group (hierarchical distribute leg).
pub const TAG_RING_CASTD: u32 = 0x4435;
/// Worker → aggregator: my ring listener address (negotiation reply).
pub const TAG_RING_ADDR: u32 = 0x4441;
/// Worker → aggregator: the chain-final reduced gradient (sent by the
/// last worker of the reduce chain).
pub const TAG_RING_FINAL: u32 = 0x4442;
/// Worker → aggregator: ring links are up (negotiation complete).
pub const TAG_RING_READY: u32 = 0x4443;

/// Worker ↔ worker, first field of a ring-link blob: a partial chain
/// sum in flight toward the chain's tail.
pub const TAG_RING_PART: u32 = 0x4451;
/// Worker ↔ worker: the final reduced gradient being distributed
/// (apply locally, forward while `hops > 0`).
pub const TAG_RING_CAST: u32 = 0x4452;

/// Worker → aggregator: a drained trace-event batch (sent at epoch
/// boundaries when the run traces; see [`crate::obs::trace`]). Purely
/// observational — the aggregator tolerates its absence, so a v3
/// worker that never sends one still interoperates.
pub const TAG_TRACE: u32 = 0x4461;

/// Serve → replica: one admitted round of a tenant job — adapter +
/// mask state to hot-swap in and the batch range to run (the
/// multi-tenant service's tenant-tagged frame; see [`JobRoundMsg`]).
pub const TAG_JOB_ROUND: u32 = 0x4471;
/// Replica → serve: round outcome — trained adapter state, solved
/// masks (fresh rounds), losses and step timings (see [`JobDoneMsg`]).
pub const TAG_JOB_DONE: u32 = 0x4472;

/// Control-protocol version carried in [`TAG_JOIN`]; the aggregator
/// rejects a mismatched worker descriptively instead of misparsing
/// its frames. v3 added the ring-collective frames, the compressed
/// wire, and the ring/compress fields of [`InitMsg`]; v4 added the
/// [`TAG_TRACE`] frame and the trace/clock-anchor fields of
/// [`InitMsg`]; v5 added CRC32C frame trailers (see
/// [`super::transport`]), the [`TAG_NACK`] resend request, the
/// incarnation/worker/last-step fields of [`JoinMsg`], and the
/// incarnation field of [`InitMsg`]; v6 added the tenant-tagged
/// [`TAG_JOB_ROUND`] / [`TAG_JOB_DONE`] frames of the serve layer.
pub const PROTO_VERSION: u32 = 6;

/// Byte offset of the embedded gradient blob in a [`TAG_UP`] frame:
/// tag (4) + micro (4) + loss (4) + n_correct (4) + ms (8) + step (8).
pub const UP_GRAD_OFF: usize = 32;

// ---------------------------------------------------------------------------
// Cursor: bounds-checked little-endian reads
// ---------------------------------------------------------------------------

/// A bounds-checked reader over one frame's bytes. Every accessor
/// fails with a "truncated" error instead of panicking when the frame
/// is shorter than its tag promises.
pub struct Cursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    /// Start reading `bytes` from offset 0.
    pub fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, off: 0 }
    }

    /// Current read offset.
    pub fn offset(&self) -> usize {
        self.off
    }

    /// Bytes left unread in the frame.
    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.off
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.off + n <= self.bytes.len(),
            "truncated message: {what} needs {n} bytes at offset {}, frame has {}",
            self.off,
            self.bytes.len()
        );
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    /// Read one `u8`.
    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    /// Read one little-endian `u32`.
    pub fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    /// Read one little-endian `u64`.
    pub fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// Read one little-endian `f32` (bit-exact).
    pub fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    /// Read one little-endian `f64` (bit-exact).
    pub fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Read a `u32` element count, guarded so a corrupt count cannot
    /// request a huge allocation: the count must fit in the bytes that
    /// actually remain (`elem_bytes` per element).
    pub fn count(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u32(what)? as usize;
        let remaining = self.bytes.len() - self.off;
        anyhow::ensure!(
            n.saturating_mul(elem_bytes) <= remaining,
            "corrupt count: {what} claims {n} elements ({elem_bytes} bytes each) \
             but only {remaining} bytes remain"
        );
        Ok(n)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    put_u32(out, v.to_bits());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(c: &mut Cursor<'_>, what: &str) -> Result<String> {
    let n = c.count(1, what)?;
    let bytes = c.take(n, what)?.to_vec();
    String::from_utf8(bytes).map_err(|_| anyhow::anyhow!("{what}: invalid UTF-8"))
}

fn put_usize_list(out: &mut Vec<u8>, vs: &[usize]) {
    put_u32(out, vs.len() as u32);
    for &v in vs {
        put_u32(out, v as u32);
    }
}

fn get_usize_list(c: &mut Cursor<'_>, what: &str) -> Result<Vec<usize>> {
    let n = c.count(4, what)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(c.u32(what)? as usize);
    }
    Ok(out)
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_usize_list(out, t.shape());
    for &v in t.data() {
        put_f32(out, v);
    }
}

fn get_tensor(c: &mut Cursor<'_>, what: &str) -> Result<Tensor> {
    let shape = get_usize_list(c, what)?;
    // The shape came off the wire: fold its product with overflow
    // checks (a crafted dimension list must not wrap into a small
    // value) and cap the allocation by the bytes that actually remain.
    let len = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("corrupt count: {what} tensor shape overflows"))?;
    anyhow::ensure!(
        len.saturating_mul(4) <= c.remaining(),
        "corrupt count: {what} tensor claims {len} elements but only {} bytes remain",
        c.remaining()
    );
    let mut data = Vec::with_capacity(len);
    for _ in 0..len {
        data.push(c.f32(what)?);
    }
    Ok(Tensor::from_vec(&shape, data))
}

fn put_masks(out: &mut Vec<u8>, m: &MaskPair) {
    put_tensor(out, &m.fwd);
    put_tensor(out, &m.bwd);
}

fn get_masks(c: &mut Cursor<'_>, what: &str) -> Result<MaskPair> {
    let fwd = get_tensor(c, what)?;
    let bwd = get_tensor(c, what)?;
    anyhow::ensure!(
        fwd.shape() == bwd.shape() && fwd.shape().len() == 2,
        "{what}: mask pair must be two [depth, heads] tensors"
    );
    Ok(MaskPair { fwd, bwd })
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Everything a worker needs to become a bitwise-identical replica:
/// the full model spec, the run's LoRA rank and seed, the wire
/// precision, and the pipeline knobs. Sent once, immediately after the
/// connection is established — a `repro dist-worker` process is
/// model-agnostic until this arrives, which is what lets one worker
/// binary serve any aggregator (including one on another host).
#[derive(Clone, Debug)]
pub struct InitMsg {
    /// This worker's id (its accept/connect order at the aggregator).
    pub worker: usize,
    /// The native model family to instantiate.
    pub spec: NativeSpec,
    /// LoRA adapter rank of the run (0 = full fine-tuning).
    pub lora_rank: usize,
    /// Run seed — replicas initialized from `(spec, lora_rank, seed)`
    /// are bitwise identical, the root of the determinism contract.
    pub seed: u64,
    /// Gradient payload precision on the wire.
    pub precision: WirePrecision,
    /// Gradient payload compression under the precision.
    pub compress: WireCompression,
    /// Ring-collective mode: hold per-micro gradients locally (metric-
    /// only Up frames) and exchange them over negotiated worker↔worker
    /// links instead of uploading them to the aggregator.
    pub ring: bool,
    /// Pipeline encode+upload behind the next task's compute.
    pub overlap: bool,
    /// Simulated NIC ms per MiB of encoded gradient (0 = off).
    pub sim_wire_ms_per_mib: f64,
    /// Heartbeat interval the worker must ping at (milliseconds);
    /// 0 disables the heartbeat thread entirely.
    pub heartbeat_ms: u64,
    /// Arm the worker's trace recorder and ship drained batches back
    /// in [`TAG_TRACE`] frames at epoch boundaries.
    pub trace: bool,
    /// The aggregator's trace clock at Init-encode time (µs since its
    /// trace epoch). The worker records its own clock at decode time;
    /// the difference is the offset that maps worker timestamps onto
    /// the aggregator timeline in the merged trace.
    pub clock_anchor_us: u64,
    /// The run's incarnation token (a fingerprint of the run identity,
    /// stable across aggregator restarts). A worker echoes it in every
    /// later [`JoinMsg`] so a restarted aggregator can tell a surviving
    /// replica of *this* run from a stray dialer of some other run.
    pub incarnation: u64,
}

/// One unit of worker compute: run micro-batch `micro` under `masks`.
pub struct MicroJob {
    /// Micro-batch index within the batch (the reduction slot).
    pub micro: usize,
    /// Input tensor `[mb, ...]`.
    pub x: Tensor,
    /// Labels.
    pub y: Vec<i32>,
    /// The schedule's mask pair for this micro-batch.
    pub masks: MaskPair,
}

/// Parsed header of a [`TAG_UP`] frame; the gradient blob is the
/// frame's tail starting at [`UP_GRAD_OFF`] (decoded in place by the
/// codec, no copy).
#[derive(Clone, Copy, Debug)]
pub struct UpHdr {
    /// Micro-batch index the gradient belongs to.
    pub micro: usize,
    /// Micro-batch training loss.
    pub loss: f32,
    /// Correct predictions in the micro-batch.
    pub n_correct: f32,
    /// Measured wall time of the gradient computation (ms).
    pub ms: f64,
    /// The aggregator step the gradient answers (echoed from the
    /// Compute frame) — lets the control plane drop stale gradients
    /// from reassigned or stalled workers.
    pub step: u64,
}

/// Read a frame's message tag without consuming it.
pub fn peek_tag(frame: &[u8]) -> Result<u32> {
    Cursor::new(frame).u32("message tag")
}

/// Encode an [`InitMsg`] (appends to `out`; caller clears).
pub fn encode_init(msg: &InitMsg, out: &mut Vec<u8>) {
    put_u32(out, TAG_INIT);
    put_u32(out, msg.worker as u32);
    let mc = &msg.spec.config;
    for v in [
        mc.img_size, mc.patch, mc.dim, mc.depth, mc.heads, mc.mlp_ratio, mc.classes,
        mc.lora_rank, mc.head_dim, mc.tokens,
    ] {
        put_u32(out, v as u32);
    }
    put_u32(out, msg.spec.micro_batch as u32);
    put_usize_list(out, &msg.spec.mb_variants);
    put_usize_list(out, &msg.spec.lora_ranks);
    put_u32(out, msg.spec.lora_standard_rank as u32);
    put_u64(out, msg.spec.init_seed);
    put_u32(out, msg.spec.threads as u32);
    put_u32(out, msg.lora_rank as u32);
    put_u64(out, msg.seed);
    out.push(match msg.precision {
        WirePrecision::F32 => 0,
        WirePrecision::F16 => 1,
    });
    put_str(out, &msg.compress.label());
    out.push(msg.ring as u8);
    out.push(msg.overlap as u8);
    put_f64(out, msg.sim_wire_ms_per_mib);
    put_u64(out, msg.heartbeat_ms);
    out.push(msg.trace as u8);
    put_u64(out, msg.clock_anchor_us);
    put_u64(out, msg.incarnation);
}

/// Decode an [`InitMsg`] frame.
pub fn decode_init(frame: &[u8]) -> Result<InitMsg> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("init tag")?;
    anyhow::ensure!(tag == TAG_INIT, "expected Init frame, got tag {tag:#x}");
    let worker = c.u32("worker id")? as usize;
    let mut mc = [0usize; 10];
    for slot in mc.iter_mut() {
        *slot = c.u32("model config")? as usize;
    }
    let config = ModelConfig {
        img_size: mc[0],
        patch: mc[1],
        dim: mc[2],
        depth: mc[3],
        heads: mc[4],
        mlp_ratio: mc[5],
        classes: mc[6],
        lora_rank: mc[7],
        head_dim: mc[8],
        tokens: mc[9],
    };
    let micro_batch = c.u32("micro batch")? as usize;
    let mb_variants = get_usize_list(&mut c, "mb variants")?;
    let lora_ranks = get_usize_list(&mut c, "lora ranks")?;
    let lora_standard_rank = c.u32("lora standard rank")? as usize;
    let init_seed = c.u64("init seed")?;
    let threads = c.u32("threads")? as usize;
    let spec = NativeSpec {
        config,
        micro_batch,
        mb_variants,
        lora_ranks,
        lora_standard_rank,
        init_seed,
        threads,
    };
    let lora_rank = c.u32("lora rank")? as usize;
    let seed = c.u64("run seed")?;
    let precision = match c.u8("wire precision")? {
        0 => WirePrecision::F32,
        1 => WirePrecision::F16,
        p => anyhow::bail!("unknown wire precision code {p} in Init frame"),
    };
    let compress = WireCompression::parse(&get_str(&mut c, "wire compression")?)?;
    let ring = c.u8("ring flag")? != 0;
    let overlap = c.u8("overlap flag")? != 0;
    let sim_wire_ms_per_mib = c.f64("sim wire ms")?;
    let heartbeat_ms = c.u64("heartbeat interval")?;
    let trace = c.u8("trace flag")? != 0;
    let clock_anchor_us = c.u64("trace clock anchor")?;
    let incarnation = c.u64("incarnation token")?;
    Ok(InitMsg {
        worker,
        spec,
        lora_rank,
        seed,
        precision,
        compress,
        ring,
        overlap,
        sim_wire_ms_per_mib,
        heartbeat_ms,
        trace,
        clock_anchor_us,
        incarnation,
    })
}

/// Encode a [`TAG_COMPUTE`] frame (appends to `out`). `step` is the
/// aggregator's batch step, echoed back in every [`TAG_UP`] answer so
/// stale gradients are identifiable after a reassignment.
pub fn encode_compute(step: u64, jobs: &[MicroJob], out: &mut Vec<u8>) {
    put_u32(out, TAG_COMPUTE);
    put_u64(out, step);
    put_u32(out, jobs.len() as u32);
    for job in jobs {
        put_u32(out, job.micro as u32);
        put_u32(out, job.y.len() as u32);
        for &v in &job.y {
            put_u32(out, v as u32);
        }
        put_tensor(out, &job.x);
        put_masks(out, &job.masks);
    }
}

/// Decode a [`TAG_COMPUTE`] frame into `(step, owned jobs)`.
pub fn decode_compute(frame: &[u8]) -> Result<(u64, Vec<MicroJob>)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("compute tag")?;
    anyhow::ensure!(tag == TAG_COMPUTE, "expected Compute frame, got tag {tag:#x}");
    let step = c.u64("compute step")?;
    let n = c.count(4, "compute job count")?;
    let mut jobs = Vec::with_capacity(n);
    for _ in 0..n {
        let micro = c.u32("micro index")? as usize;
        let ny = c.count(4, "label count")?;
        let mut y = Vec::with_capacity(ny);
        for _ in 0..ny {
            y.push(c.u32("label")? as i32);
        }
        let x = get_tensor(&mut c, "input tensor")?;
        let masks = get_masks(&mut c, "micro masks")?;
        jobs.push(MicroJob { micro, x, y, masks });
    }
    Ok((step, jobs))
}

/// Encode a [`TAG_APPLY`] frame: the learning rate, the batch's union
/// mask, and the reduced-gradient blob (codec wire format, verbatim) as
/// the tail. Returns the blob's offset within the frame.
pub fn encode_apply(lr: f32, union: &MaskPair, grad: &[u8], out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_APPLY);
    put_f32(out, lr);
    put_masks(out, union);
    let off = out.len();
    out.extend_from_slice(grad);
    off
}

/// Decode a [`TAG_APPLY`] frame: `(lr, union mask, grad blob offset)`.
/// The gradient tail at the returned offset is codec wire format.
pub fn decode_apply(frame: &[u8]) -> Result<(f32, MaskPair, usize)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("apply tag")?;
    anyhow::ensure!(tag == TAG_APPLY, "expected Apply frame, got tag {tag:#x}");
    let lr = c.f32("learning rate")?;
    let union = get_masks(&mut c, "union masks")?;
    Ok((lr, union, c.offset()))
}

/// Encode a [`TAG_DELTAS`] frame header; the caller appends the dense
/// delta payload (codec wire format). Returns the payload offset (4).
pub fn encode_deltas_header(out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_DELTAS);
    out.len()
}

/// Payload offset of a [`TAG_DELTAS`] frame after tag validation.
pub fn decode_deltas(frame: &[u8]) -> Result<usize> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("deltas tag")?;
    anyhow::ensure!(tag == TAG_DELTAS, "expected Deltas frame, got tag {tag:#x}");
    Ok(c.offset())
}

/// Encode a bare control frame ([`TAG_RESET`] / [`TAG_SHUTDOWN`]).
pub fn encode_ctrl(tag: u32, out: &mut Vec<u8>) {
    put_u32(out, tag);
}

/// Encode a [`TAG_UP`] frame header; the caller appends the gradient
/// blob at [`UP_GRAD_OFF`] via `GradCodec::encode_append`.
pub fn encode_up_header(hdr: &UpHdr, out: &mut Vec<u8>) {
    put_u32(out, TAG_UP);
    put_u32(out, hdr.micro as u32);
    put_f32(out, hdr.loss);
    put_f32(out, hdr.n_correct);
    put_f64(out, hdr.ms);
    put_u64(out, hdr.step);
    debug_assert_eq!(out.len(), UP_GRAD_OFF, "Up header layout drifted");
}

/// Decode a [`TAG_UP`] frame header (the gradient tail starts at
/// [`UP_GRAD_OFF`]).
pub fn decode_up(frame: &[u8]) -> Result<UpHdr> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("up tag")?;
    anyhow::ensure!(tag == TAG_UP, "expected Up frame, got tag {tag:#x}");
    let micro = c.u32("up micro")? as usize;
    let loss = c.f32("up loss")?;
    let n_correct = c.f32("up n_correct")?;
    let ms = c.f64("up ms")?;
    let step = c.u64("up step")?;
    // Ring mode holds gradients locally and sends metric-only Up
    // frames (exactly the header); star mode requires the tail, which
    // the aggregator enforces when it reduces.
    anyhow::ensure!(
        frame.len() >= UP_GRAD_OFF,
        "Up frame shorter than its header ({} bytes)",
        frame.len()
    );
    Ok(UpHdr { micro, loss, n_correct, ms, step })
}

/// A worker's exit report, carried in its [`TAG_BYE`] frame: local
/// encode-buffer pool counters plus the bytes its ring links moved
/// (zero outside ring mode) — the aggregator folds these into
/// [`super::trainer::DistReport`] so per-node traffic stays measurable
/// when gradients no longer pass through the star.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ByeMsg {
    /// Encode buffers the worker allocated fresh.
    pub fresh: u64,
    /// Checkouts served by recycling.
    pub reused: u64,
    /// Bytes sent over this worker's ring links.
    pub ring_sent: u64,
    /// Bytes received over this worker's ring links.
    pub ring_recv: u64,
}

/// Encode a [`TAG_BYE`] frame with the worker's exit report.
pub fn encode_bye(msg: &ByeMsg, out: &mut Vec<u8>) {
    put_u32(out, TAG_BYE);
    put_u64(out, msg.fresh);
    put_u64(out, msg.reused);
    put_u64(out, msg.ring_sent);
    put_u64(out, msg.ring_recv);
}

/// Decode a [`TAG_BYE`] frame.
pub fn decode_bye(frame: &[u8]) -> Result<ByeMsg> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("bye tag")?;
    anyhow::ensure!(tag == TAG_BYE, "expected Bye frame, got tag {tag:#x}");
    Ok(ByeMsg {
        fresh: c.u64("bye fresh")?,
        reused: c.u64("bye reused")?,
        ring_sent: c.u64("bye ring sent")?,
        ring_recv: c.u64("bye ring recv")?,
    })
}

// ---------------------------------------------------------------------------
// Observability frames: drained trace batches
// ---------------------------------------------------------------------------

/// A worker's drained trace batch, carried in a [`TAG_TRACE`] frame.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMsg {
    /// Sending worker's id.
    pub worker: usize,
    /// Signed clock offset (µs) that maps the sender's timestamps onto
    /// the aggregator timeline (`aggregator_anchor - local_anchor`,
    /// both taken at the Init handshake).
    pub offset_us: i64,
    /// Events the sender's rings overwrote since its previous drain.
    pub truncated: u64,
    /// The drained events, sender-local timestamps.
    pub events: Vec<crate::obs::trace::WireEvent>,
}

/// Smallest possible encoded trace event (empty name and category):
/// two string lengths + kind byte + timestamp + payload + tid + lane.
const TRACE_EVENT_MIN_BYTES: usize = 4 + 4 + 1 + 8 + 8 + 4 + 4;

/// Encode a [`TAG_TRACE`] frame from locally drained events.
pub fn encode_trace(
    worker: usize,
    offset_us: i64,
    truncated: u64,
    events: &[crate::obs::trace::Event],
    out: &mut Vec<u8>,
) {
    use crate::obs::trace::EventKind;
    put_u32(out, TAG_TRACE);
    put_u32(out, worker as u32);
    put_u64(out, offset_us as u64);
    put_u64(out, truncated);
    put_u32(out, events.len() as u32);
    for e in events {
        put_str(out, e.name);
        put_str(out, e.cat);
        let (kind, payload) = match e.kind {
            EventKind::Span { dur_us } => (0u8, dur_us),
            EventKind::Instant => (1, 0),
            EventKind::Counter { value } => (2, value.to_bits()),
        };
        out.push(kind);
        put_u64(out, e.ts_us);
        put_u64(out, payload);
        put_u32(out, e.tid);
        put_u32(out, e.lane);
    }
}

/// Decode a [`TAG_TRACE`] frame.
pub fn decode_trace(frame: &[u8]) -> Result<TraceMsg> {
    use crate::obs::trace::{EventKind, WireEvent};
    let mut c = Cursor::new(frame);
    let tag = c.u32("trace tag")?;
    anyhow::ensure!(tag == TAG_TRACE, "expected Trace frame, got tag {tag:#x}");
    let worker = c.u32("trace worker")? as usize;
    let offset_us = c.u64("trace clock offset")? as i64;
    let truncated = c.u64("trace truncation count")?;
    let n = c.count(TRACE_EVENT_MIN_BYTES, "trace event count")?;
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        let name = get_str(&mut c, "trace event name")?;
        let cat = get_str(&mut c, "trace event category")?;
        let kind_code = c.u8("trace event kind")?;
        let ts_us = c.u64("trace event ts")?;
        let payload = c.u64("trace event payload")?;
        let tid = c.u32("trace event tid")?;
        let lane = c.u32("trace event lane")?;
        let kind = match kind_code {
            0 => EventKind::Span { dur_us: payload },
            1 => EventKind::Instant,
            2 => EventKind::Counter { value: f64::from_bits(payload) },
            k => anyhow::bail!("unknown trace event kind {k}"),
        };
        events.push(WireEvent { name, cat, kind, ts_us, tid, lane });
    }
    Ok(TraceMsg { worker, offset_us, truncated, events })
}

// ---------------------------------------------------------------------------
// Control-plane frames: heartbeat + membership + state transfer
// ---------------------------------------------------------------------------

/// Encode a [`TAG_PING`] heartbeat with a monotonic sequence number.
pub fn encode_ping(seq: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_PING);
    put_u64(out, seq);
}

/// Decode a [`TAG_PING`] frame: the sequence number. Trailing bytes
/// are rejected — a heartbeat is exactly 12 bytes.
pub fn decode_ping(frame: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ping tag")?;
    anyhow::ensure!(tag == TAG_PING, "expected Ping frame, got tag {tag:#x}");
    let seq = c.u64("ping seq")?;
    anyhow::ensure!(
        c.remaining() == 0,
        "oversized Ping frame: {} trailing bytes after the sequence number",
        c.remaining()
    );
    Ok(seq)
}

/// Encode a [`TAG_PONG`] heartbeat acknowledgment.
pub fn encode_pong(seq: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_PONG);
    put_u64(out, seq);
}

/// Decode a [`TAG_PONG`] frame: the echoed sequence number.
pub fn decode_pong(frame: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("pong tag")?;
    anyhow::ensure!(tag == TAG_PONG, "expected Pong frame, got tag {tag:#x}");
    let seq = c.u64("pong seq")?;
    anyhow::ensure!(
        c.remaining() == 0,
        "oversized Pong frame: {} trailing bytes after the sequence number",
        c.remaining()
    );
    Ok(seq)
}

/// A worker's membership request, carried in [`TAG_JOIN`]. A fresh
/// worker sends `incarnation = 0`, `worker = u32::MAX`, `last_step =
/// 0`; a worker redialing after a link drop or an aggregator restart
/// echoes the incarnation token and worker id from its last Init (and
/// the last step it answered), which is how the control plane tells a
/// reconnect from a first connect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JoinMsg {
    /// The worker's [`PROTO_VERSION`].
    pub version: u32,
    /// Incarnation token from the last Init (0 = never initialized).
    pub incarnation: u64,
    /// Worker id from the last Init ([`u32::MAX`] = fresh).
    pub worker: u32,
    /// Last aggregator step this worker answered (0 = none).
    pub last_step: u64,
}

impl JoinMsg {
    /// A first-connect Join from a worker with no prior identity.
    pub fn fresh(version: u32) -> JoinMsg {
        JoinMsg { version, incarnation: 0, worker: u32::MAX, last_step: 0 }
    }
}

/// Encode a [`TAG_JOIN`] membership request.
pub fn encode_join(msg: &JoinMsg, out: &mut Vec<u8>) {
    put_u32(out, TAG_JOIN);
    put_u32(out, msg.version);
    put_u64(out, msg.incarnation);
    put_u32(out, msg.worker);
    put_u64(out, msg.last_step);
}

/// Decode a [`TAG_JOIN`] frame. A short pre-v5 Join (tag + version
/// only) still decodes — as a fresh join — so the version-mismatch
/// rejection downstream stays descriptive instead of a truncation
/// error.
pub fn decode_join(frame: &[u8]) -> Result<JoinMsg> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("join tag")?;
    anyhow::ensure!(tag == TAG_JOIN, "expected Join frame, got tag {tag:#x}");
    let version = c.u32("join protocol version")?;
    if c.remaining() == 0 {
        return Ok(JoinMsg::fresh(version));
    }
    let incarnation = c.u64("join incarnation")?;
    let worker = c.u32("join worker id")?;
    let last_step = c.u64("join last step")?;
    anyhow::ensure!(
        c.remaining() == 0,
        "oversized Join frame: {} trailing bytes after the last step",
        c.remaining()
    );
    Ok(JoinMsg { version, incarnation, worker, last_step })
}

/// Encode a [`TAG_NACK`] resend request naming the corrupt frame's
/// expected step.
pub fn encode_nack(step: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_NACK);
    put_u64(out, step);
}

/// Decode a [`TAG_NACK`] frame: the step whose frame arrived corrupt.
pub fn decode_nack(frame: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("nack tag")?;
    anyhow::ensure!(tag == TAG_NACK, "expected Nack frame, got tag {tag:#x}");
    let step = c.u64("nack step")?;
    anyhow::ensure!(
        c.remaining() == 0,
        "oversized Nack frame: {} trailing bytes after the step",
        c.remaining()
    );
    Ok(step)
}

/// Encode a [`TAG_EVICT`] notice naming the evicted worker.
pub fn encode_evict(worker: usize, out: &mut Vec<u8>) {
    put_u32(out, TAG_EVICT);
    put_u32(out, worker as u32);
}

/// Decode a [`TAG_EVICT`] frame: the evicted worker's id.
pub fn decode_evict(frame: &[u8]) -> Result<usize> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("evict tag")?;
    anyhow::ensure!(tag == TAG_EVICT, "expected Evict frame, got tag {tag:#x}");
    Ok(c.u32("evict worker id")? as usize)
}

/// Encode a [`TAG_STATE`] frame: the aggregator's flattened parameter
/// and momentum vectors, bit-exact.
pub fn encode_state(params: &[f32], momentum: &[f32], out: &mut Vec<u8>) {
    put_u32(out, TAG_STATE);
    put_u64(out, params.len() as u64);
    for &v in params {
        put_f32(out, v);
    }
    put_u64(out, momentum.len() as u64);
    for &v in momentum {
        put_f32(out, v);
    }
}

/// Decode a [`TAG_STATE`] frame: `(params, momentum)`, bit-exact.
pub fn decode_state(frame: &[u8]) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("state tag")?;
    anyhow::ensure!(tag == TAG_STATE, "expected State frame, got tag {tag:#x}");
    let read_vec = |c: &mut Cursor<'_>, what: &str| -> Result<Vec<f32>> {
        let n = c.u64(what)? as usize;
        anyhow::ensure!(
            n.saturating_mul(4) <= c.remaining(),
            "corrupt count: {what} claims {n} f32s but only {} bytes remain",
            c.remaining()
        );
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(c.f32(what)?);
        }
        Ok(v)
    };
    let params = read_vec(&mut c, "state params")?;
    let momentum = read_vec(&mut c, "state momentum")?;
    Ok((params, momentum))
}

// ---------------------------------------------------------------------------
// Tenant-tagged job frames: the multi-tenant serve layer's hot-swap wire
// ---------------------------------------------------------------------------

fn put_blob(out: &mut Vec<u8>, b: &[u8]) {
    put_u64(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn get_blob(c: &mut Cursor<'_>, what: &str) -> Result<Vec<u8>> {
    let n = c.u64(what)? as usize;
    anyhow::ensure!(
        n <= c.remaining(),
        "corrupt count: {what} claims {n} bytes but only {} remain",
        c.remaining()
    );
    Ok(c.take(n, what)?.to_vec())
}

fn put_mask_list(out: &mut Vec<u8>, masks: &[MaskPair]) {
    put_u32(out, masks.len() as u32);
    for m in masks {
        put_masks(out, m);
    }
}

fn get_mask_list(c: &mut Cursor<'_>, what: &str) -> Result<Vec<MaskPair>> {
    let n = c.count(8, what)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(get_masks(c, what)?);
    }
    Ok(out)
}

/// One admitted round of a tenant job, server → replica: which job,
/// which adapter state to install (a `GradCodec` dense blob — frozen
/// base parameters never ride this frame), the per-micro mask schedule,
/// and the batch range to run. A `fresh` round carries no state: the
/// replica starts from its pristine trainable snapshot, runs the spec's
/// synthetic pretraining, and *solves* the mask schedule (probe →
/// scores → scheduler), returning it in the [`JobDoneMsg`].
#[derive(Clone, Debug)]
pub struct JobRoundMsg {
    /// Service-assigned job id (the tenant tag every serve frame carries).
    pub job_id: u64,
    /// Tenant identity, for per-link accounting at the replica.
    pub tenant: String,
    /// LoRA rank the replica must open (picks its per-rank backend).
    pub lora_rank: usize,
    /// First round of the job: start from pristine state, pretrain,
    /// and solve the schedule instead of installing shipped state.
    pub fresh: bool,
    /// Run the job's final evaluation after this round's batches.
    pub finalize: bool,
    /// Global fine-tuning batch index this round starts at.
    pub start_batch: usize,
    /// Fine-tuning batches to run this round (0 is legal on a fresh
    /// round: pretrain + schedule-solve only).
    pub n_batches: usize,
    /// The job's serialized `JobSpec` (dataset, sizes, seed, lr,
    /// budget, scheduler) — the replica reconstructs data and schedule
    /// deterministically from it.
    pub spec_json: String,
    /// Per-micro mask schedule (empty on a fresh round; fixed for the
    /// job's lifetime afterwards — the paper's select-once policy).
    pub masks: Vec<MaskPair>,
    /// Trainable parameter state (`GradCodec` dense blob; empty on fresh).
    pub params: Vec<u8>,
    /// Trainable momentum state (same encoding; empty on fresh).
    pub momentum: Vec<u8>,
}

/// Round outcome, replica → server: the trained adapter state coming
/// back, the solved mask schedule (fresh rounds), per-batch step
/// latencies, and the loss/accuracy samples the per-job report meters.
#[derive(Clone, Debug)]
pub struct JobDoneMsg {
    /// Echoed job id.
    pub job_id: u64,
    /// Whether the round executed; on `false`, `error` says why and
    /// the state blobs are empty.
    pub ok: bool,
    /// Failure description (empty when `ok`).
    pub error: String,
    /// Fine-tuning batches completed this round.
    pub batches_done: usize,
    /// Per-micro training losses in execution order.
    pub losses: Vec<f32>,
    /// Correct predictions over this round's training micro-batches.
    pub n_correct: u64,
    /// Examples seen over this round's training micro-batches.
    pub n_seen: u64,
    /// Measured wall time of each fine-tuning batch (ms).
    pub step_ms: Vec<f64>,
    /// The job's mask schedule (populated on fresh rounds where the
    /// replica solved it; echoed empty otherwise).
    pub masks: Vec<MaskPair>,
    /// Trained adapter parameter state (`GradCodec` dense blob).
    pub params: Vec<u8>,
    /// Trained adapter momentum state (same encoding).
    pub momentum: Vec<u8>,
    /// Full-model state baseline in bytes (params + momentum, f32) —
    /// what a non-LoRA tenant swap would have shipped; the metering
    /// denominator for the adapter-savings claim.
    pub dense_state_bytes: u64,
    /// Test top-1 after a `finalize` round (-1.0 otherwise).
    pub test_top1: f64,
    /// Test loss after a `finalize` round (-1.0 otherwise).
    pub test_loss: f64,
}

/// Encode a [`JobRoundMsg`] (appends to `out`; caller clears).
pub fn encode_job_round(msg: &JobRoundMsg, out: &mut Vec<u8>) {
    put_u32(out, TAG_JOB_ROUND);
    put_u64(out, msg.job_id);
    put_str(out, &msg.tenant);
    put_u32(out, msg.lora_rank as u32);
    out.push(msg.fresh as u8);
    out.push(msg.finalize as u8);
    put_u32(out, msg.start_batch as u32);
    put_u32(out, msg.n_batches as u32);
    put_str(out, &msg.spec_json);
    put_mask_list(out, &msg.masks);
    put_blob(out, &msg.params);
    put_blob(out, &msg.momentum);
}

/// Decode a [`TAG_JOB_ROUND`] frame.
pub fn decode_job_round(frame: &[u8]) -> Result<JobRoundMsg> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("job-round tag")?;
    anyhow::ensure!(tag == TAG_JOB_ROUND, "expected JobRound frame, got tag {tag:#x}");
    Ok(JobRoundMsg {
        job_id: c.u64("job id")?,
        tenant: get_str(&mut c, "job tenant")?,
        lora_rank: c.u32("job lora rank")? as usize,
        fresh: c.u8("job fresh flag")? != 0,
        finalize: c.u8("job finalize flag")? != 0,
        start_batch: c.u32("job start batch")? as usize,
        n_batches: c.u32("job n_batches")? as usize,
        spec_json: get_str(&mut c, "job spec")?,
        masks: get_mask_list(&mut c, "job masks")?,
        params: get_blob(&mut c, "job params")?,
        momentum: get_blob(&mut c, "job momentum")?,
    })
}

/// Encode a [`JobDoneMsg`] (appends to `out`; caller clears).
pub fn encode_job_done(msg: &JobDoneMsg, out: &mut Vec<u8>) {
    put_u32(out, TAG_JOB_DONE);
    put_u64(out, msg.job_id);
    out.push(msg.ok as u8);
    put_str(out, &msg.error);
    put_u32(out, msg.batches_done as u32);
    put_u32(out, msg.losses.len() as u32);
    for &l in &msg.losses {
        put_f32(out, l);
    }
    put_u64(out, msg.n_correct);
    put_u64(out, msg.n_seen);
    put_u32(out, msg.step_ms.len() as u32);
    for &ms in &msg.step_ms {
        put_f64(out, ms);
    }
    put_mask_list(out, &msg.masks);
    put_blob(out, &msg.params);
    put_blob(out, &msg.momentum);
    put_u64(out, msg.dense_state_bytes);
    put_f64(out, msg.test_top1);
    put_f64(out, msg.test_loss);
}

/// Decode a [`TAG_JOB_DONE`] frame.
pub fn decode_job_done(frame: &[u8]) -> Result<JobDoneMsg> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("job-done tag")?;
    anyhow::ensure!(tag == TAG_JOB_DONE, "expected JobDone frame, got tag {tag:#x}");
    let job_id = c.u64("job id")?;
    let ok = c.u8("job ok flag")? != 0;
    let error = get_str(&mut c, "job error")?;
    let batches_done = c.u32("job batches done")? as usize;
    let n_losses = c.count(4, "job losses")?;
    let mut losses = Vec::with_capacity(n_losses);
    for _ in 0..n_losses {
        losses.push(c.f32("job loss")?);
    }
    let n_correct = c.u64("job n_correct")?;
    let n_seen = c.u64("job n_seen")?;
    let n_ms = c.count(8, "job step times")?;
    let mut step_ms = Vec::with_capacity(n_ms);
    for _ in 0..n_ms {
        step_ms.push(c.f64("job step ms")?);
    }
    Ok(JobDoneMsg {
        job_id,
        ok,
        error,
        batches_done,
        losses,
        n_correct,
        n_seen,
        step_ms,
        masks: get_mask_list(&mut c, "job masks")?,
        params: get_blob(&mut c, "job params")?,
        momentum: get_blob(&mut c, "job momentum")?,
        dense_state_bytes: c.u64("job dense state bytes")?,
        test_top1: c.f64("job test top1")?,
        test_loss: c.f64("job test loss")?,
    })
}

// ---------------------------------------------------------------------------
// Ring-collective frames: link negotiation + exchange
// ---------------------------------------------------------------------------

/// A worker's part in the distribute leg of one ring exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CastRole {
    /// Wait for a [`TAG_RING_CAST`] on the predecessor link; apply and
    /// forward while its hop count is positive.
    Member,
    /// Wait for a [`TAG_RING_CASTD`] from the aggregator (hierarchical
    /// group leader); apply and originate an intra-group cast.
    Leader {
        /// Forward hops the leader's cast starts with (group size - 1).
        hops: u32,
    },
    /// Already holds the final bytes (the plain ring's chain tail):
    /// apply locally and originate the cast around the wrap link.
    Origin {
        /// Forward hops the cast starts with (K - 1; 0 when K = 1).
        hops: u32,
    },
}

/// One worker's marching orders for a ring exchange, carried in
/// [`TAG_RING_EXEC`]. The aggregator derives every role centrally so a
/// worker never needs to know the topology — only what *it* must do.
#[derive(Clone, Debug)]
pub struct RingExec {
    /// Aggregator batch step (stale-exchange guard, echoed in every
    /// ring-link blob).
    pub step: u64,
    /// Learning rate of the update every replica applies.
    pub lr: f32,
    /// Total micro-batches in the batch (the `1/n` gradient scale).
    pub n_micros: u32,
    /// Receive a [`TAG_RING_PART`] from the predecessor before adding
    /// own micros (false for the chain head, which starts from zeros).
    pub has_in: bool,
    /// Send the finished chain sum to the aggregator as
    /// [`TAG_RING_FINAL`] (true for the chain tail).
    pub is_last: bool,
    /// Distribute-leg role.
    pub cast: CastRole,
    /// The batch's union mask — every ring-link payload is encoded
    /// under it.
    pub union: MaskPair,
}

/// Encode a [`TAG_RING_LISTEN`] frame (`tcp`: open a TCP listener,
/// else an in-process channel rendezvous). `nonce` identifies this
/// negotiation round; the worker echoes it in its [`TAG_RING_ADDR`]
/// reply so the aggregator can discard addresses from an aborted
/// round (whose listeners are already closed).
pub fn encode_ring_listen(tcp: bool, nonce: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_LISTEN);
    put_u64(out, nonce);
    out.push(tcp as u8);
}

/// Decode a [`TAG_RING_LISTEN`] frame: `(tcp, nonce)`.
pub fn decode_ring_listen(frame: &[u8]) -> Result<(bool, u64)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-listen tag")?;
    anyhow::ensure!(tag == TAG_RING_LISTEN, "expected RingListen frame, got tag {tag:#x}");
    let nonce = c.u64("ring-listen nonce")?;
    Ok((c.u8("ring-listen mode")? != 0, nonce))
}

/// Encode a [`TAG_RING_ADDR`] frame carrying the worker's listener
/// address, stamped with the negotiation nonce it answers.
pub fn encode_ring_addr(nonce: u64, addr: &str, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_ADDR);
    put_u64(out, nonce);
    put_str(out, addr);
}

/// Decode a [`TAG_RING_ADDR`] frame: `(nonce, listener address)`.
pub fn decode_ring_addr(frame: &[u8]) -> Result<(u64, String)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-addr tag")?;
    anyhow::ensure!(tag == TAG_RING_ADDR, "expected RingAddr frame, got tag {tag:#x}");
    let nonce = c.u64("ring-addr nonce")?;
    Ok((nonce, get_str(&mut c, "ring-addr address")?))
}

/// Encode a [`TAG_RING_PEERS`] frame: the successor to connect to
/// (empty = none) and whether a predecessor will dial in. The nonce is
/// echoed in the worker's [`TAG_RING_READY`] confirmation.
pub fn encode_ring_peers(nonce: u64, succ_addr: &str, accept: bool, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_PEERS);
    put_u64(out, nonce);
    put_str(out, succ_addr);
    out.push(accept as u8);
}

/// Decode a [`TAG_RING_PEERS`] frame: `(nonce, successor, accept)`.
pub fn decode_ring_peers(frame: &[u8]) -> Result<(u64, String, bool)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-peers tag")?;
    anyhow::ensure!(tag == TAG_RING_PEERS, "expected RingPeers frame, got tag {tag:#x}");
    let nonce = c.u64("ring-peers nonce")?;
    let addr = get_str(&mut c, "ring-peers successor")?;
    let accept = c.u8("ring-peers accept flag")? != 0;
    Ok((nonce, addr, accept))
}

/// Encode a [`TAG_RING_READY`] acknowledgment. `seq` names what is
/// being acknowledged — the negotiation nonce for link setup, the batch
/// step for an applied update — so stale acks from an aborted attempt
/// can never satisfy a later barrier.
pub fn encode_ring_ready(seq: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_READY);
    put_u64(out, seq);
}

/// Decode a [`TAG_RING_READY`] frame: the acknowledged sequence value.
pub fn decode_ring_ready(frame: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-ready tag")?;
    anyhow::ensure!(tag == TAG_RING_READY, "expected RingReady frame, got tag {tag:#x}");
    c.u64("ring-ready seq")
}

/// Encode a [`TAG_RING_EXEC`] frame.
pub fn encode_ring_exec(msg: &RingExec, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_EXEC);
    put_u64(out, msg.step);
    put_f32(out, msg.lr);
    put_u32(out, msg.n_micros);
    out.push(msg.has_in as u8);
    out.push(msg.is_last as u8);
    let (role, hops) = match msg.cast {
        CastRole::Member => (0u8, 0u32),
        CastRole::Leader { hops } => (1, hops),
        CastRole::Origin { hops } => (2, hops),
    };
    out.push(role);
    put_u32(out, hops);
    put_masks(out, &msg.union);
}

/// Decode a [`TAG_RING_EXEC`] frame.
pub fn decode_ring_exec(frame: &[u8]) -> Result<RingExec> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-exec tag")?;
    anyhow::ensure!(tag == TAG_RING_EXEC, "expected RingExec frame, got tag {tag:#x}");
    let step = c.u64("ring-exec step")?;
    let lr = c.f32("ring-exec lr")?;
    let n_micros = c.u32("ring-exec micro count")?;
    anyhow::ensure!(n_micros > 0, "ring-exec with zero micro-batches");
    let has_in = c.u8("ring-exec has-in flag")? != 0;
    let is_last = c.u8("ring-exec is-last flag")? != 0;
    let role = c.u8("ring-exec cast role")?;
    let hops = c.u32("ring-exec cast hops")?;
    let cast = match role {
        0 => CastRole::Member,
        1 => CastRole::Leader { hops },
        2 => CastRole::Origin { hops },
        r => anyhow::bail!("unknown ring-exec cast role {r}"),
    };
    let union = get_masks(&mut c, "ring-exec union masks")?;
    Ok(RingExec { step, lr, n_micros, has_in, is_last, cast, union })
}

/// Encode a [`TAG_RING_RESET`] frame naming the abandoned step.
pub fn encode_ring_reset(step: u64, out: &mut Vec<u8>) {
    put_u32(out, TAG_RING_RESET);
    put_u64(out, step);
}

/// Decode a [`TAG_RING_RESET`] frame: the abandoned step.
pub fn decode_ring_reset(frame: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-reset tag")?;
    anyhow::ensure!(tag == TAG_RING_RESET, "expected RingReset frame, got tag {tag:#x}");
    c.u64("ring-reset step")
}

/// Encode a [`TAG_RING_FINAL`] header; the caller appends the final
/// gradient blob. Returns the blob's offset (12).
pub fn encode_ring_final_header(step: u64, out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_RING_FINAL);
    put_u64(out, step);
    out.len()
}

/// Decode a [`TAG_RING_FINAL`] frame: `(step, grad blob offset)`.
pub fn decode_ring_final(frame: &[u8]) -> Result<(u64, usize)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-final tag")?;
    anyhow::ensure!(tag == TAG_RING_FINAL, "expected RingFinal frame, got tag {tag:#x}");
    let step = c.u64("ring-final step")?;
    Ok((step, c.offset()))
}

/// Encode a [`TAG_RING_CASTD`] header (aggregator → leader distribute);
/// the caller appends the final gradient blob. Returns the blob offset.
pub fn encode_ring_castd_header(step: u64, hops: u32, out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_RING_CASTD);
    put_u64(out, step);
    put_u32(out, hops);
    out.len()
}

/// Decode a [`TAG_RING_CASTD`] frame: `(step, hops, grad offset)`.
pub fn decode_ring_castd(frame: &[u8]) -> Result<(u64, u32, usize)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-castd tag")?;
    anyhow::ensure!(tag == TAG_RING_CASTD, "expected RingCastDown frame, got tag {tag:#x}");
    let step = c.u64("ring-castd step")?;
    let hops = c.u32("ring-castd hops")?;
    Ok((step, hops, c.offset()))
}

/// Encode a worker↔worker [`TAG_RING_PART`] blob header (partial chain
/// sum); the caller appends the gradient payload. Returns the offset.
pub fn encode_ring_part_header(step: u64, out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_RING_PART);
    put_u64(out, step);
    out.len()
}

/// Decode a [`TAG_RING_PART`] blob: `(step, grad offset)`.
pub fn decode_ring_part(frame: &[u8]) -> Result<(u64, usize)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-part tag")?;
    anyhow::ensure!(tag == TAG_RING_PART, "expected RingPart blob, got tag {tag:#x}");
    let step = c.u64("ring-part step")?;
    Ok((step, c.offset()))
}

/// Encode a worker↔worker [`TAG_RING_CAST`] blob header (distribute);
/// the caller appends the gradient payload. Returns the offset.
pub fn encode_ring_cast_header(step: u64, hops: u32, out: &mut Vec<u8>) -> usize {
    put_u32(out, TAG_RING_CAST);
    put_u64(out, step);
    put_u32(out, hops);
    out.len()
}

/// Decode a [`TAG_RING_CAST`] blob: `(step, hops, grad offset)`.
pub fn decode_ring_cast(frame: &[u8]) -> Result<(u64, u32, usize)> {
    let mut c = Cursor::new(frame);
    let tag = c.u32("ring-cast tag")?;
    anyhow::ensure!(tag == TAG_RING_CAST, "expected RingCast blob, got tag {tag:#x}");
    let step = c.u64("ring-cast step")?;
    let hops = c.u32("ring-cast hops")?;
    Ok((step, hops, c.offset()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masks(depth: usize, heads: usize) -> MaskPair {
        let mut m = MaskPair::ones(depth, heads);
        m.bwd.set(&[0, 1], 0.0);
        m
    }

    #[test]
    fn init_round_trips_exactly() {
        let mut spec = NativeSpec::tiny();
        spec.threads = 3;
        let msg = InitMsg {
            worker: 2,
            spec,
            lora_rank: 4,
            seed: 0xDEAD_BEEF_u64,
            precision: WirePrecision::F16,
            compress: WireCompression::TopK { pct: 25 },
            ring: true,
            overlap: false,
            sim_wire_ms_per_mib: 2.25,
            heartbeat_ms: 750,
            trace: true,
            clock_anchor_us: 123_456_789,
            incarnation: 0xFEED_F00D_u64,
        };
        let mut frame = Vec::new();
        encode_init(&msg, &mut frame);
        assert_eq!(peek_tag(&frame).unwrap(), TAG_INIT);
        let back = decode_init(&frame).unwrap();
        assert_eq!(back.compress, WireCompression::TopK { pct: 25 });
        assert!(back.ring);
        assert_eq!(back.worker, 2);
        assert_eq!(back.spec.config.dim, msg.spec.config.dim);
        assert_eq!(back.spec.config.tokens, msg.spec.config.tokens);
        assert_eq!(back.spec.mb_variants, msg.spec.mb_variants);
        assert_eq!(back.spec.lora_ranks, msg.spec.lora_ranks);
        assert_eq!(back.spec.init_seed, msg.spec.init_seed);
        assert_eq!(back.spec.threads, 3);
        assert_eq!(back.lora_rank, 4);
        assert_eq!(back.seed, 0xDEAD_BEEF_u64);
        assert_eq!(back.precision, WirePrecision::F16);
        assert!(!back.overlap);
        assert_eq!(back.sim_wire_ms_per_mib, 2.25);
        assert_eq!(back.heartbeat_ms, 750);
        assert!(back.trace);
        assert_eq!(back.clock_anchor_us, 123_456_789);
        assert_eq!(back.incarnation, 0xFEED_F00D_u64);
    }

    #[test]
    fn compute_round_trips_tensors_and_masks_bitwise() {
        let x = Tensor::from_vec(&[2, 3], vec![0.5, -1.25, 3.0e-8, f32::MIN_POSITIVE, 7.0, -0.0]);
        let jobs = vec![
            MicroJob { micro: 0, x: x.clone(), y: vec![3, 9], masks: masks(2, 2) },
            MicroJob { micro: 4, x, y: vec![1, 2], masks: MaskPair::ones(2, 2) },
        ];
        let mut frame = Vec::new();
        encode_compute(41, &jobs, &mut frame);
        let (step, back) = decode_compute(&frame).unwrap();
        assert_eq!(step, 41);
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].micro, 4);
        assert_eq!(back[0].y, vec![3, 9]);
        assert_eq!(back[0].x.shape(), &[2, 3]);
        for (a, b) in back[0].x.data().iter().zip(jobs_x_data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "tensor bytes must round-trip bit-exactly");
        }
        assert_eq!(back[0].masks.fingerprint(), masks(2, 2).fingerprint());
    }

    fn jobs_x_data() -> Vec<f32> {
        vec![0.5, -1.25, 3.0e-8, f32::MIN_POSITIVE, 7.0, -0.0]
    }

    #[test]
    fn apply_and_up_carry_grad_tails() {
        let union = masks(2, 2);
        let grad = vec![0xAA; 40];
        let mut frame = Vec::new();
        let off = encode_apply(0.05, &union, &grad, &mut frame);
        let (lr, u, doff) = decode_apply(&frame).unwrap();
        assert_eq!(lr, 0.05);
        assert_eq!(off, doff);
        assert_eq!(&frame[doff..], &grad[..]);
        assert_eq!(u.fingerprint(), union.fingerprint());

        let hdr = UpHdr { micro: 3, loss: 1.5, n_correct: 2.0, ms: 0.75, step: 9 };
        let mut up = Vec::new();
        encode_up_header(&hdr, &mut up);
        assert_eq!(up.len(), UP_GRAD_OFF);
        up.extend_from_slice(&grad);
        let back = decode_up(&up).unwrap();
        assert_eq!(back.micro, 3);
        assert_eq!(back.loss, 1.5);
        assert_eq!(back.ms, 0.75);
        assert_eq!(back.step, 9);
        assert_eq!(&up[UP_GRAD_OFF..], &grad[..]);
    }

    #[test]
    fn ctrl_and_bye_frames() {
        let mut f = Vec::new();
        encode_ctrl(TAG_RESET, &mut f);
        assert_eq!(peek_tag(&f).unwrap(), TAG_RESET);
        f.clear();
        let bye = ByeMsg { fresh: 7, reused: 123, ring_sent: 4096, ring_recv: 2048 };
        encode_bye(&bye, &mut f);
        assert_eq!(decode_bye(&f).unwrap(), bye);
        f.clear();
        let poff = encode_deltas_header(&mut f);
        f.extend_from_slice(&[1, 2, 3]);
        assert_eq!(decode_deltas(&f).unwrap(), poff);
    }

    #[test]
    fn malformed_frames_error_descriptively() {
        // Empty and tiny frames.
        assert!(peek_tag(&[]).unwrap_err().to_string().contains("truncated"));
        assert!(decode_init(&[1, 2]).is_err());
        // Wrong tag for the decoder.
        let mut f = Vec::new();
        encode_ctrl(TAG_RESET, &mut f);
        let err = decode_up(&f).unwrap_err().to_string();
        assert!(err.contains("expected Up"), "got: {err}");
        // Truncated mid-field: a valid Init prefix cut short.
        let spec = NativeSpec::tiny();
        let msg = InitMsg {
            worker: 0,
            spec,
            lora_rank: 0,
            seed: 1,
            precision: WirePrecision::F32,
            compress: WireCompression::None,
            ring: false,
            overlap: true,
            sim_wire_ms_per_mib: 0.0,
            heartbeat_ms: 0,
            trace: false,
            clock_anchor_us: 0,
            incarnation: 0,
        };
        let mut full = Vec::new();
        encode_init(&msg, &mut full);
        let err = decode_init(&full[..full.len() / 2]).unwrap_err().to_string();
        assert!(err.contains("truncated"), "got: {err}");
        // Corrupt element count cannot demand a huge allocation.
        let mut f = Vec::new();
        put_u32(&mut f, TAG_COMPUTE);
        put_u32(&mut f, u32::MAX); // job count far beyond the frame
        let err = decode_compute(&f).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "got: {err}");
        // A metric-only Up frame (exactly the header) is valid — ring
        // mode sends them — but anything shorter is rejected.
        let mut f = Vec::new();
        encode_up_header(
            &UpHdr { micro: 0, loss: 0.0, n_correct: 0.0, ms: 0.0, step: 0 },
            &mut f,
        );
        assert!(decode_up(&f).is_ok());
        assert!(decode_up(&f[..f.len() - 1]).is_err());
        // A tensor shape whose element product wraps usize must be
        // rejected, not wrapped into a small bogus length.
        let mut f = Vec::new();
        put_u32(&mut f, TAG_COMPUTE);
        put_u32(&mut f, 1); // one job
        put_u32(&mut f, 0); // micro
        put_u32(&mut f, 0); // no labels
        put_u32(&mut f, 3); // 3-dim shape...
        for _ in 0..3 {
            put_u32(&mut f, u32::MAX); // ...whose product overflows
        }
        let err = decode_compute(&f).unwrap_err().to_string();
        assert!(err.contains("overflow") || err.contains("corrupt count"), "got: {err}");
    }

    #[test]
    fn trace_frames_round_trip_and_reject_malformed() {
        use crate::obs::trace::{Event, EventKind};
        let events = [
            Event {
                name: "grad_step",
                cat: "compute",
                kind: EventKind::Span { dur_us: 480 },
                ts_us: 1000,
                tid: 2,
                lane: 3,
            },
            Event {
                name: "ping",
                cat: "hb",
                kind: EventKind::Instant,
                ts_us: 1500,
                tid: 1,
                lane: 3,
            },
            Event {
                name: "queue_depth",
                cat: "reduce",
                kind: EventKind::Counter { value: -2.5 },
                ts_us: 1700,
                tid: 2,
                lane: 3,
            },
        ];
        let mut f = Vec::new();
        encode_trace(2, -987_654, 41, &events, &mut f);
        assert_eq!(peek_tag(&f).unwrap(), TAG_TRACE);
        let back = decode_trace(&f).unwrap();
        assert_eq!(back.worker, 2);
        assert_eq!(back.offset_us, -987_654, "signed offsets survive the u64 transit");
        assert_eq!(back.truncated, 41);
        assert_eq!(back.events.len(), 3);
        for (orig, got) in events.iter().zip(&back.events) {
            assert_eq!(got, &orig.to_wire());
        }
        // Empty batches are valid (a quiet epoch still flushes).
        let mut empty = Vec::new();
        encode_trace(0, 0, 0, &[], &mut empty);
        assert!(decode_trace(&empty).unwrap().events.is_empty());
        // Wrong tag, truncation, corrupt count, bad kind all reject.
        let mut g = Vec::new();
        encode_ctrl(TAG_RESET, &mut g);
        assert!(decode_trace(&g).unwrap_err().to_string().contains("expected Trace"));
        assert!(decode_trace(&f[..f.len() - 3]).is_err());
        let mut huge = Vec::new();
        put_u32(&mut huge, TAG_TRACE);
        put_u32(&mut huge, 0);
        put_u64(&mut huge, 0);
        put_u64(&mut huge, 0);
        put_u32(&mut huge, u32::MAX); // event count far beyond the frame
        let err = decode_trace(&huge).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "got: {err}");
        // The last event's kind byte sits exactly kind+ts+payload+
        // tid+lane = 25 bytes from the frame end.
        let mut bad = f.clone();
        let kind_off = bad.len() - 25;
        bad[kind_off] = 9;
        assert!(
            decode_trace(&bad).unwrap_err().to_string().contains("unknown trace event kind"),
            "kind byte offset arithmetic must hit the last event's kind"
        );
    }

    #[test]
    fn control_plane_frames_round_trip() {
        let mut f = Vec::new();
        encode_ping(7, &mut f);
        assert_eq!(peek_tag(&f).unwrap(), TAG_PING);
        assert_eq!(decode_ping(&f).unwrap(), 7);
        f.clear();
        encode_pong(u64::MAX, &mut f);
        assert_eq!(decode_pong(&f).unwrap(), u64::MAX);
        f.clear();
        let join =
            JoinMsg { version: PROTO_VERSION, incarnation: 0xABCD, worker: 3, last_step: 17 };
        encode_join(&join, &mut f);
        assert_eq!(decode_join(&f).unwrap(), join);
        f.clear();
        encode_join(&JoinMsg::fresh(PROTO_VERSION), &mut f);
        let fresh = decode_join(&f).unwrap();
        assert_eq!(fresh.version, PROTO_VERSION);
        assert_eq!(fresh.incarnation, 0);
        assert_eq!(fresh.worker, u32::MAX);
        // A pre-v5 Join (tag + version only) still decodes as fresh so
        // the version mismatch downstream reads as a version error.
        let legacy = &f[..8];
        let back = decode_join(legacy).unwrap();
        assert_eq!(back, JoinMsg::fresh(PROTO_VERSION));
        f.clear();
        encode_nack(99, &mut f);
        assert_eq!(peek_tag(&f).unwrap(), TAG_NACK);
        assert_eq!(decode_nack(&f).unwrap(), 99);
        f.push(0xEE);
        assert!(decode_nack(&f).unwrap_err().to_string().contains("oversized"));
        f.clear();
        encode_evict(3, &mut f);
        assert_eq!(decode_evict(&f).unwrap(), 3);
        f.clear();
        let params = vec![1.5f32, -0.0, f32::MIN_POSITIVE];
        let momentum = vec![0.25f32, 3.0e-8];
        encode_state(&params, &momentum, &mut f);
        let (p, m) = decode_state(&f).unwrap();
        assert_eq!(bits32(&p), bits32(&params));
        assert_eq!(bits32(&m), bits32(&momentum));
    }

    fn bits32(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn heartbeat_frames_reject_bad_sizes_descriptively() {
        // Truncated: a Ping cut before its sequence number.
        let mut f = Vec::new();
        encode_ping(9, &mut f);
        let err = decode_ping(&f[..6]).unwrap_err().to_string();
        assert!(err.contains("truncated"), "got: {err}");
        // Oversized: trailing bytes after a complete heartbeat.
        f.extend_from_slice(&[0xAB; 3]);
        let err = decode_ping(&f).unwrap_err().to_string();
        assert!(err.contains("oversized"), "got: {err}");
        // Wrong tag for the decoder.
        let mut g = Vec::new();
        encode_pong(1, &mut g);
        let err = decode_ping(&g).unwrap_err().to_string();
        assert!(err.contains("expected Ping"), "got: {err}");
        // A State frame whose count outruns its payload is rejected
        // without attempting the allocation.
        let mut s = Vec::new();
        put_u32(&mut s, TAG_STATE);
        put_u64(&mut s, u64::MAX);
        let err = decode_state(&s).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "got: {err}");
    }

    #[test]
    fn property_control_frames_round_trip() {
        crate::util::proptest::check("proto-ctrl-roundtrip", 60, |g| {
            let mut f = Vec::new();
            let seq = g.rng().next_u64();
            encode_ping(seq, &mut f);
            if decode_ping(&f).map_err(|e| e.to_string())? != seq {
                return Err("ping seq mismatch".into());
            }
            f.clear();
            encode_pong(seq, &mut f);
            if decode_pong(&f).map_err(|e| e.to_string())? != seq {
                return Err("pong seq mismatch".into());
            }
            f.clear();
            let join = JoinMsg {
                version: g.rng().next_u64() as u32,
                incarnation: g.rng().next_u64(),
                worker: g.rng().next_u64() as u32,
                last_step: g.rng().next_u64(),
            };
            encode_join(&join, &mut f);
            if decode_join(&f).map_err(|e| e.to_string())? != join {
                return Err("join round-trip mismatch".into());
            }
            f.clear();
            encode_nack(seq, &mut f);
            if decode_nack(&f).map_err(|e| e.to_string())? != seq {
                return Err("nack step mismatch".into());
            }
            f.clear();
            let w = g.usize_in(0, 1 << 16);
            encode_evict(w, &mut f);
            if decode_evict(&f).map_err(|e| e.to_string())? != w {
                return Err("evict worker mismatch".into());
            }
            f.clear();
            let np = g.usize_in(0, 32);
            let nm = g.usize_in(0, 32);
            let params = g.vec(np, |g| g.f32_in(-1.0e6, 1.0e6));
            let momentum = g.vec(nm, |g| g.f32_in(-1.0, 1.0));
            encode_state(&params, &momentum, &mut f);
            let (p, m) = decode_state(&f).map_err(|e| e.to_string())?;
            if bits32(&p) != bits32(&params) || bits32(&m) != bits32(&momentum) {
                return Err("state vectors must round-trip bitwise".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_frames_round_trip() {
        let mut f = Vec::new();
        encode_ring_listen(true, 11, &mut f);
        assert_eq!(decode_ring_listen(&f).unwrap(), (true, 11));
        f.clear();
        encode_ring_addr(11, "127.0.0.1:45001", &mut f);
        assert_eq!(decode_ring_addr(&f).unwrap(), (11, "127.0.0.1:45001".to_string()));
        f.clear();
        encode_ring_peers(11, "chan://7", true, &mut f);
        assert_eq!(decode_ring_peers(&f).unwrap(), (11, "chan://7".to_string(), true));
        f.clear();
        encode_ring_ready(42, &mut f);
        assert_eq!(decode_ring_ready(&f).unwrap(), 42);
        f.clear();
        let exec = RingExec {
            step: 42,
            lr: 0.05,
            n_micros: 6,
            has_in: true,
            is_last: false,
            cast: CastRole::Leader { hops: 3 },
            union: masks(2, 2),
        };
        encode_ring_exec(&exec, &mut f);
        let back = decode_ring_exec(&f).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.lr, 0.05);
        assert_eq!(back.n_micros, 6);
        assert!(back.has_in && !back.is_last);
        assert_eq!(back.cast, CastRole::Leader { hops: 3 });
        assert_eq!(back.union.fingerprint(), exec.union.fingerprint());
        f.clear();
        encode_ring_reset(9, &mut f);
        assert_eq!(decode_ring_reset(&f).unwrap(), 9);
        // Payload-bearing frames return the exact tail offset.
        f.clear();
        let off = encode_ring_final_header(3, &mut f);
        f.extend_from_slice(&[1, 2, 3]);
        assert_eq!(decode_ring_final(&f).unwrap(), (3, off));
        assert_eq!(&f[off..], &[1, 2, 3]);
        f.clear();
        let off = encode_ring_castd_header(3, 2, &mut f);
        f.push(7);
        assert_eq!(decode_ring_castd(&f).unwrap(), (3, 2, off));
        f.clear();
        let off = encode_ring_part_header(8, &mut f);
        f.push(9);
        assert_eq!(decode_ring_part(&f).unwrap(), (8, off));
        f.clear();
        let off = encode_ring_cast_header(8, 1, &mut f);
        f.push(9);
        assert_eq!(decode_ring_cast(&f).unwrap(), (8, 1, off));
    }

    #[test]
    fn ring_frames_reject_malformed() {
        // Wrong tag for every decoder.
        let mut f = Vec::new();
        encode_ctrl(TAG_RESET, &mut f);
        assert!(decode_ring_listen(&f).is_err());
        assert!(decode_ring_addr(&f).is_err());
        assert!(decode_ring_peers(&f).is_err());
        assert!(decode_ring_ready(&f).is_err());
        assert!(decode_ring_exec(&f).is_err());
        assert!(decode_ring_reset(&f).is_err());
        assert!(decode_ring_final(&f).is_err());
        assert!(decode_ring_castd(&f).is_err());
        assert!(decode_ring_part(&f).is_err());
        assert!(decode_ring_cast(&f).is_err());
        // Oversized address count cannot demand a huge allocation.
        let mut f = Vec::new();
        put_u32(&mut f, TAG_RING_ADDR);
        put_u64(&mut f, 1); // nonce
        put_u32(&mut f, u32::MAX);
        let err = decode_ring_addr(&f).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "got: {err}");
        // Zero-micro exec and unknown cast role reject.
        let mut f = Vec::new();
        let exec = RingExec {
            step: 1,
            lr: 0.1,
            n_micros: 1,
            has_in: false,
            is_last: true,
            cast: CastRole::Origin { hops: 0 },
            union: MaskPair::ones(2, 2),
        };
        encode_ring_exec(&exec, &mut f);
        let mut zero = f.clone();
        zero[16..20].copy_from_slice(&0u32.to_le_bytes()); // n_micros = 0
        assert!(decode_ring_exec(&zero).is_err());
        let mut bad_role = f.clone();
        bad_role[22] = 9; // cast role byte
        assert!(decode_ring_exec(&bad_role).is_err());
        // Every strict prefix of an exec frame errors cleanly.
        crate::util::proptest::check("ring-exec-truncation", 40, |g| {
            let cut = g.usize_in(0, f.len() - 1);
            if decode_ring_exec(&f[..cut]).is_err() {
                Ok(())
            } else {
                Err(format!("{cut}-byte prefix decoded"))
            }
        });
    }

    #[test]
    fn property_truncated_control_frames_never_panic() {
        crate::util::proptest::check("proto-ctrl-truncation", 80, |g| {
            let mut f = Vec::new();
            match g.usize_in(0, 5) {
                0 => encode_ping(g.rng().next_u64(), &mut f),
                1 => encode_pong(g.rng().next_u64(), &mut f),
                2 => encode_join(
                    &JoinMsg {
                        version: g.rng().next_u64() as u32,
                        incarnation: g.rng().next_u64(),
                        worker: g.rng().next_u64() as u32,
                        last_step: g.rng().next_u64(),
                    },
                    &mut f,
                ),
                3 => encode_evict(g.usize_in(0, 64), &mut f),
                4 => encode_nack(g.rng().next_u64(), &mut f),
                _ => {
                    let params = g.vec(g.usize_in(0, 8), |g| g.f32_in(-1.0, 1.0));
                    let momentum = g.vec(g.usize_in(0, 8), |g| g.f32_in(-1.0, 1.0));
                    encode_state(&params, &momentum, &mut f)
                }
            }
            let cut = g.usize_in(0, f.len().saturating_sub(1));
            // Decoding any strict prefix must error (decoders are total:
            // no panic, no misparse of a short frame as a success) —
            // with one documented exception: an 8-byte Join prefix IS
            // the legacy pre-v5 Join and decodes as a fresh join.
            let slice = &f[..cut];
            let legacy_join = cut == 8 && peek_tag(slice).map(|t| t == TAG_JOIN).unwrap_or(false);
            let all_err = decode_ping(slice).is_err()
                && decode_pong(slice).is_err()
                && (legacy_join || decode_join(slice).is_err())
                && decode_evict(slice).is_err()
                && decode_nack(slice).is_err()
                && decode_state(slice).is_err();
            if all_err {
                Ok(())
            } else {
                Err(format!("a {cut}-byte prefix of a control frame decoded successfully"))
            }
        });
    }

    #[test]
    fn job_frames_round_trip_and_reject_truncation() {
        let round = JobRoundMsg {
            job_id: 7,
            tenant: "acme".to_string(),
            lora_rank: 2,
            fresh: false,
            finalize: true,
            start_batch: 4,
            n_batches: 3,
            spec_json: "{\"tenant\": \"acme\"}".to_string(),
            masks: vec![MaskPair::ones(2, 2), MaskPair::ones(2, 2)],
            params: vec![1, 2, 3, 4],
            momentum: vec![5, 6],
        };
        let mut f = Vec::new();
        encode_job_round(&round, &mut f);
        let back = decode_job_round(&f).unwrap();
        assert_eq!(back.job_id, 7);
        assert_eq!(back.tenant, "acme");
        assert_eq!(back.lora_rank, 2);
        assert!(!back.fresh && back.finalize);
        assert_eq!((back.start_batch, back.n_batches), (4, 3));
        assert_eq!(back.spec_json, round.spec_json);
        assert_eq!(back.masks.len(), 2);
        assert_eq!(back.params, vec![1, 2, 3, 4]);
        assert_eq!(back.momentum, vec![5, 6]);
        crate::util::proptest::check("job-round-truncation", 60, |g| {
            let cut = g.usize_in(0, f.len() - 1);
            if decode_job_round(&f[..cut]).is_err() {
                Ok(())
            } else {
                Err(format!("{cut}-byte prefix decoded"))
            }
        });

        let done = JobDoneMsg {
            job_id: 7,
            ok: true,
            error: String::new(),
            batches_done: 3,
            losses: vec![0.5, 0.25, 0.125],
            n_correct: 11,
            n_seen: 48,
            step_ms: vec![1.5, 2.5, 3.5],
            masks: vec![MaskPair::ones(2, 2)],
            params: vec![9, 8, 7],
            momentum: vec![6],
            dense_state_bytes: 4096,
            test_top1: 0.75,
            test_loss: 0.5,
        };
        let mut f = Vec::new();
        encode_job_done(&done, &mut f);
        let back = decode_job_done(&f).unwrap();
        assert_eq!(back.job_id, 7);
        assert!(back.ok);
        assert_eq!(back.batches_done, 3);
        assert_eq!(back.losses, vec![0.5, 0.25, 0.125]);
        assert_eq!((back.n_correct, back.n_seen), (11, 48));
        assert_eq!(back.step_ms, vec![1.5, 2.5, 3.5]);
        assert_eq!(back.masks.len(), 1);
        assert_eq!(back.params, vec![9, 8, 7]);
        assert_eq!(back.momentum, vec![6]);
        assert_eq!(back.dense_state_bytes, 4096);
        assert_eq!((back.test_top1, back.test_loss), (0.75, 0.5));
        crate::util::proptest::check("job-done-truncation", 60, |g| {
            let cut = g.usize_in(0, f.len() - 1);
            if decode_job_done(&f[..cut]).is_err() {
                Ok(())
            } else {
                Err(format!("{cut}-byte prefix decoded"))
            }
        });

        // A blob length claiming more bytes than the frame holds is a
        // corrupt count, never an allocation or a panic.
        let mut f = Vec::new();
        put_u32(&mut f, TAG_JOB_ROUND);
        put_u64(&mut f, 1);
        put_str(&mut f, "t");
        put_u32(&mut f, 0); // rank
        f.push(1); // fresh
        f.push(0); // finalize
        put_u32(&mut f, 0);
        put_u32(&mut f, 0);
        put_str(&mut f, "{}");
        put_u32(&mut f, 0); // masks
        put_u64(&mut f, u64::MAX); // params blob claims u64::MAX bytes
        let err = decode_job_round(&f).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "got: {err}");
    }
}
