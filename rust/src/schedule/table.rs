//! Schedule table + operation set (paper §II-A2, Algorithm 1 output).

use crate::partition::Partition;
use crate::tensor::Tensor;

/// The three scheduled operations. Numeric values match the paper's
/// `T_opt` encoding (1 = p_f, 2 = p_o, 3 = p_s).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Full forward + backward.
    Full,
    /// Forward only (no gradient for this subnet).
    ForwardOnly,
    /// Shortcut: skip the subnet entirely (residual route carries).
    Shortcut,
}

impl Op {
    /// The paper's `T_opt` numeric encoding of this operation.
    pub fn code(self) -> u8 {
        match self {
            Op::Full => 1,
            Op::ForwardOnly => 2,
            Op::Shortcut => 3,
        }
    }
}

/// Per-device operation budget for one batch of micro-batches.
///
/// The paper expresses budgets as operation counts per batch (e.g. "3
/// micro-batches perform p_f, 1 p_o, 1 p_s" = 60% compute): `n_full`
/// p_f slots and `n_fwd` p_o slots per device, out of `n_micro`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Budget {
    /// Micro-batches per batch.
    pub n_micro: usize,
    /// `p_f` (full) slots per device per batch.
    pub n_full: usize,
    /// `p_o` (forward-only) slots per device per batch.
    pub n_fwd: usize,
    /// Per-device overrides (device heterogeneity, paper §IV-D): device
    /// k uses `per_device[k]` = (n_full, n_fwd) when present.
    pub per_device: Vec<Option<(usize, usize)>>,
}

impl Budget {
    /// Same `(n_full, n_fwd)` budget on every device.
    pub fn uniform(n_micro: usize, n_full: usize, n_fwd: usize) -> Budget {
        assert!(
            n_full + n_fwd <= n_micro,
            "budget ({n_full} p_f + {n_fwd} p_o) exceeds {n_micro} micro-batches"
        );
        Budget { n_micro, n_full, n_fwd, per_device: Vec::new() }
    }

    /// Override device `device`'s budget (heterogeneity, §IV-D).
    pub fn with_device_override(mut self, device: usize, n_full: usize, n_fwd: usize) -> Budget {
        if self.per_device.len() <= device {
            self.per_device.resize(device + 1, None);
        }
        assert!(n_full + n_fwd <= self.n_micro);
        self.per_device[device] = Some((n_full, n_fwd));
        self
    }

    /// (n_full, n_fwd) for device `k`.
    pub fn for_device(&self, k: usize) -> (usize, usize) {
        self.per_device
            .get(k)
            .copied()
            .flatten()
            .unwrap_or((self.n_full, self.n_fwd))
    }

    /// Fraction of full-fine-tuning compute this budget uses, under the
    /// paper's cost model (c_f = `cost.fwd_frac` of a full op).
    pub fn compute_fraction(&self, fwd_frac: f64) -> f64 {
        (self.n_full as f64 + self.n_fwd as f64 * fwd_frac) / self.n_micro as f64
    }

    /// Fraction of full-fine-tuning communication (p_o = half, p_s = 0).
    pub fn comm_fraction(&self) -> f64 {
        (self.n_full as f64 + self.n_fwd as f64 * 0.5) / self.n_micro as f64
    }
}

/// Operation assignment for one batch: `table[k][i]` = op of subnet `k`
/// on micro-batch `i`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduleTable {
    /// Number of subnets (= simulated devices) scheduled.
    pub n_subnets: usize,
    /// Micro-batches per batch.
    pub n_micro: usize,
    ops: Vec<Op>,
}

/// One scheduled unit of work: subnet `subnet` runs `op` on micro-batch
/// `micro`. This is the granule the [`crate::cluster::Engine`] worker
/// queues carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    /// Subnet (= device) index.
    pub subnet: usize,
    /// Micro-batch index within the batch.
    pub micro: usize,
    /// The operation scheduled for this cell.
    pub op: Op,
}

impl ScheduleTable {
    /// Table with every cell set to `op`.
    pub fn all(n_subnets: usize, n_micro: usize, op: Op) -> ScheduleTable {
        ScheduleTable { n_subnets, n_micro, ops: vec![op; n_subnets * n_micro] }
    }

    /// Standard fine-tuning: everything p_f.
    pub fn standard(n_subnets: usize, n_micro: usize) -> ScheduleTable {
        Self::all(n_subnets, n_micro, Op::Full)
    }

    /// Operation of subnet `subnet` on micro-batch `micro`.
    pub fn get(&self, subnet: usize, micro: usize) -> Op {
        self.ops[subnet * self.n_micro + micro]
    }

    /// Assign an operation to one (subnet, micro-batch) cell.
    pub fn set(&mut self, subnet: usize, micro: usize, op: Op) {
        self.ops[subnet * self.n_micro + micro] = op;
    }

    /// Every cell as a [`Task`], row-major (all of subnet 0's
    /// micro-batches, then subnet 1's, ...) — the flat iteration the
    /// workload accounting walks.
    pub fn tasks(&self) -> impl Iterator<Item = Task> + '_ {
        (0..self.n_subnets).flat_map(move |k| {
            (0..self.n_micro).map(move |i| Task { subnet: k, micro: i, op: self.get(k, i) })
        })
    }

    /// One device's row as tasks, in micro-batch order — the work queue
    /// entry the execution engine dispatches per device.
    pub fn device_tasks(&self, subnet: usize) -> Vec<Task> {
        (0..self.n_micro)
            .map(|i| Task { subnet, micro: i, op: self.get(subnet, i) })
            .collect()
    }

    /// Count ops of a kind for one subnet row.
    pub fn count_row(&self, subnet: usize, op: Op) -> usize {
        (0..self.n_micro).filter(|&i| self.get(subnet, i) == op).count()
    }

    /// Build the dense `[L, H]` fwd/bwd masks for micro-batch `i`.
    ///
    /// p_f -> (1, 1); p_o -> (1, 0); p_s -> (0, 0). Heads covered by a
    /// multi-head subnet share its op.
    pub fn masks_for_micro(&self, part: &Partition, micro: usize) -> MaskPair {
        assert_eq!(part.n_subnets(), self.n_subnets, "partition/table mismatch");
        let mut fwd = Tensor::zeros(&[part.depth, part.heads]);
        let mut bwd = Tensor::zeros(&[part.depth, part.heads]);
        for (k, s) in part.subnets.iter().enumerate() {
            let (f, b) = match self.get(k, micro) {
                Op::Full => (1.0, 1.0),
                Op::ForwardOnly => (1.0, 0.0),
                Op::Shortcut => (0.0, 0.0),
            };
            for h in s.heads() {
                fwd.set(&[s.block, h], f);
                bwd.set(&[s.block, h], b);
            }
        }
        MaskPair { fwd, bwd }
    }

    /// All micro-batch masks at once.
    pub fn all_masks(&self, part: &Partition) -> Vec<MaskPair> {
        (0..self.n_micro).map(|i| self.masks_for_micro(part, i)).collect()
    }
}

/// Dense `[L, H]` forward/backward masks for one micro-batch — the two
/// mask inputs of the trainstep artifact.
#[derive(Clone, Debug)]
pub struct MaskPair {
    /// Forward mask (`[L, H]`, 1 = the head participates in the forward).
    pub fwd: Tensor,
    /// Backward mask (`[L, H]`, 1 = gradients flow for the head).
    pub bwd: Tensor,
}

impl MaskPair {
    /// All-ones masks (standard fine-tuning / evaluation).
    pub fn ones(depth: usize, heads: usize) -> MaskPair {
        MaskPair {
            fwd: Tensor::full(&[depth, heads], 1.0),
            bwd: Tensor::full(&[depth, heads], 1.0),
        }
    }

    /// Element-wise union (max) of a batch's mask pairs: a head is active
    /// in the union iff it is active in *any* micro-batch. This is the
    /// sparsity pattern of the batch's aggregated gradient, which the
    /// `dist` runtime's reduced-gradient broadcast is encoded under.
    pub fn union(masks: &[MaskPair]) -> MaskPair {
        assert!(!masks.is_empty(), "union of zero mask pairs");
        let mut out = masks[0].clone();
        for m in &masks[1..] {
            assert_eq!(m.fwd.shape(), out.fwd.shape(), "mask shape mismatch");
            for (o, &v) in out.fwd.data_mut().iter_mut().zip(m.fwd.data()) {
                if v > *o {
                    *o = v;
                }
            }
            for (o, &v) in out.bwd.data_mut().iter_mut().zip(m.bwd.data()) {
                if v > *o {
                    *o = v;
                }
            }
        }
        out
    }

    /// FNV-1a digest of the mask *bits* (shape + thresholded 0/1 cells).
    /// Both ends of the `dist` gradient wire format derive the payload
    /// layout from the schedule, so messages carry this fingerprint to
    /// detect a sender/receiver schedule mismatch.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for t in [&self.fwd, &self.bwd] {
            for &d in t.shape() {
                mix(d as u64 ^ 0xD1);
            }
            for &v in t.data() {
                mix(if v >= 0.5 { 0x9F } else { 0x9E });
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig {
            img_size: 32, patch: 4, dim: 64, depth: 2, heads: 2,
            mlp_ratio: 4, classes: 10, lora_rank: 0, head_dim: 32, tokens: 65,
        }
    }

    #[test]
    fn budget_fractions_match_paper_settings() {
        // "3 p_f + 2 p_s out of 5" = 60% compute (c_f = 0.4).
        let b = Budget::uniform(5, 3, 0);
        assert!((b.compute_fraction(0.4) - 0.6).abs() < 1e-9);
        // "3 p_f, 1 p_o, 1 p_s" = 75% LoRA compute table.
        let b = Budget::uniform(5, 3, 1);
        assert!((b.compute_fraction(0.4) - 0.68).abs() < 1e-9);
        assert!((b.comm_fraction() - 0.7).abs() < 1e-9);
        // "2 p_f, 1 p_o, 2 p_s" = 50% comm.
        let b = Budget::uniform(5, 2, 1);
        assert!((b.comm_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn device_overrides() {
        let b = Budget::uniform(5, 2, 2).with_device_override(3, 3, 1);
        assert_eq!(b.for_device(0), (2, 2));
        assert_eq!(b.for_device(3), (3, 1));
        assert_eq!(b.for_device(99), (2, 2));
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn overfull_budget_panics() {
        Budget::uniform(5, 3, 3);
    }

    #[test]
    fn masks_encode_ops() {
        let part = crate::partition::Partition::per_head(&cfg());
        let mut t = ScheduleTable::standard(part.n_subnets(), 3);
        t.set(0, 1, Op::Shortcut); // subnet 0 = (block 0, head 0)
        t.set(3, 1, Op::ForwardOnly); // subnet 3 = (block 1, head 1)
        let m = t.masks_for_micro(&part, 1);
        assert_eq!(m.fwd.at(&[0, 0]), 0.0);
        assert_eq!(m.bwd.at(&[0, 0]), 0.0);
        assert_eq!(m.fwd.at(&[1, 1]), 1.0);
        assert_eq!(m.bwd.at(&[1, 1]), 0.0);
        assert_eq!(m.fwd.at(&[0, 1]), 1.0);
        assert_eq!(m.bwd.at(&[0, 1]), 1.0);
        // micro-batch 0 untouched
        let m0 = t.masks_for_micro(&part, 0);
        assert_eq!(m0.fwd.at(&[0, 0]), 1.0);
    }

    #[test]
    fn grouped_subnet_masks_cover_all_heads() {
        let part = crate::partition::Partition::grouped(&cfg(), 2);
        let mut t = ScheduleTable::standard(part.n_subnets(), 2);
        t.set(1, 0, Op::Shortcut); // block 1, heads {0,1}
        let m = t.masks_for_micro(&part, 0);
        assert_eq!(m.fwd.at(&[1, 0]), 0.0);
        assert_eq!(m.fwd.at(&[1, 1]), 0.0);
        assert_eq!(m.fwd.at(&[0, 0]), 1.0);
    }

    #[test]
    fn task_iteration_covers_every_cell() {
        let mut t = ScheduleTable::standard(3, 4);
        t.set(1, 2, Op::Shortcut);
        let all: Vec<Task> = t.tasks().collect();
        assert_eq!(all.len(), 12);
        assert_eq!(all[0], Task { subnet: 0, micro: 0, op: Op::Full });
        assert_eq!(all[1 * 4 + 2], Task { subnet: 1, micro: 2, op: Op::Shortcut });
        // device rows agree with the flat iteration
        for k in 0..3 {
            let row = t.device_tasks(k);
            assert_eq!(row.len(), 4);
            for (i, task) in row.iter().enumerate() {
                assert_eq!(*task, all[k * 4 + i]);
                assert_eq!(task.op, t.get(k, i));
            }
        }
    }

    #[test]
    fn mask_union_and_fingerprint() {
        let part = crate::partition::Partition::per_head(&cfg());
        let mut t = ScheduleTable::all(part.n_subnets(), 2, Op::Shortcut);
        t.set(0, 0, Op::Full); // (block 0, head 0) full on micro 0 only
        t.set(3, 1, Op::ForwardOnly); // (block 1, head 1) fwd-only on micro 1
        let masks = t.all_masks(&part);
        let u = MaskPair::union(&masks);
        assert_eq!(u.fwd.at(&[0, 0]), 1.0);
        assert_eq!(u.bwd.at(&[0, 0]), 1.0);
        assert_eq!(u.fwd.at(&[1, 1]), 1.0, "p_o participates forward");
        assert_eq!(u.bwd.at(&[1, 1]), 0.0, "p_o never unfreezes");
        assert_eq!(u.fwd.at(&[0, 1]), 0.0, "never-scheduled head stays off");
        // Union of one mask is that mask.
        let one = MaskPair::union(&masks[..1]);
        assert_eq!(one.fwd, masks[0].fwd);
        assert_eq!(one.bwd, masks[0].bwd);
        // Fingerprints: stable for equal masks, different for different.
        assert_eq!(masks[0].fingerprint(), masks[0].clone().fingerprint());
        assert_ne!(masks[0].fingerprint(), masks[1].fingerprint());
        assert_ne!(
            MaskPair::ones(2, 2).fingerprint(),
            MaskPair::ones(4, 1).fingerprint(),
            "shape feeds the digest"
        );
    }

    #[test]
    fn op_codes_match_paper() {
        assert_eq!(Op::Full.code(), 1);
        assert_eq!(Op::ForwardOnly.code(), 2);
        assert_eq!(Op::Shortcut.code(), 3);
    }
}
