//! D2FT's bi-level knapsack scheduler (paper §II-B/C, Algorithms 1 & 2).
//!
//! Per device (subnet) k the multi-knapsack (Eq. 4) is decoupled into a
//! bi-level problem: the **outer** knapsack picks `p_f` micro-batches by
//! *backward* contribution score under the full-operation capacity
//! (Eq. 6/7); the **inner** knapsack picks `p_o` micro-batches by
//! *forward* score under the forward-only capacity (Eq. 8). Both levels
//! are solved exactly by the Algorithm-2 DP ([`knapsack_01`]).
//!
//! Merging follows Algorithm 1: chosen by both -> p_f, by neither -> p_s.
//! Two merge modes are provided:
//!
//! * [`MergeMode::Exclusive`] (default): the inner DP runs over the
//!   samples the outer level did *not* take, enforcing the paper's
//!   `1_{p_f} + 1_{p_o} <= 1` constraint exactly — every device emits
//!   precisely (n_full, n_fwd) operations, which is what makes Table I's
//!   workload variance exactly 0.
//! * [`MergeMode::PaperMerge`]: both DPs run over all samples and
//!   conflicts resolve to p_f verbatim as in Algorithm 1 lines 23-25
//!   (a device may then emit fewer p_o ops than budgeted).

use super::knapsack::knapsack_01;
use super::table::{Budget, Op, ScheduleTable};
use super::Scheduler;
use crate::cluster::cost::CostModel;
use crate::scores::{ScoreBook, ScoreConfig};

/// How the outer (p_f) and inner (p_o) selections are merged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeMode {
    /// Inner DP runs over the samples the outer level did not take —
    /// exact per-device counts (the default; Table I's zero variance).
    Exclusive,
    /// Algorithm 1 verbatim: both DPs see all samples; conflicts -> p_f.
    PaperMerge,
}

/// The D2FT scheduler.
pub struct BiLevel {
    /// Which contribution metric feeds each level.
    pub scores: ScoreConfig,
    /// Integer cost units for the knapsack capacities.
    pub cost: CostModel,
    /// Conflict-resolution mode (see [`MergeMode`]).
    pub merge: MergeMode,
}

impl BiLevel {
    /// D2FT with the default exclusive merge.
    pub fn new(scores: ScoreConfig, cost: CostModel) -> Self {
        BiLevel { scores, cost, merge: MergeMode::Exclusive }
    }

    /// Switch the merge mode (builder style).
    pub fn with_merge(mut self, merge: MergeMode) -> Self {
        self.merge = merge;
        self
    }

    /// Schedule one device (= one subnet row). Exposed for tests.
    pub fn schedule_device(
        &self,
        backward_scores: &[f64],
        forward_scores: &[f64],
        n_full: usize,
        n_fwd: usize,
    ) -> Vec<Op> {
        let n = backward_scores.len();
        let w_full = self.cost.full_units();
        let w_fwd = self.cost.fwd_units();
        // All weights within one level are equal, so a positive shift is
        // rank-preserving; it guarantees the DP fills the budget even when
        // raw scores are zero (exact per-device counts -> Table I's zero
        // workload variance).
        let shift = |xs: &[f64]| -> Vec<f64> {
            let lo = xs.iter().copied().fold(f64::INFINITY, f64::min).min(0.0);
            xs.iter().map(|&v| v - lo + 1.0).collect()
        };
        // Outer level: p_f by backward score, capacity = n_full full ops.
        let weights_full = vec![w_full; n];
        let (_, picked_f) = knapsack_01(&shift(backward_scores), &weights_full, n_full * w_full);
        // Inner level: p_o by forward score, capacity = n_fwd fwd ops.
        let weights_fwd = vec![w_fwd; n];
        let picked_o = match self.merge {
            MergeMode::Exclusive => {
                // Mask out samples the outer level took (enforce the
                // 1_{p_f} + 1_{p_o} <= 1 coupling inside the DP): shifted
                // scores are >= 1, masked items get large negative value
                // so the maximizing DP never takes them.
                let masked: Vec<f64> = shift(forward_scores)
                    .into_iter()
                    .zip(&picked_f)
                    .map(|(s, &pf)| if pf { -1e300 } else { s })
                    .collect();
                let (_, mut picked) = knapsack_01(&masked, &weights_fwd, n_fwd * w_fwd);
                for (p, &pf) in picked.iter_mut().zip(&picked_f) {
                    *p = *p && !pf;
                }
                picked
            }
            MergeMode::PaperMerge => {
                let (_, picked) = knapsack_01(&shift(forward_scores), &weights_fwd, n_fwd * w_fwd);
                picked
            }
        };
        // Algorithm 1 merge: both -> p_f; only outer -> p_f; only inner
        // -> p_o; neither -> p_s.
        (0..n)
            .map(|i| {
                if picked_f[i] {
                    Op::Full
                } else if picked_o[i] {
                    Op::ForwardOnly
                } else {
                    Op::Shortcut
                }
            })
            .collect()
    }
}

impl Scheduler for BiLevel {
    fn name(&self) -> &'static str {
        "D2FT (Ours)"
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        let mut table = ScheduleTable::all(scores.n_subnets, scores.n_micro, Op::Shortcut);
        for k in 0..scores.n_subnets {
            let (n_full, n_fwd) = budget.for_device(k);
            let ops = self.schedule_device(
                scores.row(self.scores.backward, k),
                scores.row(self.scores.forward, k),
                n_full,
                n_fwd,
            );
            for (i, op) in ops.into_iter().enumerate() {
                table.set(k, i, op);
            }
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Partition;
    use crate::runtime::ModelConfig;
    use crate::scores::Metric;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn bilevel() -> BiLevel {
        BiLevel::new(ScoreConfig::default(), CostModel::paper())
    }

    fn book_from(n_subnets: usize, n_micro: usize, seed: u64) -> ScoreBook {
        let mut rng = Rng::new(seed);
        let mut b = ScoreBook::zeros(n_subnets, n_micro);
        for k in 0..n_subnets {
            for i in 0..n_micro {
                for m in [Metric::Fisher, Metric::GradMag, Metric::Taylor] {
                    b.set(m, k, i, rng.next_f64() * 10.0);
                }
                // weight magnitude is per-subnet (sample independent)
                b.set(Metric::WeightMag, k, i, (k + 1) as f64);
            }
        }
        b
    }

    #[test]
    fn device_selects_top_forward_scores() {
        let d = bilevel();
        // backward scores equal -> first n_full by DP tie-break; forward
        // scores favor micro-batches 3, 4.
        let ops = d.schedule_device(&[1.0; 5], &[0.1, 0.2, 0.3, 9.0, 8.0], 2, 2);
        let full: Vec<usize> = (0..5).filter(|&i| ops[i] == Op::Full).collect();
        let fwd: Vec<usize> = (0..5).filter(|&i| ops[i] == Op::ForwardOnly).collect();
        assert_eq!(full.len(), 2);
        assert_eq!(fwd, vec![3, 4].into_iter().filter(|i| !full.contains(i)).collect::<Vec<_>>());
    }

    #[test]
    fn exclusive_mode_emits_exact_counts() {
        check("bilevel-exact-counts", 40, |g| {
            let n_micro = g.usize_in(2, 8);
            let n_full = g.usize_in(0, n_micro);
            let n_fwd = g.usize_in(0, n_micro - n_full);
            let n_subnets = g.usize_in(1, 20);
            let book = book_from(n_subnets, n_micro, g.usize_in(0, 1 << 30) as u64);
            let mut d = bilevel();
            let t = d.schedule(&book, &Budget::uniform(n_micro, n_full, n_fwd));
            for k in 0..n_subnets {
                if t.count_row(k, Op::Full) != n_full {
                    return Err(format!("subnet {k}: p_f {} != {n_full}", t.count_row(k, Op::Full)));
                }
                if t.count_row(k, Op::ForwardOnly) != n_fwd {
                    return Err(format!("subnet {k}: p_o count mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn paper_merge_resolves_conflicts_to_full() {
        let d = bilevel().with_merge(MergeMode::PaperMerge);
        // forward and backward both favor samples 0, 1 -> conflicts.
        let ops = d.schedule_device(&[9.0, 8.0, 0.1, 0.1, 0.1], &[9.0, 8.0, 0.2, 0.1, 0.1], 2, 2);
        assert_eq!(ops[0], Op::Full);
        assert_eq!(ops[1], Op::Full);
        // inner picked {0,1} too; merged away, so fewer p_o remain.
        assert!(ops[2..].iter().filter(|&&o| o == Op::ForwardOnly).count() <= 2);
    }

    #[test]
    fn respects_per_device_override() {
        let book = book_from(4, 5, 7);
        let mut d = bilevel();
        let budget = Budget::uniform(5, 2, 2).with_device_override(1, 3, 1);
        let t = d.schedule(&book, &budget);
        assert_eq!(t.count_row(0, Op::Full), 2);
        assert_eq!(t.count_row(1, Op::Full), 3);
        assert_eq!(t.count_row(1, Op::ForwardOnly), 1);
    }

    #[test]
    fn zero_budget_all_shortcut() {
        let book = book_from(3, 4, 1);
        let mut d = bilevel();
        let t = d.schedule(&book, &Budget::uniform(4, 0, 0));
        for k in 0..3 {
            assert_eq!(t.count_row(k, Op::Shortcut), 4);
        }
    }

    #[test]
    fn full_budget_all_full() {
        let book = book_from(3, 4, 2);
        let mut d = bilevel();
        let t = d.schedule(&book, &Budget::uniform(4, 4, 0));
        for k in 0..3 {
            assert_eq!(t.count_row(k, Op::Full), 4);
        }
    }

    #[test]
    fn workload_variance_is_zero_with_uniform_budget() {
        // The Table I headline: D2FT emits identical per-device workloads.
        let cfg = ModelConfig {
            img_size: 32, patch: 4, dim: 192, depth: 6, heads: 6,
            mlp_ratio: 4, classes: 196, lora_rank: 0, head_dim: 32, tokens: 65,
        };
        let part = Partition::per_head(&cfg);
        let book = book_from(part.n_subnets(), 5, 3);
        let mut d = bilevel();
        let t = d.schedule(&book, &Budget::uniform(5, 3, 0));
        let cost = CostModel::paper();
        let loads: Vec<f64> = (0..t.n_subnets)
            .map(|k| (0..t.n_micro).map(|i| cost.compute_units(t.get(k, i)) as f64).sum())
            .collect();
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        let var = loads.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / loads.len() as f64;
        assert_eq!(var, 0.0);
    }
}
