//! Dynamic-pruning baselines (paper §III-A; Lin et al. [21], Sokar et
//! al. [31]).
//!
//! Every `refresh_every` batches the pruner re-selects the top-scoring
//! subnets under the compute budget; selected subnets run `p_f` on every
//! micro-batch, pruned subnets run `p_s` (no `p_o` option — the paper
//! calls this out as the reason dynamic pruning degrades at high pruning
//! ratios). Selection is *global* across subnets, so devices are either
//! fully busy or idle: Table I's variance ≈ 0.25.
//!
//! * `DPruningM` ("DPruning M"): score = weight magnitude.
//! * `DPruningMG` ("DPruning M/G"): score = weight magnitude x gradient
//!   magnitude (the magnitude-gradient variant).

use super::table::{Budget, Op, ScheduleTable};
use super::Scheduler;
use crate::scores::{Metric, ScoreBook};

/// Which importance score ranks subnets for pruning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneScore {
    /// Weight magnitude ("DPruning M").
    Magnitude,
    /// Weight magnitude x gradient magnitude ("DPruning M/G").
    MagnitudeGradient,
}

/// The dynamic-pruning baseline scheduler.
pub struct DPruning {
    kind: PruneScore,
    /// Re-select every this many batches (paper: 16 iterations).
    refresh_every: usize,
    batch_idx: usize,
    selected: Vec<bool>,
}

impl DPruning {
    /// Weight-magnitude variant ("DPruning M").
    pub fn magnitude() -> DPruning {
        DPruning {
            kind: PruneScore::Magnitude,
            refresh_every: 16,
            batch_idx: 0,
            selected: Vec::new(),
        }
    }

    /// Magnitude-gradient variant ("DPruning M/G").
    pub fn magnitude_gradient() -> DPruning {
        DPruning {
            kind: PruneScore::MagnitudeGradient,
            refresh_every: 16,
            batch_idx: 0,
            selected: Vec::new(),
        }
    }

    /// Override the re-selection interval (builder style).
    pub fn with_refresh(mut self, every: usize) -> DPruning {
        assert!(every >= 1);
        self.refresh_every = every;
        self
    }

    fn subnet_score(&self, scores: &ScoreBook, k: usize) -> f64 {
        match self.kind {
            PruneScore::Magnitude => scores.subnet_total(Metric::WeightMag, k),
            PruneScore::MagnitudeGradient => {
                scores.subnet_total(Metric::WeightMag, k)
                    * scores.subnet_total(Metric::GradMag, k).max(1e-30)
            }
        }
    }

    fn reselect(&mut self, scores: &ScoreBook, budget: &Budget) {
        // Match D2FT's compute budget with p_f-only ops: keep a fraction
        // of subnets equal to the budget's compute fraction.
        let frac = budget.compute_fraction(0.4);
        let n_keep = ((scores.n_subnets as f64 * frac).round() as usize).min(scores.n_subnets);
        let mut order: Vec<usize> = (0..scores.n_subnets).collect();
        order.sort_by(|&a, &b| {
            self.subnet_score(scores, b)
                .partial_cmp(&self.subnet_score(scores, a))
                .unwrap()
        });
        self.selected = vec![false; scores.n_subnets];
        for &k in order.iter().take(n_keep) {
            self.selected[k] = true;
        }
    }
}

impl Scheduler for DPruning {
    fn name(&self) -> &'static str {
        match self.kind {
            PruneScore::Magnitude => "DPruning M",
            PruneScore::MagnitudeGradient => "DPruning M/G",
        }
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        if self.batch_idx % self.refresh_every == 0 || self.selected.len() != scores.n_subnets {
            self.reselect(scores, budget);
        }
        self.batch_idx += 1;
        let mut table = ScheduleTable::all(scores.n_subnets, scores.n_micro, Op::Shortcut);
        for k in 0..scores.n_subnets {
            if self.selected[k] {
                for i in 0..scores.n_micro {
                    table.set(k, i, Op::Full);
                }
            }
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::CostModel;
    use crate::cluster::workload::WorkloadTracker;

    fn book(n_subnets: usize) -> ScoreBook {
        let mut b = ScoreBook::zeros(n_subnets, 5);
        for k in 0..n_subnets {
            for i in 0..5 {
                b.set(Metric::WeightMag, k, i, (k + 1) as f64);
                b.set(Metric::GradMag, k, i, ((n_subnets - k) as f64).sqrt());
            }
        }
        b
    }

    #[test]
    fn keeps_top_magnitude_subnets() {
        let mut p = DPruning::magnitude();
        let b = book(10);
        let t = p.schedule(&b, &Budget::uniform(5, 3, 0)); // 60% -> keep 6
        let kept: Vec<usize> = (0..10).filter(|&k| t.get(k, 0) == Op::Full).collect();
        assert_eq!(kept, vec![4, 5, 6, 7, 8, 9]);
        // kept subnets run everything, pruned run nothing
        for &k in &kept {
            assert_eq!(t.count_row(k, Op::Full), 5);
        }
        assert_eq!(t.count_row(0, Op::Shortcut), 5);
    }

    #[test]
    fn refresh_interval_respected() {
        let mut p = DPruning::magnitude().with_refresh(2);
        let b1 = book(6);
        let t1 = p.schedule(&b1, &Budget::uniform(5, 3, 0));
        // change the scores drastically; without refresh the selection holds
        let mut b2 = ScoreBook::zeros(6, 5);
        for k in 0..6 {
            for i in 0..5 {
                b2.set(Metric::WeightMag, k, i, (6 - k) as f64);
            }
        }
        let t2 = p.schedule(&b2, &Budget::uniform(5, 3, 0));
        assert_eq!(t1, t2, "selection must persist between refreshes");
        let t3 = p.schedule(&b2, &Budget::uniform(5, 3, 0));
        assert_ne!(t1, t3, "refresh must re-rank");
    }

    #[test]
    fn all_or_nothing_workload_variance() {
        // The Table I contrast: pruning is per-subnet, so ~0.24 variance
        // of per-device compute fraction at a 60% budget.
        let mut p = DPruning::magnitude();
        let b = book(72);
        let t = p.schedule(&b, &Budget::uniform(5, 3, 0));
        let mut w = WorkloadTracker::new(CostModel::paper(), 72);
        w.record(&t);
        let var = w.workload_variance();
        assert!((var - 0.24).abs() < 0.03, "variance {var}");
    }

    #[test]
    fn mg_variant_uses_gradient() {
        let mut pm = DPruning::magnitude();
        let mut pmg = DPruning::magnitude_gradient();
        // magnitude increasing in k, gradient decreasing: the product
        // reorders the ranking.
        let mut b = ScoreBook::zeros(4, 2);
        let mags = [1.0, 2.0, 3.0, 4.0];
        let grads = [100.0, 1.0, 1.0, 1.0];
        for k in 0..4 {
            for i in 0..2 {
                b.set(Metric::WeightMag, k, i, mags[k]);
                b.set(Metric::GradMag, k, i, grads[k]);
            }
        }
        let tm = pm.schedule(&b, &Budget::uniform(2, 1, 0)); // keep 2
        let tmg = pmg.schedule(&b, &Budget::uniform(2, 1, 0));
        assert_ne!(tm, tmg);
        assert_eq!(tmg.get(0, 0), Op::Full, "huge gradient rescues subnet 0");
    }
}
