//! "Scaler" single-level baseline (paper §IV-F, Table X).
//!
//! Instead of the bi-level decoupling, both operations compete in one
//! knapsack per device: forward scores are scaled by λ to "match" the
//! backward scale, and each micro-batch contributes two mutually
//! exclusive items (p_f valued by the backward score, p_o valued by
//! λ x forward score). λ options mirror the paper: `Max` (every forward
//! score below every backward score), `Min` (the reverse), or a
//! constant.
//!
//! The single knapsack packs a combined capacity; mutual exclusion is
//! enforced by a small per-sample group DP (grouped knapsack), which is
//! the natural exact formulation of Eq. 5.

use super::table::{Budget, Op, ScheduleTable};
use super::Scheduler;
use crate::cluster::cost::CostModel;
use crate::scores::{ScoreBook, ScoreConfig};

/// The λ scaling policy relating forward to backward scores.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Lambda {
    /// Scale forward scores below the smallest backward score.
    Max,
    /// Scale backward scores below the smallest forward score.
    Min,
    /// Constant multiplier on forward scores.
    Const(f64),
}

/// The single-level "Scaler" baseline scheduler (Table X).
pub struct ScalerSched {
    /// Forward-score scaling policy.
    pub lambda: Lambda,
    /// Which contribution metric feeds each operation's value.
    pub scores: ScoreConfig,
    /// Integer cost units for the knapsack capacity.
    pub cost: CostModel,
}

impl ScalerSched {
    /// Scaler baseline with the given λ policy.
    pub fn new(lambda: Lambda, scores: ScoreConfig, cost: CostModel) -> ScalerSched {
        ScalerSched { lambda, scores, cost }
    }

    /// Grouped 0/1 knapsack: per sample choose {none, p_o, p_f}.
    /// DP over samples x capacity; O(N·C) like Algorithm 2.
    fn schedule_device(
        &self,
        backward: &[f64],
        forward: &[f64],
        capacity_units: usize,
    ) -> Vec<Op> {
        let n = backward.len();
        let w_full = self.cost.full_units();
        let w_fwd = self.cost.fwd_units();
        let (bw, fw): (Vec<f64>, Vec<f64>) = match self.lambda {
            Lambda::Const(l) => (backward.to_vec(), forward.iter().map(|&f| f * l).collect()),
            Lambda::Max => {
                // forward scores strictly below every backward score
                let bmin = backward.iter().copied().fold(f64::INFINITY, f64::min).max(1e-12);
                let fmax = forward.iter().copied().fold(0.0f64, f64::max).max(1e-12);
                let l = 0.5 * bmin / fmax;
                (backward.to_vec(), forward.iter().map(|&f| f * l).collect())
            }
            Lambda::Min => {
                // backward scores strictly below every forward score
                let fmin = forward.iter().copied().fold(f64::INFINITY, f64::min).max(1e-12);
                let bmax = backward.iter().copied().fold(0.0f64, f64::max).max(1e-12);
                let l = 0.5 * fmin / bmax;
                (backward.iter().map(|&b| b * l).collect(), forward.to_vec())
            }
        };
        let cols = capacity_units + 1;
        // dp[i][w]: best value using first i samples at weight w; choice
        // tracked for backtracking: 0 = none, 1 = p_o, 2 = p_f.
        let mut dp = vec![0.0f64; (n + 1) * cols];
        let mut choice = vec![0u8; (n + 1) * cols];
        for i in 1..=n {
            for w in 0..cols {
                let mut best = dp[(i - 1) * cols + w];
                let mut ch = 0u8;
                if w >= w_fwd {
                    let v = dp[(i - 1) * cols + (w - w_fwd)] + fw[i - 1];
                    if v > best {
                        best = v;
                        ch = 1;
                    }
                }
                if w >= w_full {
                    let v = dp[(i - 1) * cols + (w - w_full)] + bw[i - 1];
                    if v > best {
                        best = v;
                        ch = 2;
                    }
                }
                dp[i * cols + w] = best;
                choice[i * cols + w] = ch;
            }
        }
        let mut ops = vec![Op::Shortcut; n];
        let mut w = capacity_units;
        for i in (1..=n).rev() {
            match choice[i * cols + w] {
                1 => {
                    ops[i - 1] = Op::ForwardOnly;
                    w -= w_fwd;
                }
                2 => {
                    ops[i - 1] = Op::Full;
                    w -= w_full;
                }
                _ => {}
            }
        }
        ops
    }
}

impl Scheduler for ScalerSched {
    fn name(&self) -> &'static str {
        "Scaler"
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        let mut table = ScheduleTable::all(scores.n_subnets, scores.n_micro, Op::Shortcut);
        for k in 0..scores.n_subnets {
            let (n_full, n_fwd) = budget.for_device(k);
            let capacity = n_full * self.cost.full_units() + n_fwd * self.cost.fwd_units();
            let ops = self.schedule_device(
                scores.row(self.scores.backward, k),
                scores.row(self.scores.forward, k),
                capacity,
            );
            for (i, op) in ops.into_iter().enumerate() {
                table.set(k, i, op);
            }
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scores::Metric;

    fn sched(lambda: Lambda) -> ScalerSched {
        ScalerSched::new(lambda, ScoreConfig::default(), CostModel::paper())
    }

    #[test]
    fn max_scaler_prefers_full_ops() {
        // With forward scores scaled below backward ones, p_f wins the
        // capacity — matching the paper's claim that Max ≈ bi-level.
        let s = sched(Lambda::Max);
        let ops = s.schedule_device(
            &[5.0, 4.0, 3.0, 2.0, 1.0],
            &[9.0, 9.0, 9.0, 9.0, 9.0],
            2 * 5 + 2 * 2,
        );
        let n_full = ops.iter().filter(|&&o| o == Op::Full).count();
        assert_eq!(n_full, 2);
        assert!(ops.iter().filter(|&&o| o == Op::ForwardOnly).count() >= 2);
        assert_eq!(ops[0], Op::Full);
        assert_eq!(ops[1], Op::Full);
    }

    #[test]
    fn min_scaler_prefers_forward_ops() {
        let s = sched(Lambda::Min);
        // capacity for 2 p_f + 2 p_o = 14 units; min-scaler floods it
        // with p_o (2 units each -> up to 5).
        let ops = s.schedule_device(&[5.0, 4.0, 3.0, 2.0, 1.0], &[1.0, 1.0, 1.0, 1.0, 1.0], 14);
        let n_fwd = ops.iter().filter(|&&o| o == Op::ForwardOnly).count();
        assert!(n_fwd >= 4, "{ops:?}");
    }

    #[test]
    fn respects_capacity() {
        let s = sched(Lambda::Const(0.2));
        let cost = CostModel::paper();
        for cap in [0, 2, 5, 7, 14, 25] {
            let ops = s.schedule_device(&[3.0; 5], &[1.0; 5], cap);
            let used: usize = ops.iter().map(|&o| cost.compute_units(o)).sum();
            assert!(used <= cap, "capacity {cap} exceeded: {used}");
        }
    }

    #[test]
    fn schedules_all_subnets() {
        let mut s = sched(Lambda::Const(0.1));
        let mut book = ScoreBook::zeros(4, 5);
        for k in 0..4 {
            for i in 0..5 {
                book.set(Metric::WeightMag, k, i, 1.0 + k as f64);
                book.set(Metric::Fisher, k, i, 1.0 + i as f64);
            }
        }
        let t = s.schedule(&book, &Budget::uniform(5, 2, 2));
        for k in 0..4 {
            assert!(t.count_row(k, Op::Full) + t.count_row(k, Op::ForwardOnly) > 0);
        }
    }
}
