//! "Random" baseline (paper §III-A): every (subnet, micro-batch) pair
//! independently draws p_f / p_o / p_s with probabilities matching the
//! global budget — same expected cost as D2FT, no contribution awareness,
//! no workload balancing (Table I shows its variance ≥ 0.2).

use super::table::{Budget, Op, ScheduleTable};
use super::Scheduler;
use crate::scores::ScoreBook;
use crate::util::rng::Rng;

/// The budget-matched random scheduling baseline.
pub struct RandomSched {
    rng: Rng,
}

impl RandomSched {
    /// Deterministic random scheduler from a seed.
    pub fn new(seed: u64) -> RandomSched {
        RandomSched { rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> &'static str {
        "Random"
    }

    fn needs_scores(&self) -> bool {
        false
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        let n = budget.n_micro as f64;
        let p_full = budget.n_full as f64 / n;
        let p_fwd = budget.n_fwd as f64 / n;
        let mut table = ScheduleTable::all(scores.n_subnets, scores.n_micro, Op::Shortcut);
        for k in 0..scores.n_subnets {
            for i in 0..scores.n_micro {
                let u = self.rng.next_f64();
                let op = if u < p_full {
                    Op::Full
                } else if u < p_full + p_fwd {
                    Op::ForwardOnly
                } else {
                    Op::Shortcut
                };
                table.set(k, i, op);
            }
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::CostModel;
    use crate::cluster::workload::WorkloadTracker;
    use crate::schedule::table::Budget;

    #[test]
    fn expected_cost_matches_budget() {
        let mut s = RandomSched::new(1);
        let book = ScoreBook::zeros(72, 5);
        let budget = Budget::uniform(5, 3, 0); // 60% compute target
        let cost = CostModel::paper();
        let mut w = WorkloadTracker::new(cost, 72);
        for _ in 0..50 {
            w.record(&s.schedule(&book, &budget));
        }
        let frac = w.total_compute_fraction();
        assert!((frac - 0.6).abs() < 0.03, "fraction {frac}");
    }

    #[test]
    fn workload_variance_is_positive() {
        // The Table I contrast: Random cannot balance workloads.
        let mut s = RandomSched::new(2);
        let book = ScoreBook::zeros(72, 5);
        let budget = Budget::uniform(5, 3, 0);
        let mut w = WorkloadTracker::new(CostModel::paper(), 72);
        w.record(&s.schedule(&book, &budget));
        assert!(w.workload_variance() > 0.0);
        assert!(w.sample_count_variance() > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let book = ScoreBook::zeros(8, 5);
        let budget = Budget::uniform(5, 2, 2);
        let a = RandomSched::new(7).schedule(&book, &budget);
        let b = RandomSched::new(7).schedule(&book, &budget);
        assert_eq!(a, b);
        let c = RandomSched::new(8).schedule(&book, &budget);
        assert_ne!(a, c);
    }
}
