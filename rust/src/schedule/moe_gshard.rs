//! MoE GShard baseline (paper §III-A; Lepikhin et al. [30]).
//!
//! A gating network routes each micro-batch to experts (subnets) per
//! block, with GShard's *expert capacity* limit: once an expert hits its
//! capacity, further micro-batches routed to it are **dropped** — they
//! are simply not processed by any expert of that block. The paper's
//! Table II observation follows: lower execution time (fewer samples
//! processed) but much worse accuracy.
//!
//! We simulate the learned gate with per-(micro-batch, expert) logits
//! derived from the Fisher probe plus gate noise (GShard trains its gate
//! jointly; this keeps the baseline honest without adding trainable gate
//! parameters to the HLO — substitution documented in DESIGN.md).

use super::table::{Budget, Op, ScheduleTable};
use super::Scheduler;
use crate::scores::{Metric, ScoreBook};
use crate::util::rng::Rng;

/// The MoE GShard gating baseline scheduler.
pub struct MoeGshard {
    rng: Rng,
    /// Experts activated per micro-batch per block (top-k gate).
    pub top_k: usize,
    /// Subnets per block (needed to group experts).
    pub subnets_per_block: usize,
}

impl MoeGshard {
    /// GShard gate with top-2 routing over `subnets_per_block` experts.
    pub fn new(seed: u64, subnets_per_block: usize) -> MoeGshard {
        MoeGshard { rng: Rng::new(seed), top_k: 2, subnets_per_block }
    }
}

impl Scheduler for MoeGshard {
    fn name(&self) -> &'static str {
        "MoE Gshard"
    }

    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable {
        let spb = self.subnets_per_block;
        assert!(spb > 0 && scores.n_subnets % spb == 0, "subnets not divisible by block");
        let n_blocks = scores.n_subnets / spb;
        let mut table = ScheduleTable::all(scores.n_subnets, scores.n_micro, Op::Shortcut);
        // GShard capacity factor 1.0: capacity = top_k * N / experts,
        // scaled by the compute budget so total cost matches D2FT's.
        let budget_frac = budget.compute_fraction(0.4);
        let cap = (((self.top_k * scores.n_micro) as f64 / spb as f64) * budget_frac
            / (self.top_k as f64 / spb as f64).min(1.0))
        .ceil()
        .max(1.0) as usize;
        // capacity per expert in micro-batches, bounded by the budget's
        // p_f count so cost stays comparable:
        let cap = cap.min(budget.n_full.max(1));
        for b in 0..n_blocks {
            let mut load = vec![0usize; spb];
            for i in 0..scores.n_micro {
                // gate logits: fisher signal + noise, softmax-free top-k.
                let mut logits: Vec<(f64, usize)> = (0..spb)
                    .map(|e| {
                        let k = b * spb + e;
                        let sig = scores.get(Metric::Fisher, k, i).max(0.0);
                        (sig.ln_1p() + self.rng.next_f64(), e)
                    })
                    .collect();
                logits.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                for &(_, e) in logits.iter().take(self.top_k) {
                    if load[e] < cap {
                        load[e] += 1;
                        table.set(b * spb + e, i, Op::Full);
                    }
                    // over capacity: dropped (stays Shortcut) — GShard's
                    // "skip once they hit their processing limit".
                }
            }
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::CostModel;
    use crate::cluster::workload::WorkloadTracker;

    fn book(n_subnets: usize, n_micro: usize, seed: u64) -> ScoreBook {
        let mut rng = Rng::new(seed);
        let mut b = ScoreBook::zeros(n_subnets, n_micro);
        for k in 0..n_subnets {
            for i in 0..n_micro {
                b.set(Metric::Fisher, k, i, rng.next_f64() * 5.0);
            }
        }
        b
    }

    #[test]
    fn respects_expert_capacity() {
        let mut m = MoeGshard::new(3, 6);
        let b = book(36, 5, 1);
        let budget = Budget::uniform(5, 3, 0);
        let t = m.schedule(&b, &budget);
        for k in 0..36 {
            assert!(t.count_row(k, Op::Full) <= 3, "expert {k} over capacity");
            assert_eq!(t.count_row(k, Op::ForwardOnly), 0, "gshard has no p_o");
        }
    }

    #[test]
    fn drops_overflow_samples() {
        // With top_k = 2 of 6 experts and capacity limits, some
        // (block, micro-batch) pairs end up unprocessed.
        let mut m = MoeGshard::new(5, 6);
        let b = book(36, 5, 2);
        let t = m.schedule(&b, &Budget::uniform(5, 2, 0));
        let processed: usize =
            (0..36).map(|k| t.count_row(k, Op::Full)).sum();
        // top_k * n_micro * n_blocks = 2 * 5 * 6 = 60 max routings
        assert!(processed <= 60);
        // but strictly fewer than standard fine-tuning would process:
        assert!(processed < 36 * 5);
    }

    #[test]
    fn unbalanced_workloads() {
        let mut m = MoeGshard::new(7, 6);
        let b = book(72, 5, 3);
        let t = m.schedule(&b, &Budget::uniform(5, 3, 0));
        let mut w = WorkloadTracker::new(CostModel::paper(), 72);
        w.record(&t);
        assert!(w.workload_variance() > 0.0);
    }
}
