//! Scheduling: the paper's core contribution (Algorithms 1 & 2) plus all
//! evaluated baselines behind one trait.
//!
//! A scheduler maps per-(subnet, micro-batch) contribution scores +
//! per-device budgets to a [`table::ScheduleTable`] assigning every
//! (subnet, micro-batch) pair one of `p_f` / `p_o` / `p_s`.

pub mod bilevel;
pub mod dpruning;
pub mod knapsack;
pub mod moe_gshard;
pub mod random_sched;
pub mod scaler;
pub mod table;

pub use table::{Budget, MaskPair, Op, ScheduleTable, Task};

use crate::scores::ScoreBook;

/// Common interface for D2FT and every baseline scheduler.
pub trait Scheduler {
    /// Human-readable name used in reports (matches the paper's labels).
    fn name(&self) -> &'static str;

    /// Build the schedule for one batch of `n_micro` micro-batches.
    ///
    /// `scores` carries the per-subnet, per-micro-batch contribution
    /// scores for this batch; `budget` the per-device operation budget.
    fn schedule(&mut self, scores: &ScoreBook, budget: &Budget) -> ScheduleTable;

    /// Whether this policy reads contribution scores at all. The
    /// coordinator skips the (expensive) score probes when false.
    fn needs_scores(&self) -> bool {
        true
    }
}
