//! 0/1 knapsack by dynamic programming — the paper's Algorithm 2
//! (`DPSearching`), phase 1 (value table) + phase 2 (backtrack).
//!
//! Weights are integer cost units (the cluster cost model uses
//! c_f = 2, c_b = 3 units so a full op weighs 5 — the paper's measured
//! "forward ≈ 40% of forward+backward", Table IV). Complexity is
//! O(N · C) per subnet, with N = micro-batches per batch.

/// Solve max Σ value[i]·x[i] s.t. Σ weight[i]·x[i] ≤ capacity, x ∈ {0,1}.
///
/// Returns (best value, selection bitmap). Deterministic tie-break: when
/// skipping and taking score equally, the DP *skips* (keeps earlier
/// items out), matching Algorithm 2's `T[k][i-1][w]` preference.
pub fn knapsack_01(values: &[f64], weights: &[usize], capacity: usize) -> (f64, Vec<bool>) {
    assert_eq!(values.len(), weights.len());
    let n = values.len();
    // Phase 1: full (N+1) x (C+1) table — needed for the exact phase-2
    // backtrack the paper specifies.
    let w_cols = capacity + 1;
    let mut table = vec![0.0f64; (n + 1) * w_cols];
    for i in 1..=n {
        let (wi, vi) = (weights[i - 1], values[i - 1]);
        let (prev, cur) = table.split_at_mut(i * w_cols);
        let prev_row = &prev[(i - 1) * w_cols..i * w_cols];
        let cur_row = &mut cur[..w_cols];
        for w in 0..w_cols {
            let skip = prev_row[w];
            cur_row[w] = if w >= wi {
                let take = prev_row[w - wi] + vi;
                if take > skip { take } else { skip }
            } else {
                skip
            };
        }
    }
    // Phase 2: backtrack from T[n][C].
    let mut picked = vec![false; n];
    let mut w = capacity;
    for i in (1..=n).rev() {
        if table[i * w_cols + w] != table[(i - 1) * w_cols + w] {
            picked[i - 1] = true;
            w -= weights[i - 1];
        }
    }
    (table[n * w_cols + capacity], picked)
}

/// Brute-force reference for tests (2^n subsets).
#[cfg(test)]
pub fn knapsack_brute(values: &[f64], weights: &[usize], capacity: usize) -> f64 {
    let n = values.len();
    let mut best = 0.0f64;
    for mask in 0..(1u32 << n) {
        let mut v = 0.0;
        let mut w = 0usize;
        for i in 0..n {
            if mask & (1 << i) != 0 {
                v += values[i];
                w += weights[i];
            }
        }
        if w <= capacity && v > best {
            best = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn textbook_instance() {
        // values 60/100/120, weights 10/20/30, cap 50 -> 220 (items 2,3).
        let (v, picked) = knapsack_01(&[60.0, 100.0, 120.0], &[10, 20, 30], 50);
        assert_eq!(v, 220.0);
        assert_eq!(picked, vec![false, true, true]);
    }

    #[test]
    fn zero_capacity_selects_nothing() {
        let (v, picked) = knapsack_01(&[5.0, 7.0], &[1, 1], 0);
        assert_eq!(v, 0.0);
        assert!(picked.iter().all(|&p| !p));
    }

    #[test]
    fn capacity_exceeds_total_selects_all_positive() {
        let (v, picked) = knapsack_01(&[1.0, 2.0, 3.0], &[5, 5, 5], 100);
        assert_eq!(v, 6.0);
        assert!(picked.iter().all(|&p| p));
    }

    #[test]
    fn equal_values_fill_to_capacity() {
        // The D2FT weight-magnitude backward score: same value per sample.
        let (v, picked) = knapsack_01(&[2.0; 5], &[5; 5], 15);
        assert_eq!(v, 6.0);
        assert_eq!(picked.iter().filter(|&&p| p).count(), 3);
    }

    #[test]
    fn property_matches_brute_force() {
        check("knapsack-vs-brute", 60, |g| {
            let n = g.usize_in(1, 10);
            let values: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 20.0)).collect();
            let weights: Vec<usize> = (0..n).map(|_| g.usize_in(1, 8)).collect();
            let cap = g.usize_in(0, 30);
            let (v, picked) = knapsack_01(&values, &weights, cap);
            let brute = knapsack_brute(&values, &weights, cap);
            if (v - brute).abs() > 1e-9 {
                return Err(format!("dp {v} != brute {brute}"));
            }
            // Selection must be feasible and achieve the reported value.
            let w: usize = picked.iter().zip(&weights).filter(|(p, _)| **p).map(|(_, w)| w).sum();
            let vv: f64 = picked.iter().zip(&values).filter(|(p, _)| **p).map(|(_, v)| v).sum();
            if w > cap {
                return Err(format!("infeasible selection weight {w} > {cap}"));
            }
            if (vv - v).abs() > 1e-9 {
                return Err("selection value mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_monotone_in_capacity() {
        check("knapsack-monotone", 40, |g| {
            let n = g.usize_in(1, 8);
            let values: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 10.0)).collect();
            let weights: Vec<usize> = (0..n).map(|_| g.usize_in(1, 6)).collect();
            let c = g.usize_in(0, 20);
            let (v1, _) = knapsack_01(&values, &weights, c);
            let (v2, _) = knapsack_01(&values, &weights, c + 1);
            if v2 + 1e-12 < v1 {
                return Err(format!("value decreased with capacity: {v1} -> {v2}"));
            }
            Ok(())
        });
    }
}
