//! The one versioned home of every report-JSON artifact.
//!
//! Three reports leave this crate as JSON contracts consumed outside it
//! (CI greps, dashboards, the serve control plane): the serial
//! [`TrainReport`], the distributed `DistReport`, and the per-tenant
//! [`JobReport`] the multi-tenant service emits. All three share one
//! [`SCHEMA_VERSION`] and the key-writer helpers below, and
//! `tests/dist_report_schema.rs` pins each key set exactly — adding or
//! removing a key means bumping the version and updating that golden
//! test in the same change.

use crate::coordinator::TrainReport;
#[cfg(feature = "native")]
use crate::dist::DistReport;
use crate::util::json::{arr, num, obj, s, Json};

/// Schema version shared by every report artifact. v4 unified the
/// emitters here and added the train and per-job report schemas next to
/// the dist report (previously versioned alone as v3).
pub const SCHEMA_VERSION: usize = 4;

/// `(key, number)` writer — the shared idiom of every emitter below.
fn knum(key: &'static str, v: f64) -> (&'static str, Json) {
    (key, num(v))
}

/// `(key, string)` writer.
fn kstr(key: &'static str, v: &str) -> (&'static str, Json) {
    (key, s(v))
}

/// The two schema keys every report leads with. `kind` is the artifact
/// family (`train` / `dist` / `job`).
fn schema_pair(kind: &str) -> [(&'static str, Json); 2] {
    [
        ("schema", s(&format!("d2ft-{kind}-report-v{SCHEMA_VERSION}"))),
        ("schema_version", num(SCHEMA_VERSION as f64)),
    ]
}

/// Serialize a serial [`TrainReport`] (`repro train --report-json`
/// without `--dist`). Scalars only — the loss/eval curves are run
/// artifacts, not part of the schema contract.
pub fn train_report_json(r: &TrainReport) -> Json {
    let mut pairs: Vec<(&str, Json)> = schema_pair("train").to_vec();
    pairs.extend([
        kstr("scheduler", &r.scheduler),
        kstr("backend", &r.backend),
        kstr("engine", &r.engine),
        knum("batches", r.batches as f64),
        knum("final_train_loss", r.final_train_loss),
        knum("test_top1", r.test_top1),
        knum("test_loss", r.test_loss),
        knum("compute_fraction", r.compute_fraction),
        knum("comm_fraction", r.comm_fraction),
        knum("workload_variance", r.workload_variance),
        knum("sample_count_variance", r.sample_count_variance),
        knum("mean_exec_ms", r.mean_exec_ms),
        knum("makespan_ms", r.makespan_ms),
        knum("utilization", r.utilization),
        knum("imbalance", r.imbalance),
        knum("straggler_ms", r.straggler_ms),
        knum("wall_s", r.wall_s),
        knum("calib_scale", r.calib_scale),
        knum("calib_scale_full", r.calib_scale_full),
        knum("calib_scale_fwd", r.calib_scale_fwd),
        knum("calib_epochs", r.calib_epochs as f64),
        knum("makespan_drift", r.makespan_drift),
    ]);
    obj(pairs)
}

/// Serialize a `DistReport` (the `--report-json` artifact of a dist
/// run): loss/accuracy, membership churn, byte totals, and the recovery
/// counters the chaos CI step inspects.
#[cfg(feature = "native")]
pub fn dist_report_json(r: &DistReport) -> Json {
    let membership = r
        .membership
        .iter()
        .map(|e| {
            obj(vec![
                knum("batch", e.batch as f64),
                knum("worker", e.worker as f64),
                kstr("kind", &e.kind),
            ])
        })
        .collect();
    let socket_classes = r
        .socket
        .classes()
        .map(|(name, sent, recv)| {
            obj(vec![kstr("class", name), knum("sent", sent as f64), knum("recv", recv as f64)])
        })
        .collect();
    let ring_bytes = r
        .ring_bytes
        .iter()
        .map(|&(sent, recv)| obj(vec![knum("sent", sent as f64), knum("recv", recv as f64)]))
        .collect();
    let mut pairs: Vec<(&str, Json)> = schema_pair("dist").to_vec();
    pairs.extend([
        kstr("compress", &r.compress),
        knum("workers", r.n_workers as f64),
        knum("live_workers", r.live_workers as f64),
        kstr("transport", &r.transport),
        kstr("exchange", &r.exchange),
        knum("aggregator_restarts", r.aggregator_restarts as f64),
        knum("batches", r.train.batches as f64),
        knum("epochs", r.epochs as f64),
        knum("final_train_loss", r.train.final_train_loss),
        knum("frames_corrupt", r.frames_corrupt as f64),
        knum("test_top1", r.train.test_top1),
        knum("evictions", r.evictions as f64),
        knum("joins", r.joins as f64),
        knum("reconnects", r.reconnects as f64),
        knum("resends", r.resends as f64),
        knum("reassigned_micros", r.reassigned_micros as f64),
        knum("knapsack_resolves", r.knapsack_resolves as f64),
        knum("checkpoints_written", r.checkpoints_written as f64),
        knum("grad_bytes_up", r.wire.up_bytes as f64),
        knum("grad_bytes_down", r.wire.down_bytes as f64),
        knum("socket_bytes_sent", r.socket.bytes_sent as f64),
        knum("socket_bytes_recv", r.socket.bytes_recv as f64),
        ("socket_classes", arr(socket_classes)),
        ("ring_bytes", arr(ring_bytes)),
        ("membership", arr(membership)),
    ]);
    obj(pairs)
}

/// Everything the multi-tenant service meters for one job: lifecycle,
/// per-tenant wire bytes (vs the full-state dense baseline), hot-swap
/// counts, and step-latency percentiles. Emitted per job by the serve
/// report and returned by `repro job result`.
#[derive(Clone, Debug)]
pub struct JobReport {
    /// Service-assigned job id.
    pub job_id: u64,
    /// Tenant that submitted the job (the metering key).
    pub tenant: String,
    /// Terminal lifecycle state label (`completed` / `failed`) or the
    /// live state when queried mid-run.
    pub state: String,
    /// Failure description; empty unless `state == "failed"`.
    pub error: String,
    /// LoRA adapter rank the job trained.
    pub lora_rank: usize,
    /// Admission priority the job was submitted with.
    pub priority: u32,
    /// Step quota (fine-tuning batches requested).
    pub batches_quota: usize,
    /// Fine-tuning batches actually completed.
    pub batches_done: usize,
    /// Service rounds the job was admitted into.
    pub rounds: usize,
    /// Times the job was preempted back to the queue by admission.
    pub preemptions: usize,
    /// Adapter hot-swaps onto a replica (one per admitted round).
    pub replica_swaps: usize,
    /// Bytes shipped server→replica for this job (adapter + mask
    /// state inside `job` frames).
    pub bytes_up: u64,
    /// Bytes returned replica→server (trained adapter state).
    pub bytes_down: u64,
    /// The dense baseline: full model params+momentum in f32, the
    /// traffic a non-LoRA tenant swap would have cost per round.
    pub dense_state_bytes: u64,
    /// `1 - measured/dense` over all rounds (the LoRA multiplexing
    /// win; 0 when nothing moved).
    pub adapter_savings: f64,
    /// Median per-batch step latency (ms) across the job's batches.
    pub step_ms_p50: f64,
    /// 99th-percentile per-batch step latency (ms).
    pub step_ms_p99: f64,
    /// Mean training loss over the job's fine-tuning batches.
    pub final_train_loss: f64,
    /// Test top-1 after the final batch (-1.0 until finalized — the
    /// JSON layer has no NaN).
    pub test_top1: f64,
    /// Test loss after the final batch (-1.0 until finalized).
    pub test_loss: f64,
    /// Wall-clock from submission to terminal state (ms).
    pub wall_ms: f64,
}

/// Serialize a [`JobReport`] (the per-tenant metering contract).
pub fn job_report_json(r: &JobReport) -> Json {
    let mut pairs: Vec<(&str, Json)> = schema_pair("job").to_vec();
    pairs.extend([
        knum("job_id", r.job_id as f64),
        kstr("tenant", &r.tenant),
        kstr("state", &r.state),
        kstr("error", &r.error),
        knum("lora_rank", r.lora_rank as f64),
        knum("priority", r.priority as f64),
        knum("batches_quota", r.batches_quota as f64),
        knum("batches_done", r.batches_done as f64),
        knum("rounds", r.rounds as f64),
        knum("preemptions", r.preemptions as f64),
        knum("replica_swaps", r.replica_swaps as f64),
        knum("bytes_up", r.bytes_up as f64),
        knum("bytes_down", r.bytes_down as f64),
        knum("dense_state_bytes", r.dense_state_bytes as f64),
        knum("adapter_savings", r.adapter_savings),
        knum("step_ms_p50", r.step_ms_p50),
        knum("step_ms_p99", r.step_ms_p99),
        knum("final_train_loss", r.final_train_loss),
        knum("test_top1", r.test_top1),
        knum("test_loss", r.test_loss),
        knum("wall_ms", r.wall_ms),
    ]);
    obj(pairs)
}
