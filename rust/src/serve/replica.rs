//! The replica side of the multi-tenant service: one long-lived worker
//! process (or thread) that keeps *base* model state resident and
//! hot-swaps per-tenant LoRA adapter state between rounds.
//!
//! A replica owns one [`NativeBackend`] per `(model, lora_rank, seed)`
//! combination it has served — the frozen base parameters and the
//! momentum slots of non-trainable tensors never change under LoRA
//! fine-tuning (the optimizer skips frozen slots entirely), so swapping
//! a tenant in is exactly: install its trainable params + momentum,
//! run its batches, export trainable state back. Only adapter-sized
//! blobs ever cross the wire; the dense base never moves after replica
//! start. That is the serving-side payoff of the paper's LoRA + partial
//! (mask-scheduled) fine-tuning: many tenants multiplex one resident
//! model.
//!
//! Determinism contract: a job's arithmetic is a pure function of its
//! `JobSpec`. The replica rebuilds datasets, batch order, the pretrain
//! trajectory, and (on the fresh round) the probe → score → schedule
//! pipeline from the spec alone, and the F32 dense codec round-trips
//! state bit-exactly — so a job sliced into rounds across replicas
//! produces *bitwise* the same adapter as the same spec run in one
//! uninterrupted pass. `tests/serve.rs` pins this.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::backend::native::{NativeBackend, NativeSpec};
use crate::backend::Backend;
use crate::config::JobSpec;
use crate::coordinator::{build_scheduler, prepare_run, TrainerConfig};
use crate::data::{Batcher, Dataset};
use crate::dist::grads::GradCodec;
use crate::dist::proto::{self, JobDoneMsg, JobRoundMsg};
use crate::dist::transport::Transport;
use crate::metrics::Meter;
use crate::partition::Partition;
use crate::schedule::MaskPair;
use crate::scores::ScoreBook;
use crate::tensor::Tensor;

/// One resident backend: base params stay put, tenants swap through.
struct Slot {
    backend: NativeBackend,
    codec: GradCodec,
    /// Trainable state exactly as constructed — the start line every
    /// fresh job round is reset to before its own pretraining.
    pristine_params: Vec<Tensor>,
    pristine_momentum: Vec<Tensor>,
    /// Full-model params + momentum in f32 bytes: the dense baseline a
    /// non-LoRA swap would ship, reported for the metering denominator.
    dense_state_bytes: u64,
}

/// Per-job reusable setup (partition + generated datasets), rebuilt
/// deterministically from the spec and cached across the job's rounds.
struct JobData {
    cfg: TrainerConfig,
    partition: Partition,
    train: Dataset,
    test: Dataset,
}

/// Replica-resident state across rounds: backend slots keyed by
/// `(model, lora_rank, seed)` and job setup keyed by job id.
#[derive(Default)]
struct ReplicaState {
    slots: HashMap<(String, usize, u64), Slot>,
    data: HashMap<u64, JobData>,
}

impl ReplicaState {
    fn slot_for(&mut self, spec: &JobSpec, lora_rank: usize) -> Result<&mut Slot> {
        let key = (spec.model.to_ascii_lowercase(), lora_rank, spec.seed);
        if !self.slots.contains_key(&key) {
            let nspec = NativeSpec::preset(&spec.model)?;
            anyhow::ensure!(
                nspec.lora_ranks.contains(&lora_rank),
                "lora rank {lora_rank} not in the {:?} preset's supported set {:?}",
                spec.model,
                nspec.lora_ranks
            );
            let backend = NativeBackend::new(&nspec, lora_rank, nspec.micro_batch, spec.seed);
            let codec = GradCodec::new(&backend);
            let (pristine_params, pristine_momentum) = backend.export_trainable();
            let elems: u64 =
                (0..backend.n_param_tensors()).map(|i| backend.param_elems(i) as u64).sum();
            let dense_state_bytes = elems * 4 * 2;
            self.slots.insert(
                key.clone(),
                Slot { backend, codec, pristine_params, pristine_momentum, dense_state_bytes },
            );
        }
        Ok(self.slots.get_mut(&key).unwrap())
    }

    fn data_for(&mut self, job_id: u64, spec: &JobSpec, mc_slot: &Slot) -> Result<&JobData> {
        if !self.data.contains_key(&job_id) {
            let cfg = spec.to_trainer_config()?;
            let setup = prepare_run(mc_slot.backend.config(), &cfg)?;
            self.data.insert(
                job_id,
                JobData { cfg, partition: setup.partition, train: setup.train, test: setup.test },
            );
        }
        Ok(self.data.get(&job_id).unwrap())
    }

    /// Execute one admitted round, converting any failure into an
    /// `ok: false` reply — a bad spec must fail *that job*, never the
    /// replica loop serving every other tenant.
    fn run_round(&mut self, msg: &JobRoundMsg) -> JobDoneMsg {
        match self.try_round(msg) {
            Ok(done) => done,
            Err(e) => JobDoneMsg {
                job_id: msg.job_id,
                ok: false,
                error: format!("{e:#}"),
                batches_done: 0,
                losses: Vec::new(),
                n_correct: 0,
                n_seen: 0,
                step_ms: Vec::new(),
                masks: Vec::new(),
                params: Vec::new(),
                momentum: Vec::new(),
                dense_state_bytes: 0,
                test_top1: -1.0,
                test_loss: -1.0,
            },
        }
    }

    fn try_round(&mut self, msg: &JobRoundMsg) -> Result<JobDoneMsg> {
        let spec = JobSpec::parse(&msg.spec_json)?;
        anyhow::ensure!(
            spec.lora_rank == msg.lora_rank,
            "frame lora rank {} disagrees with spec rank {}",
            msg.lora_rank,
            spec.lora_rank
        );
        // Split the borrow: take the slot out, run, put it back — the
        // round needs the slot mutably and the data cache immutably.
        let key = {
            let _ = self.slot_for(&spec, msg.lora_rank)?;
            (spec.model.to_ascii_lowercase(), msg.lora_rank, spec.seed)
        };
        let mut slot = self.slots.remove(&key).unwrap();
        let result = self.round_on_slot(&mut slot, msg, &spec);
        self.slots.insert(key, slot);
        if msg.finalize && result.as_ref().map(|d| d.ok).unwrap_or(false) {
            self.data.remove(&msg.job_id);
        }
        result
    }

    fn round_on_slot(
        &mut self,
        slot: &mut Slot,
        msg: &JobRoundMsg,
        spec: &JobSpec,
    ) -> Result<JobDoneMsg> {
        self.data_for(msg.job_id, spec, slot)?;
        let data = self.data.get(&msg.job_id).unwrap();
        let cfg = &data.cfg;
        let mb = slot.backend.micro_batch();
        let micros_per_batch = cfg.micros_per_batch;

        // --- install state -------------------------------------------------
        let masks: Vec<MaskPair>;
        if msg.fresh {
            slot.backend.import_trainable(&slot.pristine_params, &slot.pristine_momentum)?;
            pretrain(&mut slot.backend, cfg)?;
            masks = solve_schedule(&mut slot.backend, cfg, &data.partition, &data.train)?;
        } else {
            anyhow::ensure!(
                msg.masks.len() == micros_per_batch,
                "resumed round carries {} masks for {} micro-batches",
                msg.masks.len(),
                micros_per_batch
            );
            let params = slot.codec.decode_dense(&msg.params)?;
            let momentum = slot.codec.decode_dense(&msg.momentum)?;
            slot.backend.import_trainable(&params, &momentum)?;
            masks = msg.masks.clone();
        }

        // --- run the admitted batch range ----------------------------------
        let end = msg.start_batch + msg.n_batches;
        let mut g = 0usize;
        let mut losses = Vec::new();
        let mut step_ms = Vec::new();
        let mut n_correct = 0u64;
        let mut n_seen = 0u64;
        let mut batches_done = 0usize;
        'outer: while g < end {
            // Same order every epoch — identical to the serial Trainer's
            // epoch loop, which is what makes round-sliced ≡ one-pass.
            let mut batcher = Batcher::new(&data.train, mb, micros_per_batch, cfg.seed);
            let mut any = false;
            while let Some(micros) = batcher.next_batch() {
                any = true;
                if g >= end {
                    break 'outer;
                }
                if g >= msg.start_batch {
                    let t0 = Instant::now();
                    for ((x, y), m) in micros.iter().zip(&masks) {
                        let out = slot.backend.step(x, y, m, cfg.lr)?;
                        losses.push(out.loss);
                        n_correct += out.n_correct as u64;
                        n_seen += mb as u64;
                    }
                    step_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    batches_done += 1;
                }
                g += 1;
            }
            anyhow::ensure!(
                any,
                "train split yields zero full batches \
                 ({} examples < {} micro-batch x {} micros)",
                data.train.len(),
                mb,
                micros_per_batch
            );
        }

        // --- finalize + export ---------------------------------------------
        let (test_top1, test_loss) = if msg.finalize {
            evaluate(&slot.backend, &data.test)?
        } else {
            (-1.0, -1.0)
        };
        let (params, momentum) = slot.backend.export_trainable();
        Ok(JobDoneMsg {
            job_id: msg.job_id,
            ok: true,
            error: String::new(),
            batches_done,
            losses,
            n_correct,
            n_seen,
            step_ms,
            masks,
            params: slot.codec.encode_dense(&params),
            momentum: slot.codec.encode_dense(&momentum),
            dense_state_bytes: slot.dense_state_bytes,
            test_top1,
            test_loss,
        })
    }
}

/// Synthetic pre-training from the pristine snapshot — mirrors the
/// serial `Trainer::pretrain` exactly (same dataset seed offset, ones
/// masks, per-micro updates, momentum reset at the boundary).
fn pretrain(backend: &mut NativeBackend, cfg: &TrainerConfig) -> Result<()> {
    if cfg.pretrain_batches == 0 {
        return Ok(());
    }
    let (img, depth, heads) = {
        let mc = backend.config();
        (mc.img_size, mc.depth, mc.heads)
    };
    let mb = backend.micro_batch();
    let n = cfg.pretrain_batches * cfg.micros_per_batch * mb;
    let pre = crate::data::DatasetSpec::preset(
        crate::data::SyntheticKind::Pretrain,
        img,
        n,
        cfg.seed ^ 0x5A,
    )
    .generate("train");
    let mut batcher = Batcher::new(&pre, mb, cfg.micros_per_batch, cfg.seed);
    let ones = MaskPair::ones(depth, heads);
    while let Some(micros) = batcher.next_batch() {
        for (x, y) in &micros {
            backend.step(x, y, &ones, cfg.lr)?;
        }
    }
    backend.reset_momentum()
}

/// The select-once schedule solve of a fresh round: probe the first
/// fine-tuning batch, build the score book, run the spec's scheduler
/// once, and freeze the per-micro masks for the job's lifetime (the
/// paper computes contribution scores once before fine-tuning, §II-A3).
fn solve_schedule(
    backend: &mut NativeBackend,
    cfg: &TrainerConfig,
    partition: &Partition,
    train: &Dataset,
) -> Result<Vec<MaskPair>> {
    let mb = backend.micro_batch();
    let mut batcher = Batcher::new(train, mb, cfg.micros_per_batch, cfg.seed);
    let micros = batcher.next_batch().ok_or_else(|| {
        anyhow::anyhow!(
            "train split yields zero full batches ({} examples < {} x {})",
            train.len(),
            mb,
            cfg.micros_per_batch
        )
    })?;
    let mut scheduler = build_scheduler(cfg.scheduler, cfg.scores, cfg.seed);
    let book = if scheduler.needs_scores() {
        let probes: Vec<Tensor> =
            micros.iter().map(|(x, y)| backend.score_probe(x, y)).collect::<Result<_>>()?;
        ScoreBook::from_probes(partition, &probes)
    } else {
        ScoreBook::zeros(partition.n_subnets(), micros.len())
    };
    let table = scheduler.schedule(&book, &cfg.budget);
    Ok((0..micros.len()).map(|i| table.masks_for_micro(partition, i)).collect())
}

/// Full-forward evaluation over the job's test split (mirrors the
/// serial `Trainer::evaluate`).
fn evaluate(backend: &NativeBackend, test: &Dataset) -> Result<(f64, f64)> {
    let mb = backend.eval_micro_batch();
    let mut meter = Meter::new();
    let mut i = 0;
    while i + mb <= test.len() {
        let idxs: Vec<usize> = (i..i + mb).collect();
        let (x, y) = test.gather(&idxs);
        let out = backend.eval(&x, &y, None)?;
        meter.push(out.loss, out.n_correct, mb);
        i += mb;
    }
    Ok((meter.top1(), meter.mean_loss()))
}

/// Serve one link until shutdown: decode each admitted round, run it,
/// reply with a [`JobDoneMsg`]. The server may pack several rounds onto
/// this replica back-to-back (one frame per job, in dispatch order);
/// they execute sequentially in frame order. Returns on a clean
/// [`proto::TAG_SHUTDOWN`]; errors out on link failure or protocol
/// desync — job-level failures travel inside the reply instead.
pub fn run_replica(mut transport: Box<dyn Transport>) -> Result<()> {
    let mut state = ReplicaState::default();
    loop {
        let frame = transport.recv_blob()?;
        match proto::peek_tag(&frame)? {
            proto::TAG_JOB_ROUND => {
                let msg = proto::decode_job_round(&frame)?;
                let done = state.run_round(&msg);
                let mut out = Vec::new();
                proto::encode_job_done(&done, &mut out);
                transport.send_blob(out)?;
            }
            proto::TAG_SHUTDOWN => return Ok(()),
            other => anyhow::bail!("replica got unexpected frame tag {other:#x}"),
        }
    }
}
