//! Multi-tenant LoRA fine-tuning service over the dist transport.
//!
//! The serving claim this layer demonstrates: because D2FT fine-tunes
//! LoRA adapters under per-head mask schedules — the dense base model
//! is frozen — one resident replica fleet can time-multiplex many
//! tenants by hot-swapping only adapter + mask state between rounds.
//! Three pieces:
//!
//! - [`admission`]: the round-based admission controller. Live
//!   replicas are knapsack bins, tenant jobs are items (priority-then-
//!   FIFO values), solved per round with the scheduler's own
//!   `knapsack_01` — a pure, deterministic plan.
//! - [`replica`]: the worker loop. Keeps one backend resident per
//!   `(model, rank, seed)`, installs a tenant's adapter state, runs its
//!   admitted batch range bit-deterministically from the `JobSpec`, and
//!   ships trained state back.
//! - [`server`]: the job queue, scheduler thread, per-tenant metering,
//!   and the newline-JSON control plane behind `repro serve` /
//!   `repro job`.
//!
//! A [`crate::config::JobSpec`] enters via [`ServerHandle::submit`] (or
//! the control plane), moves Queued → Running ⇄ Preempted → Completed /
//! Failed, and exits as a [`crate::report::JobReport`] whose byte
//! meters quantify the adapter-vs-dense traffic savings.

pub mod admission;
pub mod replica;
pub mod server;

pub use admission::{plan_round, Bin, Candidate, RoundPlan};
pub use replica::run_replica;
pub use server::{serve, JobState, ServeConfig, ServerHandle};
