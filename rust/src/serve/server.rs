//! The service side of multi-tenant fine-tuning: a job queue, the
//! round-based admission scheduler, replica link management, and the
//! per-tenant metering report.
//!
//! Lifecycle of a job: `submit` validates the [`JobSpec`] against the
//! fleet (model preset, adapter rank, tenant cap) and queues it; every
//! service *round* the admission controller packs waiting jobs onto
//! live replicas ([`crate::serve::admission::plan_round`] — devices are
//! bins, tenant jobs are items); admitted jobs get a tenant-tagged
//! `JobRound` frame carrying only their adapter + mask state (hot-swap
//! — the resident base model never moves); the replies fold trained
//! state, losses, and step latencies back into the job record. A job
//! that loses its slot to a higher-priority arrival is *preempted* at
//! the round boundary — its state lives in the server between rounds,
//! so resumption is exact. `Completed` / `Failed` are terminal and wake
//! every waiter.
//!
//! Everything the service meters per tenant — frame bytes up/down
//! against the dense full-state baseline, hot-swap counts, step-latency
//! percentiles — lands in a [`JobReport`] and the aggregate
//! [`ServerHandle::report_json`] artifact the CI smoke step inspects.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::native::NativeSpec;
use crate::config::JobSpec;
use crate::dist::grads::BufPool;
use crate::dist::proto::{self, JobDoneMsg, JobRoundMsg};
use crate::dist::transport::{self, TcpTransport, Transport};
use crate::obs::metrics::Registry;
use crate::report::{job_report_json, JobReport};
use crate::schedule::MaskPair;
use crate::serve::admission::{plan_round, Bin, Candidate};
use crate::serve::replica::run_replica;
use crate::util::json::{arr, num, obj, s, Json};
use crate::{info, warn_};

/// How the service runs (see `repro serve`). Plain data — construct,
/// adjust fields, pass to [`serve`].
#[derive(Clone)]
pub struct ServeConfig {
    /// Model preset every replica keeps resident (`mini|tiny|small`);
    /// submissions naming a different preset are rejected.
    pub model: String,
    /// Replicas (worker backends) to run.
    pub workers: usize,
    /// Cap on *distinct* tenants with non-terminal jobs at once.
    pub max_tenants: usize,
    /// Max fine-tuning batches one admitted round runs per job.
    pub round_batches: usize,
    /// Per-replica micro-step capacity per round (the knapsack bin
    /// size; a job whose single batch exceeds this can never run).
    pub round_micros: usize,
    /// Route replica links over real TCP sockets (loopback) instead of
    /// in-process channels — same bytes, real wire.
    pub tcp: bool,
    /// Control-plane listen address (e.g. `127.0.0.1:0`); `None` runs
    /// without a TCP control plane (library/API use only).
    pub control: Option<String>,
    /// Metrics registry for per-tenant byte counters and step-latency
    /// histograms; `None` meters into the job records only.
    pub metrics: Option<Arc<Registry>>,
}

impl ServeConfig {
    /// Defaults: `tiny` model, 2 replicas, 4 tenants, 4-batch rounds
    /// with a 32-micro-step bin, in-process channel links, no control
    /// plane, no registry.
    pub fn new() -> ServeConfig {
        ServeConfig {
            model: "tiny".to_string(),
            workers: 2,
            max_tenants: 4,
            round_batches: 4,
            round_micros: 32,
            tcp: false,
            control: None,
            metrics: None,
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self::new()
    }
}

/// Where a job is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Waiting for first admission.
    Queued,
    /// Admitted this round (or holding state between rounds).
    Running,
    /// Lost its slot to admission; resumes exactly where it stopped.
    Preempted,
    /// Step quota reached; final evaluation done. Terminal.
    Completed,
    /// Rejected, oversized, or broken (spec error, dead replica).
    /// Terminal; see the report's `error`.
    Failed,
}

impl JobState {
    /// Report label (`queued` / `running` / ...).
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Preempted => "preempted",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
        }
    }

    /// Whether the job still wants admission.
    fn active(&self) -> bool {
        matches!(self, JobState::Queued | JobState::Running | JobState::Preempted)
    }
}

/// Everything the server holds for one job between rounds.
struct Job {
    id: u64,
    seq: u64,
    spec: JobSpec,
    spec_json: String,
    state: JobState,
    error: String,
    batches_done: usize,
    rounds: usize,
    preemptions: usize,
    swaps: usize,
    bytes_up: u64,
    bytes_down: u64,
    dense_state_bytes: u64,
    losses: Vec<f32>,
    step_ms: Vec<f64>,
    masks: Vec<MaskPair>,
    params: Vec<u8>,
    momentum: Vec<u8>,
    test_top1: f64,
    test_loss: f64,
    submitted: Instant,
    wall_ms: f64,
}

/// Mutex-guarded server state.
struct Shared {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    next_seq: u64,
    shutdown: bool,
}

/// The server core shared by the API handle, the scheduler thread, and
/// the control plane.
struct Inner {
    state: Mutex<Shared>,
    cv: Condvar,
    cfg: ServeConfig,
    /// Replica micro-batch size (from the model preset) — submit-time
    /// dataset validation needs it.
    micro_batch: usize,
}

impl Inner {
    fn submit(&self, spec: &JobSpec) -> Result<u64> {
        spec.validate()?;
        anyhow::ensure!(
            spec.model.eq_ignore_ascii_case(&self.cfg.model),
            "this service hosts the {:?} preset; job asks for {:?}",
            self.cfg.model,
            spec.model
        );
        anyhow::ensure!(
            spec.lora_rank >= 1,
            "rank 0 is full fine-tuning — the service multiplexes LoRA adapters \
             (pick a rank from the model's supported set)"
        );
        anyhow::ensure!(
            spec.train_size >= self.micro_batch * spec.micros_per_batch,
            "train_size {} yields zero full batches ({} micro-batch x {} micros)",
            spec.train_size,
            self.micro_batch,
            spec.micros_per_batch
        );
        let mut st = self.state.lock().expect("serve state lock");
        anyhow::ensure!(!st.shutdown, "service is shutting down");
        let active_tenants: std::collections::BTreeSet<&str> = st
            .jobs
            .values()
            .filter(|j| j.state.active())
            .map(|j| j.spec.tenant.as_str())
            .collect();
        anyhow::ensure!(
            active_tenants.contains(spec.tenant.as_str())
                || active_tenants.len() < self.cfg.max_tenants,
            "tenant cap reached ({} active, max {})",
            active_tenants.len(),
            self.cfg.max_tenants
        );
        let id = st.next_id;
        st.next_id += 1;
        let seq = st.next_seq;
        st.next_seq += 1;
        st.jobs.insert(
            id,
            Job {
                id,
                seq,
                spec: spec.clone(),
                spec_json: spec.to_json().to_string_compact(),
                state: JobState::Queued,
                error: String::new(),
                batches_done: 0,
                rounds: 0,
                preemptions: 0,
                swaps: 0,
                bytes_up: 0,
                bytes_down: 0,
                dense_state_bytes: 0,
                losses: Vec::new(),
                step_ms: Vec::new(),
                masks: Vec::new(),
                params: Vec::new(),
                momentum: Vec::new(),
                test_top1: -1.0,
                test_loss: -1.0,
                submitted: Instant::now(),
                wall_ms: 0.0,
            },
        );
        self.cv.notify_all();
        Ok(id)
    }

    fn report(&self, id: u64) -> Option<JobReport> {
        let st = self.state.lock().expect("serve state lock");
        st.jobs.get(&id).map(job_report)
    }

    fn wait(&self, id: u64, timeout: Duration) -> Result<JobReport> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("serve state lock");
        loop {
            match st.jobs.get(&id) {
                None => anyhow::bail!("no such job {id}"),
                Some(j) if !j.state.active() => return Ok(job_report(j)),
                Some(_) => {}
            }
            let left = deadline.saturating_duration_since(Instant::now());
            anyhow::ensure!(!left.is_zero(), "timed out waiting for job {id}");
            let (guard, _) = self.cv.wait_timeout(st, left).expect("serve state lock");
            st = guard;
        }
    }

    fn final_state(&self, id: u64) -> Option<(Vec<u8>, Vec<u8>)> {
        let st = self.state.lock().expect("serve state lock");
        st.jobs
            .get(&id)
            .filter(|j| j.state == JobState::Completed)
            .map(|j| (j.params.clone(), j.momentum.clone()))
    }

    fn report_json(&self) -> Json {
        let st = self.state.lock().expect("serve state lock");
        let jobs: Vec<Json> =
            st.jobs.values().map(|j| job_report_json(&job_report(j))).collect();
        let mut tenants: BTreeMap<&str, (u64, u64, usize)> = BTreeMap::new();
        for j in st.jobs.values() {
            let e = tenants.entry(j.spec.tenant.as_str()).or_default();
            e.0 += j.bytes_up;
            e.1 += j.bytes_down;
            e.2 += 1;
        }
        let tenants: Vec<Json> = tenants
            .into_iter()
            .map(|(t, (up, down, n))| {
                obj(vec![
                    ("tenant", s(t)),
                    ("bytes_up", num(up as f64)),
                    ("bytes_down", num(down as f64)),
                    ("jobs", num(n as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("model", s(&self.cfg.model)),
            ("workers", num(self.cfg.workers as f64)),
            ("jobs", arr(jobs)),
            ("tenants", arr(tenants)),
        ])
    }

    fn request_shutdown(&self) {
        let mut st = self.state.lock().expect("serve state lock");
        st.shutdown = true;
        self.cv.notify_all();
    }

    fn shutdown_requested(&self) -> bool {
        self.state.lock().expect("serve state lock").shutdown
    }

    /// Fail every non-terminal job with `why` (fleet gone, shutdown).
    fn fail_active(&self, why: &str) {
        let mut st = self.state.lock().expect("serve state lock");
        for j in st.jobs.values_mut() {
            if j.state.active() {
                j.state = JobState::Failed;
                j.error = why.to_string();
                j.wall_ms = j.submitted.elapsed().as_secs_f64() * 1e3;
            }
        }
        self.cv.notify_all();
    }
}

/// Batches the job's next admitted round would run: bounded by its
/// remaining quota, the round cap, and what fits one bin.
fn round_len(job: &Job, cfg: &ServeConfig) -> usize {
    let remaining = job.spec.batches.saturating_sub(job.batches_done);
    let fits_bin = cfg.round_micros / job.spec.micros_per_batch.max(1);
    remaining.min(cfg.round_batches).min(fits_bin)
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn job_report(j: &Job) -> JobReport {
    let mut sorted = j.step_ms.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite step latencies"));
    let moved = (j.bytes_up + j.bytes_down) as f64;
    let dense = 2.0 * j.rounds as f64 * j.dense_state_bytes as f64;
    let adapter_savings =
        if dense > 0.0 { (1.0 - moved / dense).max(0.0) } else { 0.0 };
    let final_train_loss = if j.losses.is_empty() {
        0.0
    } else {
        j.losses.iter().map(|&l| l as f64).sum::<f64>() / j.losses.len() as f64
    };
    let wall_ms = if j.state.active() {
        j.submitted.elapsed().as_secs_f64() * 1e3
    } else {
        j.wall_ms
    };
    JobReport {
        job_id: j.id,
        tenant: j.spec.tenant.clone(),
        state: j.state.label().to_string(),
        error: j.error.clone(),
        lora_rank: j.spec.lora_rank,
        priority: j.spec.priority,
        batches_quota: j.spec.batches,
        batches_done: j.batches_done,
        rounds: j.rounds,
        preemptions: j.preemptions,
        replica_swaps: j.swaps,
        bytes_up: j.bytes_up,
        bytes_down: j.bytes_down,
        dense_state_bytes: j.dense_state_bytes,
        adapter_savings,
        step_ms_p50: pct(&sorted, 0.50),
        step_ms_p99: pct(&sorted, 0.99),
        final_train_loss,
        test_top1: j.test_top1,
        test_loss: j.test_loss,
        wall_ms,
    }
}

/// Metric-name-safe tenant id (the registry has no label support, so
/// per-tenant series are name-mangled).
fn sanitize(tenant: &str) -> String {
    tenant
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// One frame headed to one replica this round.
struct Dispatch {
    job_id: u64,
    replica: usize,
    frame: Vec<u8>,
}

/// The admission/dispatch loop (one thread). Owns the replica links.
fn scheduler_loop(inner: Arc<Inner>, mut links: Vec<Option<Box<dyn Transport>>>) {
    loop {
        // --- gather this round's candidates --------------------------------
        let (cands, shutdown) = {
            let st = inner.state.lock().expect("serve state lock");
            let cands: Vec<Candidate> = st
                .jobs
                .values()
                .filter(|j| j.state.active())
                .map(|j| Candidate {
                    job_id: j.id,
                    seq: j.seq,
                    priority: j.spec.priority,
                    micros: j.spec.micros_per_batch * round_len(j, &inner.cfg).max(1),
                    running: j.state == JobState::Running,
                })
                .collect();
            (cands, st.shutdown)
        };
        if shutdown {
            if !cands.is_empty() {
                inner.fail_active("service shut down before the job finished");
            }
            break;
        }
        if cands.is_empty() {
            let st = inner.state.lock().expect("serve state lock");
            let _ = inner.cv.wait_timeout(st, Duration::from_millis(50));
            continue;
        }
        let bins: Vec<Bin> = links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_some())
            .map(|(replica, _)| Bin { replica, capacity_micros: inner.cfg.round_micros })
            .collect();
        if bins.is_empty() {
            inner.fail_active("every replica link is dead");
            break;
        }
        let plan = plan_round(&cands, &bins);

        // --- apply the plan and build the dispatch frames ------------------
        let mut dispatches: Vec<Dispatch> = Vec::new();
        {
            let mut st = inner.state.lock().expect("serve state lock");
            for id in &plan.oversized {
                if let Some(j) = st.jobs.get_mut(id) {
                    j.state = JobState::Failed;
                    j.error = format!(
                        "one batch of {} micro-steps exceeds the {}-micro-step \
                         round capacity of every replica",
                        j.spec.micros_per_batch, inner.cfg.round_micros
                    );
                    j.wall_ms = j.submitted.elapsed().as_secs_f64() * 1e3;
                }
            }
            for id in &plan.preempted {
                if let Some(j) = st.jobs.get_mut(id) {
                    j.state = JobState::Preempted;
                    j.preemptions += 1;
                }
            }
            for &(id, replica) in &plan.admitted {
                let j = st.jobs.get_mut(&id).expect("admitted job exists");
                let n_batches = round_len(j, &inner.cfg);
                let fresh = j.params.is_empty();
                let finalize = j.batches_done + n_batches >= j.spec.batches;
                let msg = JobRoundMsg {
                    job_id: id,
                    tenant: j.spec.tenant.clone(),
                    lora_rank: j.spec.lora_rank,
                    fresh,
                    finalize,
                    start_batch: j.batches_done,
                    n_batches,
                    spec_json: j.spec_json.clone(),
                    masks: if fresh { Vec::new() } else { j.masks.clone() },
                    params: if fresh { Vec::new() } else { j.params.clone() },
                    momentum: if fresh { Vec::new() } else { j.momentum.clone() },
                };
                let mut frame = Vec::new();
                proto::encode_job_round(&msg, &mut frame);
                j.state = JobState::Running;
                j.rounds += 1;
                j.swaps += 1;
                j.bytes_up += frame.len() as u64;
                if let Some(reg) = &inner.cfg.metrics {
                    reg.inc(
                        &format!("serve_tenant_{}_bytes_up", sanitize(&j.spec.tenant)),
                        frame.len() as u64,
                    );
                    reg.inc("serve_rounds_total", 1);
                }
                dispatches.push(Dispatch { job_id: id, replica, frame });
            }
            inner.cv.notify_all();
        }
        if dispatches.is_empty() {
            // Plan admitted nothing (all oversized/preempted churn); the
            // state changes above are the round's only effect.
            continue;
        }

        // --- ship all frames, then collect one reply per frame -------------
        let mut per: Vec<Vec<usize>> = vec![Vec::new(); links.len()];
        for (di, d) in dispatches.iter().enumerate() {
            per[d.replica].push(di);
        }
        for (r, idxs) in per.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let mut pending = idxs.clone();
            let mut failed: Option<String> = None;
            if let Some(link) = links[r].as_mut() {
                for &di in idxs {
                    let frame = std::mem::take(&mut dispatches[di].frame);
                    if let Err(e) = link.send_blob(frame) {
                        failed = Some(format!("replica {r} link failed on send: {e:#}"));
                        break;
                    }
                }
                if failed.is_none() {
                    for &di in idxs {
                        let reply = match link.recv_blob() {
                            Ok(b) => b,
                            Err(e) => {
                                failed =
                                    Some(format!("replica {r} link failed on recv: {e:#}"));
                                break;
                            }
                        };
                        let done = match proto::decode_job_done(&reply) {
                            Ok(d) => d,
                            Err(e) => {
                                failed = Some(format!("replica {r} protocol desync: {e:#}"));
                                break;
                            }
                        };
                        if done.job_id != dispatches[di].job_id {
                            failed = Some(format!(
                                "replica {r} answered job {} out of order (expected {})",
                                done.job_id, dispatches[di].job_id
                            ));
                            break;
                        }
                        pending.retain(|&p| p != di);
                        fold_reply(&inner, &done, reply.len() as u64);
                    }
                }
            }
            if let Some(why) = failed {
                warn_!("{why}");
                links[r] = None;
                let mut st = inner.state.lock().expect("serve state lock");
                for &di in &pending {
                    if let Some(j) = st.jobs.get_mut(&dispatches[di].job_id) {
                        if j.state.active() {
                            j.state = JobState::Failed;
                            j.error = why.clone();
                            j.wall_ms = j.submitted.elapsed().as_secs_f64() * 1e3;
                        }
                    }
                }
                inner.cv.notify_all();
            }
        }
    }

    // Drain: clean shutdown frame to every live replica.
    for link in links.iter_mut().flatten() {
        let mut f = Vec::new();
        proto::encode_ctrl(proto::TAG_SHUTDOWN, &mut f);
        let _ = link.send_blob(f);
    }
}

/// Fold one replica reply into its job record.
fn fold_reply(inner: &Inner, done: &JobDoneMsg, reply_bytes: u64) {
    if let Some(reg) = &inner.cfg.metrics {
        for &ms in &done.step_ms {
            reg.observe("serve_step_ms", ms);
        }
    }
    let mut st = inner.state.lock().expect("serve state lock");
    let Some(j) = st.jobs.get_mut(&done.job_id) else {
        return;
    };
    j.bytes_down += reply_bytes;
    if let Some(reg) = &inner.cfg.metrics {
        reg.inc(
            &format!("serve_tenant_{}_bytes_down", sanitize(&j.spec.tenant)),
            reply_bytes,
        );
    }
    if !done.ok {
        j.state = JobState::Failed;
        j.error = done.error.clone();
        j.wall_ms = j.submitted.elapsed().as_secs_f64() * 1e3;
    } else {
        j.batches_done += done.batches_done;
        j.losses.extend_from_slice(&done.losses);
        j.step_ms.extend_from_slice(&done.step_ms);
        if j.masks.is_empty() {
            j.masks = done.masks.clone();
        }
        j.params = done.params.clone();
        j.momentum = done.momentum.clone();
        j.dense_state_bytes = done.dense_state_bytes;
        if done.test_top1 >= 0.0 {
            j.test_top1 = done.test_top1;
            j.test_loss = done.test_loss;
        }
        if j.batches_done >= j.spec.batches {
            j.state = JobState::Completed;
            j.wall_ms = j.submitted.elapsed().as_secs_f64() * 1e3;
        }
    }
    inner.cv.notify_all();
}

/// A running service: submit jobs, await reports, shut down. Dropping
/// the handle without [`ServerHandle::shutdown`] aborts the process's
/// replica threads unjoined — call shutdown.
pub struct ServerHandle {
    inner: Arc<Inner>,
    scheduler: Option<JoinHandle<()>>,
    replicas: Vec<JoinHandle<()>>,
    control: Option<JoinHandle<()>>,
    control_addr: Option<String>,
}

impl ServerHandle {
    /// Queue a job. Validates the spec against the fleet and the tenant
    /// cap; returns the job id.
    pub fn submit(&self, spec: &JobSpec) -> Result<u64> {
        self.inner.submit(spec)
    }

    /// Current metering report for a job (`None`: unknown id).
    pub fn report(&self, id: u64) -> Option<JobReport> {
        self.inner.report(id)
    }

    /// Block until the job reaches a terminal state and return its
    /// report; errors on timeout or unknown id.
    pub fn wait(&self, id: u64, timeout: Duration) -> Result<JobReport> {
        self.inner.wait(id, timeout)
    }

    /// The completed job's trained adapter state `(params, momentum)`
    /// as codec blobs — the bitwise-isolation probe the tests compare.
    pub fn final_state(&self, id: u64) -> Option<(Vec<u8>, Vec<u8>)> {
        self.inner.final_state(id)
    }

    /// Aggregate service report: every job's report plus per-tenant
    /// byte totals.
    pub fn report_json(&self) -> Json {
        self.inner.report_json()
    }

    /// Control-plane address when one is listening (pass to
    /// `repro job --connect`).
    pub fn control_addr(&self) -> Option<&str> {
        self.control_addr.as_deref()
    }

    /// Block until a control-plane client requests shutdown (no-op
    /// without a control plane).
    pub fn wait_for_shutdown_request(&mut self) {
        if let Some(h) = self.control.take() {
            let _ = h.join();
        }
    }

    /// Stop the service: drain the scheduler, shut replicas down, join
    /// every thread. Queued/running jobs that never finished are failed.
    pub fn shutdown(&mut self) {
        self.inner.request_shutdown();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        for h in self.replicas.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.control.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start the service: spawn `cfg.workers` replicas (threads over
/// channel or loopback-TCP links), the admission scheduler, and — when
/// configured — the TCP control plane.
pub fn serve(cfg: ServeConfig) -> Result<ServerHandle> {
    anyhow::ensure!(cfg.workers >= 1, "need at least one replica");
    anyhow::ensure!(cfg.max_tenants >= 1, "need room for at least one tenant");
    anyhow::ensure!(cfg.round_batches >= 1, "rounds must run at least one batch");
    let nspec = NativeSpec::preset(&cfg.model)?;
    let micro_batch = nspec.micro_batch;

    let mut links: Vec<Option<Box<dyn Transport>>> = Vec::with_capacity(cfg.workers);
    let mut replicas = Vec::with_capacity(cfg.workers);
    if cfg.tcp {
        let (listener, addr) = transport::listen("127.0.0.1:0")?;
        let addr = addr.to_string();
        for r in 0..cfg.workers {
            let addr = addr.clone();
            replicas.push(std::thread::spawn(move || {
                let run = || -> Result<()> {
                    let t = TcpTransport::connect(
                        &addr,
                        Duration::from_secs(10),
                        Arc::new(BufPool::new()),
                    )?;
                    run_replica(Box::new(t))
                };
                if let Err(e) = run() {
                    warn_!("replica {r} exited: {e:#}");
                }
            }));
        }
        let streams = transport::accept_workers(&listener, cfg.workers, Duration::from_secs(10))?;
        let pool = Arc::new(BufPool::new());
        for stream in streams {
            links.push(Some(Box::new(TcpTransport::from_stream(stream, Arc::clone(&pool))?)));
        }
    } else {
        for r in 0..cfg.workers {
            let (server_end, replica_end) = transport::channel_pair();
            replicas.push(std::thread::spawn(move || {
                if let Err(e) = run_replica(Box::new(replica_end)) {
                    warn_!("replica {r} exited: {e:#}");
                }
            }));
            links.push(Some(Box::new(server_end)));
        }
    }

    let inner = Arc::new(Inner {
        state: Mutex::new(Shared {
            jobs: BTreeMap::new(),
            next_id: 1,
            next_seq: 0,
            shutdown: false,
        }),
        cv: Condvar::new(),
        cfg: cfg.clone(),
        micro_batch,
    });

    let sched_inner = Arc::clone(&inner);
    let scheduler = std::thread::spawn(move || scheduler_loop(sched_inner, links));

    let (control, control_addr) = match &cfg.control {
        Some(addr) => {
            let (listener, bound) = transport::listen(addr)?;
            let bound = bound.to_string();
            info!("serve control plane listening on {bound}");
            let ctrl_inner = Arc::clone(&inner);
            let h = std::thread::spawn(move || control_loop(ctrl_inner, listener));
            (Some(h), Some(bound))
        }
        None => (None, None),
    };

    Ok(ServerHandle { inner, scheduler: Some(scheduler), replicas, control, control_addr })
}

/// Accept control-plane clients until shutdown. One JSON object per
/// line in, one per line out (`repro job` speaks this).
fn control_loop(inner: Arc<Inner>, listener: std::net::TcpListener) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        if inner.shutdown_requested() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                if handle_control_conn(&inner, stream) {
                    // Client asked for shutdown; stop accepting.
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => return,
        }
    }
}

/// Serve one control connection; returns true when the client
/// requested service shutdown.
fn handle_control_conn(inner: &Inner, stream: TcpStream) -> bool {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return false,
    };
    let reader = BufReader::new(stream);
    let mut wants_shutdown = false;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = control_request(inner, &line, &mut wants_shutdown);
        let text = reply.to_string_compact();
        if writer.write_all(text.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
        let _ = writer.flush();
        if wants_shutdown {
            break;
        }
    }
    wants_shutdown
}

fn control_err(e: impl std::fmt::Display) -> Json {
    obj(vec![("ok", num(0.0)), ("error", s(&format!("{e:#}")))])
}

/// Dispatch one control-plane request line.
fn control_request(inner: &Inner, line: &str, wants_shutdown: &mut bool) -> Json {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return control_err(e),
    };
    let cmd = match doc.str_at("cmd") {
        Ok(c) => c,
        Err(_) => return control_err("request needs a \"cmd\" string"),
    };
    match cmd.as_str() {
        "submit" => {
            let Some(spec_doc) = doc.opt("spec") else {
                return control_err("submit needs a \"spec\" object");
            };
            match JobSpec::from_json(spec_doc).and_then(|spec| inner.submit(&spec)) {
                Ok(id) => obj(vec![("ok", num(1.0)), ("job_id", num(id as f64))]),
                Err(e) => control_err(e),
            }
        }
        "status" => match doc.usize_at("job_id") {
            Ok(id) => match inner.report(id as u64) {
                Some(r) => obj(vec![("ok", num(1.0)), ("report", job_report_json(&r))]),
                None => control_err(format!("no such job {id}")),
            },
            Err(_) => control_err("status needs a numeric \"job_id\""),
        },
        "result" => match doc.usize_at("job_id") {
            Ok(id) => match inner.wait(id as u64, Duration::from_secs(600)) {
                Ok(r) => obj(vec![("ok", num(1.0)), ("report", job_report_json(&r))]),
                Err(e) => control_err(e),
            },
            Err(_) => control_err("result needs a numeric \"job_id\""),
        },
        "report" => obj(vec![("ok", num(1.0)), ("report", inner.report_json())]),
        "shutdown" => {
            inner.request_shutdown();
            *wants_shutdown = true;
            obj(vec![("ok", num(1.0))])
        }
        other => control_err(format!("unknown cmd {other:?}")),
    }
}
