//! Round-based admission control: multiple-knapsack over live replica
//! capacity.
//!
//! Each service round, every waiting job (queued, preempted, or between
//! rounds) becomes a knapsack *item* whose weight is the micro-steps its
//! next round would cost, and every live replica is a *bin* whose
//! capacity is the per-round micro-step allowance. Bins are solved in
//! replica order with [`crate::schedule::knapsack::knapsack_01`] — the
//! same exact solver the D2FT scheduler uses per device, reused at the
//! job granularity. Values encode priority-then-FIFO: a higher-priority
//! job always outranks a lower one, and ties break by submission
//! sequence, so the plan is a pure function of its inputs and two
//! services fed the same submissions admit identically.

use crate::schedule::knapsack::knapsack_01;

/// One admission candidate: a job with work remaining.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    /// Job id (the service's key for the admitted round).
    pub job_id: u64,
    /// Submission sequence number (FIFO tie-break; unique per job).
    pub seq: u64,
    /// Admission priority (higher wins).
    pub priority: u32,
    /// Micro-steps the job's next round costs.
    pub micros: usize,
    /// Whether the job ran in the previous round (losing admission
    /// while `running` is a preemption, not a mere wait).
    pub running: bool,
}

/// One replica's capacity this round.
#[derive(Clone, Copy, Debug)]
pub struct Bin {
    /// Replica index the admitted jobs are dispatched to.
    pub replica: usize,
    /// Micro-steps this replica can absorb this round.
    pub capacity_micros: usize,
}

/// The admission decision for one round.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoundPlan {
    /// `(job_id, replica)` assignments, in bin order then knapsack
    /// pick order — the dispatch order the server uses verbatim.
    pub admitted: Vec<(u64, usize)>,
    /// Previously-running jobs that lost admission this round.
    pub preempted: Vec<u64>,
    /// Jobs whose single-round cost exceeds every bin outright — they
    /// can never run on this fleet and should be failed, not starved.
    pub oversized: Vec<u64>,
}

/// Priority-then-FIFO knapsack value: one priority step dominates any
/// sequence-number difference, and among equal priorities an earlier
/// submission is strictly more valuable.
fn value_of(c: &Candidate) -> f64 {
    c.priority as f64 * 1e9 + (1e9 - c.seq.min(999_999_999) as f64)
}

/// Solve one round of admissions. Pure and deterministic: no clocks, no
/// randomness — the plan depends only on `candidates` and `bins`.
pub fn plan_round(candidates: &[Candidate], bins: &[Bin]) -> RoundPlan {
    let max_capacity = bins.iter().map(|b| b.capacity_micros).max().unwrap_or(0);
    let mut plan = RoundPlan::default();
    let mut remaining: Vec<Candidate> = Vec::with_capacity(candidates.len());
    for c in candidates {
        if c.micros > max_capacity {
            plan.oversized.push(c.job_id);
        } else {
            remaining.push(*c);
        }
    }
    for bin in bins {
        if remaining.is_empty() {
            break;
        }
        let values: Vec<f64> = remaining.iter().map(value_of).collect();
        let weights: Vec<usize> = remaining.iter().map(|c| c.micros).collect();
        let (_, picks) = knapsack_01(&values, &weights, bin.capacity_micros);
        let mut kept = Vec::with_capacity(remaining.len());
        for (c, picked) in remaining.into_iter().zip(picks) {
            if picked {
                plan.admitted.push((c.job_id, bin.replica));
            } else {
                kept.push(c);
            }
        }
        remaining = kept;
    }
    for c in remaining {
        if c.running {
            plan.preempted.push(c.job_id);
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(job_id: u64, seq: u64, priority: u32, micros: usize) -> Candidate {
        Candidate { job_id, seq, priority, micros, running: false }
    }

    fn bins(caps: &[usize]) -> Vec<Bin> {
        caps.iter()
            .enumerate()
            .map(|(replica, &capacity_micros)| Bin { replica, capacity_micros })
            .collect()
    }

    #[test]
    fn oversized_job_is_rejected_not_starved() {
        // A job demanding more micro-steps than any replica offers can
        // never be admitted — it must surface as oversized.
        let plan = plan_round(&[cand(1, 0, 5, 10), cand(2, 1, 1, 4)], &bins(&[4, 4]));
        assert_eq!(plan.oversized, vec![1]);
        assert_eq!(plan.admitted, vec![(2, 0)]);
        assert!(plan.preempted.is_empty());
    }

    #[test]
    fn zero_capacity_bins_admit_nothing() {
        let mut running = cand(7, 0, 9, 5);
        running.running = true;
        let plan = plan_round(&[running, cand(8, 1, 1, 5)], &bins(&[0, 0]));
        assert!(plan.admitted.is_empty());
        // Everything is oversized relative to a zero-capacity fleet.
        assert_eq!(plan.oversized, vec![7, 8]);
    }

    #[test]
    fn priority_wins_then_fifo_breaks_ties_deterministically() {
        // One slot; the high-priority latecomer beats both early
        // low-priority jobs, regardless of candidate order.
        let a = cand(1, 0, 1, 5);
        let b = cand(2, 1, 1, 5);
        let hi = cand(3, 2, 4, 5);
        let plan = plan_round(&[a, b, hi], &bins(&[5]));
        assert_eq!(plan.admitted, vec![(3, 0)]);
        let plan2 = plan_round(&[hi, b, a], &bins(&[5]));
        assert_eq!(plan2.admitted, vec![(3, 0)]);
        // Equal priority: the earlier sequence number wins, stably.
        let plan3 = plan_round(&[b, a], &bins(&[5]));
        assert_eq!(plan3.admitted, vec![(1, 0)]);
        for _ in 0..8 {
            assert_eq!(plan_round(&[b, a], &bins(&[5])), plan3);
        }
    }

    #[test]
    fn running_job_is_preempted_at_round_boundary_by_priority() {
        // The running low-priority job loses its slot to a
        // higher-priority arrival and is reported preempted.
        let mut low = cand(1, 0, 1, 5);
        low.running = true;
        let hi = cand(2, 1, 8, 5);
        let plan = plan_round(&[low, hi], &bins(&[5]));
        assert_eq!(plan.admitted, vec![(2, 0)]);
        assert_eq!(plan.preempted, vec![1]);
        // With capacity for both there is no preemption.
        let plan2 = plan_round(&[low, hi], &bins(&[5, 5]));
        assert_eq!(plan2.admitted.len(), 2);
        assert!(plan2.preempted.is_empty());
    }

    #[test]
    fn one_bin_can_pack_multiple_small_jobs() {
        let plan = plan_round(
            &[cand(1, 0, 1, 3), cand(2, 1, 1, 3), cand(3, 2, 1, 3)],
            &bins(&[6]),
        );
        assert_eq!(plan.admitted.len(), 2);
        assert!(plan.admitted.iter().all(|&(_, r)| r == 0));
    }
}
