//! Synthetic class-conditional Gaussian image datasets.
//!
//! Each class gets a deterministic low-frequency prototype image; samples
//! are `prototype + noise`. The three presets match the paper's datasets
//! in class count and relative difficulty: cifar10-like (10 classes),
//! cifar100-like (100 classes), cars-like (196 classes, fewer examples
//! per class — reproducing "Stanford Cars is the harder dataset" in the
//! figures). A `pretrain` variant draws prototypes from a different seed
//! universe so fine-tuning starts from informative weights (DESIGN.md
//! Substitution 4).

use super::Dataset;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// The synthetic dataset presets (class count + difficulty match the
/// paper's datasets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyntheticKind {
    /// 10 classes, CIFAR-10-like difficulty.
    Cifar10Like,
    /// 100 classes, CIFAR-100-like difficulty.
    Cifar100Like,
    /// 196 classes, noisier (Stanford-Cars-like difficulty).
    CarsLike,
    /// Broad distribution used for the synthetic "pre-training" phase.
    Pretrain,
}

impl SyntheticKind {
    /// Parse a CLI dataset label.
    pub fn parse(s: &str) -> anyhow::Result<SyntheticKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "cifar10" | "cifar10-like" | "c10" => SyntheticKind::Cifar10Like,
            "cifar100" | "cifar100-like" | "c100" => SyntheticKind::Cifar100Like,
            "cars" | "cars-like" => SyntheticKind::CarsLike,
            "pretrain" => SyntheticKind::Pretrain,
            _ => anyhow::bail!("unknown dataset {s:?} (c10|c100|cars|pretrain)"),
        })
    }

    /// The CLI token for this preset — the inverse of
    /// [`SyntheticKind::parse`], used when a config is serialized back
    /// out (e.g. a `JobSpec` travelling to the serve control plane).
    pub fn cli_label(self) -> &'static str {
        match self {
            SyntheticKind::Cifar10Like => "c10",
            SyntheticKind::Cifar100Like => "c100",
            SyntheticKind::CarsLike => "cars",
            SyntheticKind::Pretrain => "pretrain",
        }
    }

    /// Display label for reports.
    pub fn label(self) -> &'static str {
        match self {
            SyntheticKind::Cifar10Like => "CIFAR-10 (synthetic)",
            SyntheticKind::Cifar100Like => "CIFAR-100 (synthetic)",
            SyntheticKind::CarsLike => "Stanford Cars (synthetic)",
            SyntheticKind::Pretrain => "pretrain (synthetic)",
        }
    }

    /// Default class count; the model head is fixed at 196 logits, so
    /// datasets simply use a label-space prefix.
    pub fn default_classes(self) -> usize {
        match self {
            SyntheticKind::Cifar10Like => 10,
            SyntheticKind::Cifar100Like => 100,
            SyntheticKind::CarsLike => 196,
            SyntheticKind::Pretrain => 196,
        }
    }

    /// Distinct prototype seed universe per kind.
    fn seed_base(self) -> u64 {
        match self {
            SyntheticKind::Cifar10Like => 0x1000,
            SyntheticKind::Cifar100Like => 0x2000,
            SyntheticKind::CarsLike => 0x3000,
            SyntheticKind::Pretrain => 0x9000,
        }
    }

    /// Per-sample noise; cars-like is noisier (harder). Calibrated so
    /// the scaled ViT separates classes within a few hundred steps while
    /// the relative difficulty ordering (cars > cifar) holds.
    pub fn default_noise(self) -> f32 {
        match self {
            SyntheticKind::CarsLike => 0.45,
            SyntheticKind::Pretrain => 0.5,
            _ => 0.35,
        }
    }
}

/// Full description of one synthetic dataset to generate.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Which preset distribution to draw from.
    pub kind: SyntheticKind,
    /// Number of examples to generate.
    pub train_size: usize,
    /// Image side length.
    pub img: usize,
    /// Number of label classes.
    pub classes: usize,
    /// Per-sample Gaussian noise level.
    pub noise: f32,
    /// Sampling seed (splits derive distinct streams from it).
    pub seed: u64,
}

impl DatasetSpec {
    /// Spec with the preset's default class count and noise.
    pub fn preset(kind: SyntheticKind, img: usize, train_size: usize, seed: u64) -> DatasetSpec {
        DatasetSpec {
            kind,
            train_size,
            img,
            classes: kind.default_classes(),
            noise: kind.default_noise(),
            seed,
        }
    }

    /// Low-frequency class prototype: random 4x4 color grid, bilinearly
    /// upsampled — class-separable but not trivially so under noise.
    fn prototype(&self, class: usize) -> Vec<f32> {
        let mut rng = Rng::new(self.kind.seed_base() ^ (class as u64).wrapping_mul(0x9E37));
        let g = 4usize;
        let grid: Vec<f32> = (0..g * g * 3).map(|_| rng.next_normal() * 0.8).collect();
        let mut out = vec![0.0f32; self.img * self.img * 3];
        let scale = g as f32 / self.img as f32;
        for y in 0..self.img {
            for x in 0..self.img {
                let fy = (y as f32 + 0.5) * scale - 0.5;
                let fx = (x as f32 + 0.5) * scale - 0.5;
                let y0 = (fy.floor().max(0.0) as usize).min(g - 1);
                let x0 = (fx.floor().max(0.0) as usize).min(g - 1);
                let y1 = (y0 + 1).min(g - 1);
                let x1 = (x0 + 1).min(g - 1);
                let wy = (fy - y0 as f32).clamp(0.0, 1.0);
                let wx = (fx - x0 as f32).clamp(0.0, 1.0);
                for c in 0..3 {
                    let v00 = grid[(y0 * g + x0) * 3 + c];
                    let v01 = grid[(y0 * g + x1) * 3 + c];
                    let v10 = grid[(y1 * g + x0) * 3 + c];
                    let v11 = grid[(y1 * g + x1) * 3 + c];
                    let v0 = v00 * (1.0 - wx) + v01 * wx;
                    let v1 = v10 * (1.0 - wx) + v11 * wx;
                    out[(y * self.img + x) * 3 + c] = v0 * (1.0 - wy) + v1 * wy;
                }
            }
        }
        out
    }

    /// Generate a split ("train" / "test" — distinct sample noise).
    pub fn generate(&self, split: &str) -> Dataset {
        let split_tag = match split {
            "train" => 0u64,
            "test" => 1,
            _ => 2,
        };
        let n = self.train_size;
        let ex = self.img * self.img * 3;
        let mut rng = Rng::new(self.seed ^ (split_tag << 32) ^ self.kind.seed_base());
        let mut images = vec![0.0f32; n * ex];
        let mut labels = Vec::with_capacity(n);
        // Round-robin classes so every class appears even in small splits.
        let protos: Vec<Vec<f32>> = (0..self.classes).map(|c| self.prototype(c)).collect();
        for i in 0..n {
            let class = i % self.classes;
            labels.push(class as i32);
            let proto = &protos[class];
            let out = &mut images[i * ex..(i + 1) * ex];
            for (o, &p) in out.iter_mut().zip(proto) {
                *o = p + rng.next_normal() * self.noise;
            }
        }
        // Shuffle examples (labels stay aligned).
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut shuffled = vec![0.0f32; n * ex];
        let mut shuffled_labels = vec![0i32; n];
        for (dst, &src) in order.iter().enumerate() {
            shuffled[dst * ex..(dst + 1) * ex].copy_from_slice(&images[src * ex..(src + 1) * ex]);
            shuffled_labels[dst] = labels[src];
        }
        Dataset {
            name: format!("{} [{split}]", self.kind.label()),
            classes: self.classes,
            img: self.img,
            images: Tensor::from_vec(&[n, self.img, self.img, 3], shuffled),
            labels: shuffled_labels,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DatasetSpec {
        DatasetSpec {
            kind: SyntheticKind::Cifar10Like,
            train_size: 40,
            img: 16,
            classes: 4,
            noise: 0.3,
            seed: 9,
        }
    }

    #[test]
    fn generates_all_classes() {
        let d = spec().generate("train");
        assert_eq!(d.len(), 40);
        let mut seen = vec![0usize; 4];
        for &l in &d.labels {
            seen[l as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c == 10), "{seen:?}");
    }

    #[test]
    fn train_test_differ_prototypes_shared() {
        let tr = spec().generate("train");
        let te = spec().generate("test");
        assert_ne!(tr.images, te.images);
        // but class structure is shared: mean image of a class in train
        // correlates with the same class in test far more than across
        // classes.
        let class_mean = |d: &Dataset, c: i32| -> Vec<f32> {
            let ex = d.img * d.img * 3;
            let mut acc = vec![0.0f32; ex];
            let mut n = 0;
            for (i, &l) in d.labels.iter().enumerate() {
                if l == c {
                    for (a, &v) in acc.iter_mut().zip(&d.images.data()[i * ex..(i + 1) * ex]) {
                        *a += v;
                    }
                    n += 1;
                }
            }
            acc.iter_mut().for_each(|a| *a /= n as f32);
            acc
        };
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let m0_tr = class_mean(&tr, 0);
        let m0_te = class_mean(&te, 0);
        let m1_te = class_mean(&te, 1);
        assert!(dot(&m0_tr, &m0_te) > dot(&m0_tr, &m1_te));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = spec().generate("train");
        let b = spec().generate("train");
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SyntheticKind::parse("c100").unwrap(), SyntheticKind::Cifar100Like);
        assert_eq!(SyntheticKind::parse("cars").unwrap(), SyntheticKind::CarsLike);
        assert!(SyntheticKind::parse("imagenet").is_err());
    }

    #[test]
    fn pretrain_universe_differs() {
        let ft = DatasetSpec { kind: SyntheticKind::Cifar10Like, ..spec() }.generate("train");
        let pt = DatasetSpec { kind: SyntheticKind::Pretrain, classes: 4, ..spec() }
            .generate("train");
        assert_ne!(ft.images, pt.images);
    }
}
