//! Real CIFAR-10 binary loader (`cifar-10-batches-bin` format: per
//! record 1 label byte + 3072 bytes of channel-planar 32x32 RGB).
//!
//! Used automatically by the experiment harness when the directory
//! exists; all shipped runs fall back to the synthetic datasets
//! (DESIGN.md Substitution 3 — no network access assumed).

use std::path::Path;

use anyhow::{Context, Result};

use super::Dataset;
use crate::tensor::Tensor;

const REC: usize = 1 + 3072;

/// Load one or more `*_batch*.bin` files into a dataset, rescaled to the
/// model's input size by nearest-neighbour if needed.
pub fn load_cifar10_bin(dir: &Path, files: &[&str], out_img: usize) -> Result<Dataset> {
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for f in files {
        let path = dir.join(f);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(bytes.len() % REC == 0, "{} has bad record size", path.display());
        for rec in bytes.chunks_exact(REC) {
            labels.push(rec[0] as i32);
            let planes = &rec[1..];
            // channel-planar [3][32][32] u8 -> NHWC f32 in [-1, 1],
            // resampled to out_img.
            for y in 0..out_img {
                for x in 0..out_img {
                    let sy = y * 32 / out_img;
                    let sx = x * 32 / out_img;
                    for c in 0..3 {
                        let v = planes[c * 1024 + sy * 32 + sx] as f32;
                        images.push(v / 127.5 - 1.0);
                    }
                }
            }
        }
    }
    let n = labels.len();
    anyhow::ensure!(n > 0, "no CIFAR records found");
    Ok(Dataset {
        name: "CIFAR-10 (binary)".into(),
        classes: 10,
        img: out_img,
        images: Tensor::from_vec(&[n, out_img, out_img, 3], images),
        labels,
    })
}

/// Probe for the conventional directory layout.
pub fn cifar10_dir_if_present() -> Option<std::path::PathBuf> {
    let candidates = ["data/cifar-10-batches-bin", "cifar-10-batches-bin"];
    candidates
        .iter()
        .map(Path::new)
        .find(|p| p.join("data_batch_1.bin").exists())
        .map(|p| p.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_batch(n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n * REC);
        for i in 0..n {
            out.push((i % 10) as u8);
            for b in 0..3072usize {
                out.push(((i * 37 + b * 11) % 256) as u8);
            }
        }
        out
    }

    #[test]
    fn parses_records() {
        let dir = std::env::temp_dir().join("d2ft_cifar_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("data_batch_1.bin"), fake_batch(7)).unwrap();
        let d = load_cifar10_bin(&dir, &["data_batch_1.bin"], 32).unwrap();
        assert_eq!(d.len(), 7);
        assert_eq!(d.images.shape(), &[7, 32, 32, 3]);
        assert_eq!(d.labels[3], 3);
        // values normalized to [-1, 1]
        assert!(d.images.data().iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }

    #[test]
    fn downsamples() {
        let dir = std::env::temp_dir().join("d2ft_cifar_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("data_batch_1.bin"), fake_batch(2)).unwrap();
        let d = load_cifar10_bin(&dir, &["data_batch_1.bin"], 16).unwrap();
        assert_eq!(d.images.shape(), &[2, 16, 16, 3]);
    }

    #[test]
    fn rejects_truncated_file() {
        let dir = std::env::temp_dir().join("d2ft_cifar_test3");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("data_batch_1.bin"), [0u8; 100]).unwrap();
        assert!(load_cifar10_bin(&dir, &["data_batch_1.bin"], 32).is_err());
    }
}
