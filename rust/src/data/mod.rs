//! Datasets: synthetic class-conditional Gaussian images (DESIGN.md
//! Substitution 3) + a real CIFAR-10 binary loader used automatically
//! when the files are present (no network access assumed).

mod cifar_bin;
mod synthetic;

pub use cifar_bin::{cifar10_dir_if_present, load_cifar10_bin};
pub use synthetic::{DatasetSpec, SyntheticKind};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// An in-memory labelled image dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Display name.
    pub name: String,
    /// Number of label classes.
    pub classes: usize,
    /// Image side length (square images).
    pub img: usize,
    /// `[n, img, img, 3]` f32.
    pub images: Tensor,
    /// `[n]` class ids.
    pub labels: Vec<i32>,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the dataset has no examples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy one example's image into a flat buffer slot.
    fn copy_example(&self, idx: usize, out: &mut [f32]) {
        let ex = self.img * self.img * 3;
        out.copy_from_slice(&self.images.data()[idx * ex..(idx + 1) * ex]);
    }

    /// Gather examples into a micro-batch tensor pair.
    pub fn gather(&self, idxs: &[usize]) -> (Tensor, Vec<i32>) {
        let ex = self.img * self.img * 3;
        let mut buf = vec![0.0f32; idxs.len() * ex];
        let mut ys = Vec::with_capacity(idxs.len());
        for (slot, &i) in idxs.iter().enumerate() {
            self.copy_example(i, &mut buf[slot * ex..(slot + 1) * ex]);
            ys.push(self.labels[i]);
        }
        (
            Tensor::from_vec(&[idxs.len(), self.img, self.img, 3], buf),
            ys,
        )
    }
}

/// Deterministic epoch iterator yielding batches of micro-batches.
pub struct Batcher<'a> {
    data: &'a Dataset,
    micro_batch: usize,
    micros_per_batch: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl<'a> Batcher<'a> {
    /// Batcher with a deterministic shuffle from `seed`.
    pub fn new(
        data: &'a Dataset,
        micro_batch: usize,
        micros_per_batch: usize,
        seed: u64,
    ) -> Batcher<'a> {
        let mut order: Vec<usize> = (0..data.len()).collect();
        Rng::new(seed).shuffle(&mut order);
        Batcher { data, micro_batch, micros_per_batch, order, cursor: 0 }
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.data.len() / (self.micro_batch * self.micros_per_batch)
    }

    /// Next batch: `micros_per_batch` micro-batches (drops the ragged
    /// tail; re-shuffles between epochs is the caller's seed choice).
    pub fn next_batch(&mut self) -> Option<Vec<(Tensor, Vec<i32>)>> {
        let need = self.micro_batch * self.micros_per_batch;
        if self.cursor + need > self.order.len() {
            return None;
        }
        let mut micros = Vec::with_capacity(self.micros_per_batch);
        for m in 0..self.micros_per_batch {
            let lo = self.cursor + m * self.micro_batch;
            let idxs = &self.order[lo..lo + self.micro_batch];
            micros.push(self.data.gather(idxs));
        }
        self.cursor += need;
        Some(micros)
    }

    /// Restart (same order).
    pub fn reset(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        DatasetSpec {
            kind: SyntheticKind::Cifar10Like,
            train_size: 64,
            img: 16,
            classes: 4,
            noise: 0.3,
            seed: 1,
        }
        .generate("train")
    }

    #[test]
    fn gather_shapes() {
        let d = tiny();
        let (x, y) = d.gather(&[0, 5, 9]);
        assert_eq!(x.shape(), &[3, 16, 16, 3]);
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|&c| (c as usize) < d.classes));
    }

    #[test]
    fn batcher_yields_full_epoch() {
        let d = tiny();
        let mut b = Batcher::new(&d, 4, 2, 7);
        assert_eq!(b.batches_per_epoch(), 8);
        let mut n = 0;
        while let Some(batch) = b.next_batch() {
            assert_eq!(batch.len(), 2);
            assert_eq!(batch[0].0.shape()[0], 4);
            n += 1;
        }
        assert_eq!(n, 8);
        b.reset();
        assert!(b.next_batch().is_some());
    }

    #[test]
    fn batcher_is_seed_deterministic() {
        let d = tiny();
        let a = Batcher::new(&d, 4, 2, 3).next_batch().unwrap();
        let b = Batcher::new(&d, 4, 2, 3).next_batch().unwrap();
        assert_eq!(a[0].1, b[0].1);
        assert_eq!(a[0].0, b[0].0);
        let c = Batcher::new(&d, 4, 2, 4).next_batch().unwrap();
        assert!(a[0].1 != c[0].1 || a[0].0 != c[0].0);
    }
}
