//! Dense linear-algebra kernels for the native compute backend: matmul
//! (plus the transposed variants gradients need), 2-D transpose, row-wise
//! softmax, row-wise layer norm, and GELU — each with its backward pass.
//!
//! Everything operates on 2-D row-major [`Tensor`]s; the backend flattens
//! `[mb, T, D]` activations to `[mb*T, D]` matrices and loops per-sample
//! only where attention genuinely needs the `[T, T]` structure. All
//! accumulation is sequential f32, so results are bit-deterministic.
//!
//! The three matmul variants are **blocked/tiled**: `matmul` and
//! `matmul_tn` tile the `k`/`n` loops so a `KC x JC` panel of the
//! right-hand operand stays cache-resident while every output row
//! consumes it, and `matmul_nt` computes four output columns per pass so
//! the dot-product reductions (which the compiler cannot vectorize
//! without reassociating floats) overlap in independent accumulators.
//! Tiling never reorders the per-element accumulation: each output
//! element still sums its `k` terms in ascending order, so every kernel
//! is **bitwise identical** to the order-defining naive loops kept in
//! [`reference`] — the property `reference::*` unit tests pin and the
//! serial ≡ distributed determinism contract builds on.

//!
//! On top of the tiling, each matmul variant parallelizes its *output
//! row* loop over the internal [`pool`] when the kernel is large enough
//! to amortize dispatch: output rows split into contiguous writer-owned
//! blocks (each thread writes a disjoint row range and nothing else),
//! and every element keeps the serial kernel's exact accumulation
//! order — so results stay bitwise identical to [`reference`] for
//! **any** thread count. Thread count is a pure performance knob:
//! [`pool::configure`] / `NativeSpec::threads` / `repro --threads`.

use super::{pool, Tensor};

/// k-dimension tile: a `KC x JC` f32 panel is 32 KiB — L1-resident.
const KC: usize = 64;
/// n-dimension (output column) tile.
const JC: usize = 128;
/// Minimum `m * k * n` before the row-parallel path engages — below
/// this, pool dispatch overhead beats the win.
const PAR_MIN_FLOPS: usize = 96 * 1024;
/// Minimum output rows per parallel chunk (writer-owned block).
const PAR_MIN_ROWS: usize = 8;

/// Effective thread count for an `[m, k] x [k, n]`-shaped kernel:
/// requested `t`, gated on the kernel being worth splitting at all.
fn gate_threads(t: usize, m: usize, k: usize, n: usize) -> usize {
    if t <= 1 || m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        1
    } else {
        t
    }
}

/// Split `out` (an `[m, row_w]` row-major buffer) into contiguous
/// writer-owned row blocks and run `body(lo, hi, block)` on each, in
/// parallel over at most `t` threads. `body` must write rows `lo..hi`
/// of the logical output into `block` (re-based at row `lo`); blocks
/// are disjoint, so parallel execution is race-free by construction and
/// bitwise identical to `body(0, m, out)`.
fn run_row_blocks(
    t: usize,
    m: usize,
    row_w: usize,
    out: &mut [f32],
    body: &(dyn Fn(usize, usize, &mut [f32]) + Sync),
) {
    let chunks = pool::ranges(m, PAR_MIN_ROWS, t);
    if chunks.len() <= 1 {
        body(0, m, out);
        return;
    }
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(chunks.len());
    let mut rest = out;
    for &(lo, hi) in &chunks {
        let (block, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * row_w);
        rest = tail;
        jobs.push(Box::new(move || body(lo, hi, block)));
    }
    pool::run(jobs);
}

fn dims2(t: &Tensor) -> (usize, usize) {
    assert_eq!(t.shape().len(), 2, "expected a 2-D tensor, got {:?}", t.shape());
    (t.shape()[0], t.shape()[1])
}

impl Tensor {
    /// Matrix product `self [m,k] x other [k,n] -> [m,n]`.
    ///
    /// Blocked over `(k, n)`, output rows parallelized over the kernel
    /// pool; bitwise identical to [`reference::matmul`] at any thread
    /// count.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_threads(other, pool::threads())
    }

    /// [`Tensor::matmul`] with an explicit thread count (testing/bench
    /// hook; the public entry point snapshots the pool configuration).
    pub(crate) fn matmul_threads(&self, other: &Tensor, t: usize) -> Tensor {
        let (m, k) = dims2(self);
        let (k2, n) = dims2(other);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let a = self.data();
        let b = other.data();
        let mut out = vec![0.0f32; m * n];
        // j-tiles outermost: each output element receives all of its k
        // terms within one (j0, i) visit, in ascending-k order (k0 then
        // kk both ascend) — the same per-element order as the naive
        // i,k,j loops, so tiling cannot change a single bit. The row
        // loop runs per writer-owned block (`lo..hi`), which permutes
        // only the order *across* rows — never within one element.
        let body = |lo: usize, hi: usize, o: &mut [f32]| {
            for j0 in (0..n).step_by(JC) {
                let j1 = (j0 + JC).min(n);
                for k0 in (0..k).step_by(KC) {
                    let k1 = (k0 + KC).min(k);
                    for i in lo..hi {
                        let arow = &a[i * k..(i + 1) * k];
                        let orow = &mut o[(i - lo) * n + j0..(i - lo) * n + j1];
                        for kk in k0..k1 {
                            let av = arow[kk];
                            let brow = &b[kk * n + j0..kk * n + j1];
                            for (ov, &bv) in orow.iter_mut().zip(brow) {
                                *ov += av * bv;
                            }
                        }
                    }
                }
            }
        };
        run_row_blocks(gate_threads(t, m, k, n), m, n, &mut out, &body);
        Tensor::from_vec(&[m, n], out)
    }

    /// Transposed-A product `self^T [k,m]^T x other [k,n] -> [m,n]`
    /// (the `dW = X^T dY` shape every weight gradient uses).
    ///
    /// Blocked over `(k, n)`, output rows parallelized over the kernel
    /// pool; bitwise identical to [`reference::matmul_tn`] at any
    /// thread count.
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        self.matmul_tn_threads(other, pool::threads())
    }

    /// [`Tensor::matmul_tn`] with an explicit thread count.
    pub(crate) fn matmul_tn_threads(&self, other: &Tensor, t: usize) -> Tensor {
        let (k, m) = dims2(self);
        let (k2, n) = dims2(other);
        assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
        let a = self.data();
        let b = other.data();
        let mut out = vec![0.0f32; m * n];
        // Per element (i, j): k0 tiles ascend, kk ascends within each —
        // identical accumulation order to the naive k-outer loops. Out
        // rows (= columns of `a`) split into writer-owned blocks; the
        // `i` loop order across rows never touches per-element order.
        let body = |lo: usize, hi: usize, o: &mut [f32]| {
            for j0 in (0..n).step_by(JC) {
                let j1 = (j0 + JC).min(n);
                for k0 in (0..k).step_by(KC) {
                    let k1 = (k0 + KC).min(k);
                    for kk in k0..k1 {
                        let arow = &a[kk * m..(kk + 1) * m];
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for i in lo..hi {
                            let av = arow[i];
                            let orow = &mut o[(i - lo) * n + j0..(i - lo) * n + j1];
                            for (ov, &bv) in orow.iter_mut().zip(brow) {
                                *ov += av * bv;
                            }
                        }
                    }
                }
            }
        };
        run_row_blocks(gate_threads(t, m, k, n), m, n, &mut out, &body);
        Tensor::from_vec(&[m, n], out)
    }

    /// Transposed-B product `self [m,k] x other^T [n,k]^T -> [m,n]`
    /// (the `dX = dY W^T` shape every input gradient uses).
    ///
    /// Four output columns per pass: each dot product keeps its own
    /// accumulator in ascending-k order (bitwise identical to
    /// [`reference::matmul_nt`]), but the four reductions overlap —
    /// the ILP the naive one-dot-at-a-time loop cannot expose, since
    /// float reductions are not compiler-vectorizable.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        self.matmul_nt_threads(other, pool::threads())
    }

    /// [`Tensor::matmul_nt`] with an explicit thread count.
    pub(crate) fn matmul_nt_threads(&self, other: &Tensor, t: usize) -> Tensor {
        let (m, k) = dims2(self);
        let (n, k2) = dims2(other);
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let a = self.data();
        let b = other.data();
        let mut out = vec![0.0f32; m * n];
        // Each output row is an independent set of dot products, so the
        // writer-owned row blocks change nothing about any reduction.
        let body = |lo: usize, hi: usize, o: &mut [f32]| {
            let mut j = 0;
            // Column-quad outer loop: the four B rows (4k floats) stay
            // hot across every output row of the block.
            while j + 4 <= n {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                for i in lo..hi {
                    let arow = &a[i * k..(i + 1) * k];
                    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    for (kk, &av) in arow.iter().enumerate() {
                        s0 += av * b0[kk];
                        s1 += av * b1[kk];
                        s2 += av * b2[kk];
                        s3 += av * b3[kk];
                    }
                    let orow = (i - lo) * n;
                    o[orow + j] = s0;
                    o[orow + j + 1] = s1;
                    o[orow + j + 2] = s2;
                    o[orow + j + 3] = s3;
                }
                j += 4;
            }
            for jj in j..n {
                let brow = &b[jj * k..(jj + 1) * k];
                for i in lo..hi {
                    let arow = &a[i * k..(i + 1) * k];
                    let mut acc = 0.0f32;
                    for (&av, &bv) in arow.iter().zip(brow) {
                        acc += av * bv;
                    }
                    o[(i - lo) * n + jj] = acc;
                }
            }
        };
        run_row_blocks(gate_threads(t, m, k, n), m, n, &mut out, &body);
        Tensor::from_vec(&[m, n], out)
    }

    /// 2-D transpose `[m,n] -> [n,m]`.
    pub fn transpose2(&self) -> Tensor {
        let (m, n) = dims2(self);
        let a = self.data();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = a[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    /// Numerically-stable softmax over the last dim of a 2-D tensor.
    pub fn softmax_rows(&self) -> Tensor {
        let (m, n) = dims2(self);
        let a = self.data();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                let e = (x - mx).exp();
                *o = e;
                z += e;
            }
            let inv = 1.0 / z;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Row-wise layer norm `y = (x - mean) * rstd * g + b` over the last
    /// dim. Returns `(y, mean, rstd)`; the stats feed the backward pass.
    pub fn layer_norm_rows(&self, g: &Tensor, b: &Tensor, eps: f32) -> (Tensor, Tensor, Tensor) {
        let (m, n) = dims2(self);
        assert_eq!(g.len(), n, "layer_norm gain length");
        assert_eq!(b.len(), n, "layer_norm bias length");
        let a = self.data();
        let gd = g.data();
        let bd = b.data();
        let mut out = vec![0.0f32; m * n];
        let mut means = vec![0.0f32; m];
        let mut rstds = vec![0.0f32; m];
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let mean = row.iter().sum::<f32>() / n as f32;
            let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
            let rstd = 1.0 / (var + eps).sqrt();
            means[i] = mean;
            rstds[i] = rstd;
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] = (row[j] - mean) * rstd * gd[j] + bd[j];
            }
        }
        (
            Tensor::from_vec(&[m, n], out),
            Tensor::from_vec(&[m], means),
            Tensor::from_vec(&[m], rstds),
        )
    }
}

/// Order-defining naive matmul kernels.
///
/// These are the seed's original triple loops, kept as the bitwise
/// reference the tiled hot-path kernels are pinned against: unit tests
/// assert `Tensor::matmul* == reference::matmul*` to the last bit on
/// shapes that exercise every tile-remainder path, and
/// `benches/native_step.rs` asserts the tiled kernels are measurably
/// faster. Not for production use.
pub mod reference {
    use crate::tensor::Tensor;

    fn dims2(t: &Tensor) -> (usize, usize) {
        assert_eq!(t.shape().len(), 2, "expected a 2-D tensor, got {:?}", t.shape());
        (t.shape()[0], t.shape()[1])
    }

    /// Naive `a [m,k] x b [k,n] -> [m,n]` (i, k, j loops).
    pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = dims2(a);
        let (k2, n) = dims2(b);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let ad = a.data();
        let bd = b.data();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &bd[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Naive `a^T [k,m]^T x b [k,n] -> [m,n]` (k outer).
    pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
        let (k, m) = dims2(a);
        let (k2, n) = dims2(b);
        assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
        let ad = a.data();
        let bd = b.data();
        let mut out = vec![0.0f32; m * n];
        for kk in 0..k {
            let arow = &ad[kk * m..(kk + 1) * m];
            let brow = &bd[kk * n..(kk + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    /// Naive `a [m,k] x b^T [n,k]^T -> [m,n]` (one dot per element).
    pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = dims2(a);
        let (n, k2) = dims2(b);
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let ad = a.data();
        let bd = b.data();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &bd[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
        Tensor::from_vec(&[m, n], out)
    }
}

/// Backward of [`Tensor::softmax_rows`]: given the softmax output `y` and
/// upstream `dy`, returns `dx = y * (dy - sum(dy * y))` per row.
pub fn softmax_rows_backward(y: &Tensor, dy: &Tensor) -> Tensor {
    let (m, n) = dims2(y);
    assert_eq!(y.shape(), dy.shape(), "softmax backward shape");
    let yd = y.data();
    let dyd = dy.data();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let yr = &yd[i * n..(i + 1) * n];
        let dyr = &dyd[i * n..(i + 1) * n];
        let dot: f32 = yr.iter().zip(dyr).map(|(&a, &b)| a * b).sum();
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            orow[j] = yr[j] * (dyr[j] - dot);
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// Backward of [`Tensor::layer_norm_rows`]: given the *input* `x`, gain
/// `g`, the saved `(mean, rstd)` stats, and upstream `d_out`, returns
/// `(dx, dg, db)`.
pub fn layer_norm_rows_backward(
    x: &Tensor,
    g: &Tensor,
    mean: &Tensor,
    rstd: &Tensor,
    d_out: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (m, n) = dims2(x);
    assert_eq!(d_out.shape(), x.shape(), "layer_norm backward shape");
    let xd = x.data();
    let gd = g.data();
    let md = mean.data();
    let rd = rstd.data();
    let dod = d_out.data();
    let mut dx = vec![0.0f32; m * n];
    let mut dg = vec![0.0f32; n];
    let mut db = vec![0.0f32; n];
    for i in 0..m {
        let xr = &xd[i * n..(i + 1) * n];
        let dor = &dod[i * n..(i + 1) * n];
        let (mu, rs) = (md[i], rd[i]);
        // y_hat, dy, and the two row means the dx formula needs.
        let mut mean_dy = 0.0f32;
        let mut mean_dy_yhat = 0.0f32;
        for j in 0..n {
            let yhat = (xr[j] - mu) * rs;
            let dy = dor[j] * gd[j];
            dg[j] += dor[j] * yhat;
            db[j] += dor[j];
            mean_dy += dy;
            mean_dy_yhat += dy * yhat;
        }
        mean_dy /= n as f32;
        mean_dy_yhat /= n as f32;
        let dxr = &mut dx[i * n..(i + 1) * n];
        for j in 0..n {
            let yhat = (xr[j] - mu) * rs;
            let dy = dor[j] * gd[j];
            dxr[j] = rs * (dy - mean_dy - yhat * mean_dy_yhat);
        }
    }
    (
        Tensor::from_vec(&[m, n], dx),
        Tensor::from_vec(&[n], dg),
        Tensor::from_vec(&[n], db),
    )
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

/// GELU activation (tanh approximation), elementwise.
pub fn gelu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data_mut() {
        let x = *v;
        let u = GELU_C * (x + GELU_A * x * x * x);
        *v = 0.5 * x * (1.0 + u.tanh());
    }
    out
}

/// Backward of [`gelu`]: given the pre-activation `x` and upstream
/// `d_out`, returns the gradient w.r.t. `x`.
pub fn gelu_backward(x: &Tensor, d_out: &Tensor) -> Tensor {
    assert_eq!(x.shape(), d_out.shape(), "gelu backward shape");
    let mut out = d_out.clone();
    for (v, &xv) in out.data_mut().iter_mut().zip(x.data()) {
        let u = GELU_C * (xv + GELU_A * xv * xv * xv);
        let t = u.tanh();
        let du = GELU_C * (1.0 + 3.0 * GELU_A * xv * xv);
        let d = 0.5 * (1.0 + t) + 0.5 * xv * (1.0 - t * t) * du;
        *v *= d;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.next_normal() * 0.5).collect())
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::from_vec(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let a = rand_t(&[4, 3], 1);
        let b = rand_t(&[4, 5], 2);
        let c = rand_t(&[5, 3], 3);
        assert!(a.matmul_tn(&b).max_abs_diff(&a.transpose2().matmul(&b)) < 1e-6);
        assert!(b.matmul_nt(&c).max_abs_diff(&b.matmul(&c.transpose2())) < 1e-6);
    }

    #[test]
    fn tiled_kernels_match_reference_bitwise() {
        // Shapes straddling the KC=64 / JC=128 tile edges plus the
        // matmul_nt 4-column remainder, so every tail path runs.
        for (m, k, n, seed) in [
            (3, 5, 7, 20),
            (4, 64, 128, 21),
            (5, 65, 129, 22),
            (70, 130, 258, 23),
            (2, 200, 3, 24),
            (1, 1, 1, 25),
        ] {
            let a = rand_t(&[m, k], seed);
            let b = rand_t(&[k, n], seed + 100);
            let at = rand_t(&[k, m], seed + 200);
            let bt = rand_t(&[n, k], seed + 300);
            assert_eq!(a.matmul(&b), reference::matmul(&a, &b), "matmul {m}x{k}x{n}");
            assert_eq!(
                at.matmul_tn(&b),
                reference::matmul_tn(&at, &b),
                "matmul_tn {m}x{k}x{n}"
            );
            assert_eq!(
                a.matmul_nt(&bt),
                reference::matmul_nt(&a, &bt),
                "matmul_nt {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn threaded_kernels_match_reference_bitwise() {
        // Force the parallel path with explicit thread counts (no
        // dependence on the global pool knob, which other tests may
        // flip concurrently): shapes above the flop gate with row
        // counts that exercise uneven chunking, for t in {2, 3, 5}.
        for (m, k, n, seed) in [(70, 130, 258, 40), (67, 64, 129, 41), (128, 48, 100, 42)] {
            let a = rand_t(&[m, k], seed);
            let b = rand_t(&[k, n], seed + 100);
            let at = rand_t(&[k, m], seed + 200);
            let bt = rand_t(&[n, k], seed + 300);
            for t in [2usize, 3, 5] {
                assert_eq!(
                    a.matmul_threads(&b, t),
                    reference::matmul(&a, &b),
                    "matmul {m}x{k}x{n} t={t}"
                );
                assert_eq!(
                    at.matmul_tn_threads(&b, t),
                    reference::matmul_tn(&at, &b),
                    "matmul_tn {m}x{k}x{n} t={t}"
                );
                assert_eq!(
                    a.matmul_nt_threads(&bt, t),
                    reference::matmul_nt(&a, &bt),
                    "matmul_nt {m}x{k}x{n} t={t}"
                );
            }
        }
    }

    #[test]
    fn small_kernels_stay_serial_under_gate() {
        // Below the flop gate the requested thread count is ignored —
        // same bits either way (this pins the gate itself works).
        let a = rand_t(&[3, 5], 50);
        let b = rand_t(&[5, 7], 51);
        assert_eq!(a.matmul_threads(&b, 8), reference::matmul(&a, &b));
        assert_eq!(super::gate_threads(8, 3, 5, 7), 1);
        assert_eq!(super::gate_threads(8, 128, 64, 128), 8);
        assert_eq!(super::gate_threads(1, 128, 64, 128), 1);
    }

    #[test]
    fn transpose_round_trip() {
        let a = rand_t(&[3, 7], 4);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_rows_normalized_and_stable() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 1000.0, 1000.0, 1000.0]);
        let s = a.softmax_rows();
        for i in 0..2 {
            let row = &s.data()[i * 3..(i + 1) * 3];
            let z: f32 = row.iter().sum();
            assert!((z - 1.0).abs() < 1e-6, "row {i} sums to {z}");
            assert!(row.iter().all(|&p| p.is_finite() && p >= 0.0));
        }
        assert!((s.at(&[1, 0]) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn layer_norm_rows_zero_mean_unit_var() {
        let x = rand_t(&[5, 16], 6);
        let g = Tensor::full(&[16], 1.0);
        let b = Tensor::zeros(&[16]);
        let (y, _, _) = x.layer_norm_rows(&g, &b, 1e-5);
        for i in 0..5 {
            let row = &y.data()[i * 16..(i + 1) * 16];
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5, "row {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {i} var {var}");
        }
    }

    /// Central finite difference of a scalar-valued function of one
    /// tensor element.
    fn fd<F: FnMut(&Tensor) -> f32>(x: &Tensor, idx: usize, mut f: F) -> f32 {
        let eps = 1e-2f32;
        let mut xp = x.clone();
        xp.data_mut()[idx] += eps;
        let mut xm = x.clone();
        xm.data_mut()[idx] -= eps;
        (f(&xp) - f(&xm)) / (2.0 * eps)
    }

    fn assert_close(analytic: f32, numeric: f32, what: &str) {
        let tol = 2e-3 + 2e-2 * analytic.abs().max(numeric.abs());
        assert!(
            (analytic - numeric).abs() < tol,
            "{what}: analytic {analytic} vs numeric {numeric}"
        );
    }

    #[test]
    fn softmax_backward_matches_finite_difference() {
        let x = rand_t(&[3, 5], 7);
        let w = rand_t(&[3, 5], 8); // random projection -> scalar loss
        let loss = |x: &Tensor| -> f32 {
            x.softmax_rows()
                .data()
                .iter()
                .zip(w.data())
                .map(|(&a, &b)| a * b)
                .sum()
        };
        let y = x.softmax_rows();
        let dx = softmax_rows_backward(&y, &w);
        for idx in [0usize, 4, 7, 14] {
            assert_close(dx.data()[idx], fd(&x, idx, loss), &format!("softmax dx[{idx}]"));
        }
    }

    #[test]
    fn layer_norm_backward_matches_finite_difference() {
        let x = rand_t(&[3, 8], 9);
        let g = rand_t(&[8], 10);
        let b = rand_t(&[8], 11);
        let w = rand_t(&[3, 8], 12);
        let loss = |x: &Tensor, g: &Tensor, b: &Tensor| -> f32 {
            x.layer_norm_rows(g, b, 1e-5)
                .0
                .data()
                .iter()
                .zip(w.data())
                .map(|(&a, &b)| a * b)
                .sum()
        };
        let (_, mean, rstd) = x.layer_norm_rows(&g, &b, 1e-5);
        let (dx, dg, db) = layer_norm_rows_backward(&x, &g, &mean, &rstd, &w);
        for idx in [0usize, 5, 13, 23] {
            let n = fd(&x, idx, |xp| loss(xp, &g, &b));
            assert_close(dx.data()[idx], n, &format!("ln dx[{idx}]"));
        }
        for idx in [0usize, 3, 7] {
            let n = fd(&g, idx, |gp| loss(&x, gp, &b));
            assert_close(dg.data()[idx], n, &format!("ln dg[{idx}]"));
            let n = fd(&b, idx, |bp| loss(&x, &g, bp));
            assert_close(db.data()[idx], n, &format!("ln db[{idx}]"));
        }
    }

    #[test]
    fn gelu_backward_matches_finite_difference() {
        let x = rand_t(&[2, 6], 13);
        let w = rand_t(&[2, 6], 14);
        let loss = |x: &Tensor| -> f32 {
            gelu(x).data().iter().zip(w.data()).map(|(&a, &b)| a * b).sum()
        };
        let dx = gelu_backward(&x, &w);
        for idx in [0usize, 3, 8, 11] {
            assert_close(dx.data()[idx], fd(&x, idx, loss), &format!("gelu dx[{idx}]"));
        }
    }

    #[test]
    fn matmul_gradients_match_finite_difference() {
        let a = rand_t(&[3, 4], 15);
        let b = rand_t(&[4, 2], 16);
        let w = rand_t(&[3, 2], 17);
        let loss = |a: &Tensor, b: &Tensor| -> f32 {
            a.matmul(b).data().iter().zip(w.data()).map(|(&x, &y)| x * y).sum()
        };
        let da = w.matmul_nt(&b); // dL/dA = dY B^T
        let db = a.matmul_tn(&w); // dL/dB = A^T dY
        for idx in [0usize, 5, 11] {
            let n = fd(&a, idx, |ap| loss(ap, &b));
            assert_close(da.data()[idx], n, &format!("matmul da[{idx}]"));
        }
        for idx in [0usize, 4, 7] {
            let n = fd(&b, idx, |bp| loss(&a, bp));
            assert_close(db.data()[idx], n, &format!("matmul db[{idx}]"));
        }
    }
}
