//! Elementwise / reduction ops used by metrics and data synthesis.

use super::Tensor;

impl Tensor {
    /// Sum of all elements (f64 accumulation).
    pub fn sum(&self) -> f32 {
        // Pairwise-ish accumulation in f64 for stable metric reductions.
        self.data().iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Mean of all elements (0 for an empty tensor).
    pub fn mean(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        self.sum() / self.len() as f32
    }

    /// Sum of absolute values.
    pub fn abs_sum(&self) -> f32 {
        self.data().iter().map(|&x| (x as f64).abs()).sum::<f64>() as f32
    }

    /// Sum of squares.
    pub fn sq_sum(&self) -> f32 {
        self.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() as f32
    }

    /// Maximum element.
    pub fn max(&self) -> f32 {
        self.data().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Minimum element.
    pub fn min(&self) -> f32 {
        self.data().iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Population variance (the paper's workload-variance metric).
    pub fn variance(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        let mean = self.mean() as f64;
        let var = self
            .data()
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / self.len() as f64;
        var as f32
    }

    /// Index of the (first) maximum element.
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &x) in self.data().iter().enumerate() {
            if x > self.data()[best] {
                best = i;
            }
        }
        best
    }

    /// Multiply every element by `a` in place.
    pub fn scale(&mut self, a: f32) -> &mut Self {
        for x in self.data_mut() {
            *x *= a;
        }
        self
    }

    /// Elementwise add `other` in place (shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) -> &mut Self {
        assert_eq!(self.shape(), other.shape());
        let other_data: &[f32] = other.data();
        for (x, &y) in self.data_mut().iter_mut().zip(other_data) {
            *x += y;
        }
        self
    }

    /// Maximum absolute difference (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(t.sum(), -2.0);
        assert_eq!(t.abs_sum(), 10.0);
        assert_eq!(t.sq_sum(), 30.0);
        assert_eq!(t.max(), 3.0);
        assert_eq!(t.min(), -4.0);
        assert_eq!(t.argmax(), 2);
    }

    #[test]
    fn variance_zero_for_constant() {
        let t = Tensor::full(&[10], 2.5);
        assert_eq!(t.variance(), 0.0);
    }

    #[test]
    fn variance_known_value() {
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert!((t.variance() - 1.25).abs() < 1e-6);
    }

    #[test]
    fn add_and_scale() {
        let mut a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        a.add_assign(&b).scale(2.0);
        assert_eq!(a.data(), &[8.0, 12.0]);
    }
}
