//! Minimal dense f32 tensor for host-side work (data synthesis, metric
//! reductions, parameter inspection) — and, since the backend refactor,
//! the numeric substrate of the pure-Rust [`crate::backend::native`]
//! training path: [`linalg`] provides matmul/transpose/softmax/
//! layer-norm/GELU with their backward passes. When the optional `xla`
//! feature drives training instead, this type never appears on the PJRT
//! hot path beyond flat-slice views.

pub mod linalg;
mod ops;
pub mod pool;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from a flat row-major buffer (must match the shape).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat row-major view of the elements.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major view of the elements.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat element buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row-major flat index for a multi-index.
    pub fn index_of(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < d, "index {x} out of bounds for dim {i} of size {d}");
            flat = flat * d + x;
        }
        flat
    }

    /// Element at a multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.index_of(idx)]
    }

    /// Set the element at a multi-index.
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.index_of(idx);
        self.data[i] = v;
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_panics() {
        Tensor::zeros(&[2, 2]).at(&[2, 0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]);
        assert_eq!(t.at(&[1, 1]), 4.0);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.data(), &[3.5]);
    }
}
