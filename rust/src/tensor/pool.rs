//! Tiny internal thread pool for the native linear-algebra kernels — no
//! external dependencies, deterministic numerics by construction.
//!
//! The blocked matmul kernels in [`super::linalg`] parallelize their
//! *output-row* loops: each job owns a disjoint, contiguous block of
//! output rows (writer-owned tiles), so no two threads ever touch the
//! same element and the per-element accumulation order is exactly the
//! serial kernel's. Parallel results are therefore **bitwise identical**
//! to single-threaded execution for any thread count — the property the
//! serial ≡ distributed determinism contract of `tests/dist.rs` builds
//! on, re-pinned for the threaded kernels by `tensor::linalg` unit
//! tests.
//!
//! The pool is process-global (the [`crate::tensor::Tensor`] kernel
//! entry points have no backend handle to hang per-instance state on):
//! [`configure`] sets the target thread count (0 = auto), worker threads
//! are spawned lazily on first parallel dispatch and then reused for the
//! life of the process. Because thread count can never change numerics,
//! the global knob is a pure performance setting — safe to flip between
//! (or even during) runs.
//!
//! Dispatch is a scoped fork/join: [`run`] ships all but the first job
//! to the workers, executes the first job on the calling thread, and
//! blocks until every job has signalled completion — which is what makes
//! it sound to smuggle non-`'static` borrows across the channel (the
//! borrows cannot outlive the call). Worker panics are caught and
//! re-raised on the caller after the join, so a failed job can never
//! leave a half-written tile unobserved.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;

/// A type-erased unit of work shipped to a pool worker.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Desired kernel thread count (resolved; >= 1). Default 1 = serial.
static CONFIGURED: AtomicUsize = AtomicUsize::new(1);

/// Set the kernel thread count: `0` = auto (one per available core,
/// capped at 8), `1` = serial (the default), `n` = exactly `n` threads.
/// Process-global; thread count never changes numerics (see the module
/// docs), so this is purely a performance knob.
pub fn configure(threads: usize) {
    let t = if threads == 0 { auto_threads() } else { threads };
    CONFIGURED.store(t.max(1), Ordering::Relaxed);
}

/// The currently configured kernel thread count (>= 1).
pub fn threads() -> usize {
    CONFIGURED.load(Ordering::Relaxed).max(1)
}

/// Auto thread count: available parallelism, capped at 8 (the kernels
/// here are cache-bound; more threads than memory channels buy little).
fn auto_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// The lazily-created global pool: one injector queue, workers share the
/// receiver behind a mutex (job granularity dwarfs the lock).
struct Pool {
    tx: mpsc::Sender<Job>,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    spawned: Mutex<usize>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let (tx, rx) = mpsc::channel::<Job>();
        Pool { tx, rx: Arc::new(Mutex::new(rx)), spawned: Mutex::new(0) }
    })
}

/// Grow the worker set to at least `n` threads (never shrinks; idle
/// workers block on the shared queue and cost nothing but memory).
fn ensure_workers(p: &'static Pool, n: usize) {
    let mut spawned = p.spawned.lock().expect("pool spawn lock");
    while *spawned < n {
        let rx = Arc::clone(&p.rx);
        thread::Builder::new()
            .name(format!("d2ft-pool-{spawned}"))
            .spawn(move || loop {
                // Hold the lock only for the blocking recv; the job runs
                // unlocked so other workers can pick up the next one.
                let job = { rx.lock().expect("pool recv lock").recv() };
                match job {
                    Ok(job) => job(),
                    Err(_) => break, // channel closed: process exit
                }
            })
            .expect("spawning kernel pool worker");
        *spawned += 1;
    }
}

/// Execute `jobs` concurrently and block until all of them finish: jobs
/// `1..` go to the pool workers, job `0` runs on the calling thread.
/// Jobs may borrow the caller's stack (they cannot outlive this call).
/// If any job panics, the panic is re-raised here after the join.
pub fn run(jobs: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    let mut iter = jobs.into_iter();
    let first = iter.next().expect("n >= 1");
    if n == 1 {
        first();
        return;
    }
    let p = pool();
    ensure_workers(p, (n - 1).min(threads().saturating_sub(1)).max(1));
    // Completion barrier: every dispatched job reports (panicked?) here.
    let (done_tx, done_rx) = mpsc::channel::<bool>();
    let mut dispatched = 0usize;
    for job in iter {
        // SAFETY: the job may borrow data from the caller's stack (its
        // real lifetime is the duration of this call). We block on the
        // completion barrier below before returning — and before
        // propagating any caller-side panic — so the borrow can never
        // outlive its referent. The transmute only erases the lifetime;
        // the layout of `Box<dyn FnOnce() + Send>` does not depend on it.
        let job: Job = unsafe { std::mem::transmute(job) };
        let done = done_tx.clone();
        p.tx.send(Box::new(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            let _ = done.send(r.is_err());
        }))
        .expect("kernel pool queue closed");
        dispatched += 1;
    }
    // Run the first job here — the caller is a perfectly good worker.
    let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(first));
    let mut worker_panicked = false;
    for _ in 0..dispatched {
        worker_panicked |= done_rx.recv().expect("kernel pool worker lost");
    }
    if let Err(payload) = caller {
        std::panic::resume_unwind(payload);
    }
    assert!(!worker_panicked, "parallel kernel job panicked");
}

/// Split `0..n` into at most `t` contiguous ranges of at least
/// `min_chunk` items each (a single range when chunking isn't worth it).
/// Pure function of its arguments — callers snapshot [`threads`] once so
/// a concurrent [`configure`] cannot tear one dispatch.
pub fn ranges(n: usize, min_chunk: usize, t: usize) -> Vec<(usize, usize)> {
    let t = t.min(n / min_chunk.max(1)).max(1);
    if t <= 1 || n == 0 {
        return vec![(0, n)];
    }
    let base = n / t;
    let rem = n % t;
    let mut out = Vec::with_capacity(t);
    let mut lo = 0;
    for i in 0..t {
        let hi = lo + base + usize::from(i < rem);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_and_respect_min_chunk() {
        let r = ranges(100, 8, 4);
        assert!(!r.is_empty() && r.len() <= 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0, "contiguous");
        }
        for &(lo, hi) in &r {
            assert!(hi - lo >= 8, "chunk below min: {lo}..{hi}");
        }
        // Tiny inputs and t = 1 collapse to one range.
        assert_eq!(ranges(5, 8, 4), vec![(0, 5)]);
        assert_eq!(ranges(100, 8, 1), vec![(0, 100)]);
        // 13 items over 4 chunks: remainders spread over the first ones.
        let r = ranges(13, 1, 4);
        assert_eq!(r, vec![(0, 4), (4, 7), (7, 10), (10, 13)]);
    }

    #[test]
    fn run_executes_every_job_with_borrows() {
        let mut outs = vec![0u64; 6];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = outs
                .chunks_mut(2)
                .enumerate()
                .map(|(i, chunk)| {
                    Box::new(move || {
                        for (j, c) in chunk.iter_mut().enumerate() {
                            *c = (i * 2 + j) as u64 + 1;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run(jobs);
        }
        assert_eq!(outs, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn configure_always_resolves_to_at_least_one() {
        // `0` means auto; whatever races with this test, the resolved
        // value is never below 1. (Thread count cannot change numerics,
        // so no test asserts an exact global value.)
        configure(0);
        assert!(threads() >= 1);
        configure(1);
    }

    #[test]
    #[should_panic(expected = "parallel kernel job panicked")]
    fn worker_panic_propagates() {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
        ];
        run(jobs);
    }
}
