//! Experiment registry + shared context.

use anyhow::Result;

use crate::backend::BackendProvider;

/// Shared handles every experiment receives.
pub struct ExperimentCtx<'a> {
    /// The compute backend family every run opens its model from.
    pub provider: &'a dyn BackendProvider,
    /// Scale factor for run length (1 = shipped default; raise for
    /// closer-to-paper convergence, lower for smoke tests).
    pub scale: f64,
    /// Base seed for every run the experiment launches.
    pub seed: u64,
}

impl<'a> ExperimentCtx<'a> {
    /// Context with default scale (1.0) and seed (17).
    pub fn new(provider: &'a dyn BackendProvider) -> ExperimentCtx<'a> {
        ExperimentCtx { provider, scale: 1.0, seed: 17 }
    }

    /// Scaled batch count (min 2).
    pub fn batches(&self, base: usize) -> usize {
        ((base as f64 * self.scale).round() as usize).max(2)
    }
}

/// (id, description) of every runnable experiment.
pub fn list_experiments() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig1", "Fig. 1: top-1 vs compute & comm cost, full FT (CIFAR-100- and Cars-like)"),
        ("fig2", "Fig. 2: top-1 vs compute & comm cost, full FT (CIFAR-10-like)"),
        ("fig3", "Fig. 3: LoRA fine-tuning comparison (Cars-like)"),
        ("table1", "Table I: workload variance across devices @60% budget"),
        ("table2", "Table II: execution time + top-1 @60% budget"),
        ("table3", "Table III: backward/forward score metric combinations"),
        ("table4", "Table IV: subnet execution time for 1..5 micro-batches"),
        ("table5", "Table V: impact of the number of subnets"),
        ("table6", "Table VI: impact of micro-batch size"),
        ("table7", "Table VII: memory heterogeneity"),
        ("table8", "Table VIII: computation heterogeneity"),
        ("table9", "Table IX: Forward-Only (p_o) effectiveness"),
        ("table10", "Table X: bi-level vs Scaler-lambda scheduling"),
        ("tables", "run table1..table10 in one process"),
        ("all", "run every experiment in sequence"),
    ]
}

/// Dispatch by id; prints the paper-shaped table and returns its
/// markdown rendering (for EXPERIMENTS.md capture).
pub fn run_experiment(ctx: &ExperimentCtx, id: &str) -> Result<String> {
    let out = match id {
        "fig1" => super::figures::fig1(ctx)?,
        "fig2" => super::figures::fig2(ctx)?,
        "fig3" => super::figures::fig3(ctx)?,
        "table1" => super::tables::table1(ctx)?,
        "table2" => super::tables::table2(ctx)?,
        "table3" => super::tables::table3(ctx)?,
        "table4" => super::tables::table4(ctx)?,
        "table5" => super::tables::table5(ctx)?,
        "table6" => super::tables::table6(ctx)?,
        "table7" => super::tables::table7(ctx)?,
        "table8" => super::tables::table8(ctx)?,
        "table9" => super::tables::table9(ctx)?,
        "table10" => super::tables::table10(ctx)?,
        "tables" => {
            let mut all = String::new();
            for i in 1..=10 {
                all.push_str(&run_experiment(ctx, &format!("table{i}"))?);
                all.push('\n');
            }
            all
        }
        "all" => {
            let mut all = String::new();
            for (eid, _) in list_experiments() {
                if eid == "all" || eid == "tables" {
                    continue;
                }
                all.push_str(&run_experiment(ctx, eid)?);
                all.push('\n');
            }
            all
        }
        _ => anyhow::bail!(
            "unknown experiment {id:?}; known: {:?}",
            list_experiments().iter().map(|(i, _)| *i).collect::<Vec<_>>()
        ),
    };
    Ok(out)
}
