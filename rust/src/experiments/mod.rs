//! Experiment harness: one runner per paper table/figure.
//!
//! `repro experiment <id>` regenerates the rows/series of the paper's
//! evaluation (DESIGN.md "Per-experiment index"). Accuracy experiments
//! run scaled fine-tuning on the synthetic datasets; timing tables are
//! additionally covered by `cargo bench` targets.

mod figures;
mod registry;
mod tables;

pub use registry::{list_experiments, run_experiment, ExperimentCtx};
