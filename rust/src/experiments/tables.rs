//! Table regenerators: paper Tables I-X.

use anyhow::Result;

use super::registry::ExperimentCtx;
use crate::backend::{Backend, BackendProvider, BackendSel};
use crate::cluster::{ExecTimeModel, HeteroSpec};
use crate::coordinator::{SchedulerKind, Trainer, TrainerConfig, TrainReport};
use crate::data::SyntheticKind;
use crate::metrics::{pct, Table};
use crate::schedule::scaler::Lambda;
use crate::schedule::{Budget, Op};
use crate::scores::{Metric, ScoreConfig};

pub(super) fn section(title: &str) -> String {
    format!("\n## {title}\n\n")
}

/// The three budget points used across the figure sweeps (comm
/// fractions 50% / 70% / 90%, compute 48% / 68% / 88%).
pub(super) fn budget_points() -> Vec<(&'static str, Budget)> {
    vec![
        ("low (2pf,1po)", Budget::uniform(5, 2, 1)),
        ("mid (3pf,1po)", Budget::uniform(5, 3, 1)),
        ("high (4pf,1po)", Budget::uniform(5, 4, 1)),
    ]
}

/// Run one configured fine-tuning and return the report. The backend
/// (and, via `cfg.lora_rank`, the model variant) comes from the
/// context's provider.
pub(super) fn run_one(ctx: &ExperimentCtx, cfg: TrainerConfig) -> Result<TrainReport> {
    let label = format!(
        "{} on {:?} budget ({},{})",
        cfg.scheduler.label(),
        cfg.dataset,
        cfg.budget.n_full,
        cfg.budget.n_fwd
    );
    crate::info!("run_one: {label}");
    let mut trainer = Trainer::new(ctx.provider, cfg)?;
    let r = trainer.run()?;
    crate::info!(
        "  -> top-1 {} loss {:.3} compute {} comm {} var {:.3} ({:.1}s)",
        pct(r.test_top1),
        r.final_train_loss,
        pct(r.compute_fraction),
        pct(r.comm_fraction),
        r.workload_variance,
        r.wall_s
    );
    Ok(r)
}

/// Table I: workload variance across devices at a ~60% compute budget.
pub fn table1(ctx: &ExperimentCtx) -> Result<String> {
    let budget = Budget::uniform(5, 3, 0); // 60% compute, the paper's setting
    let methods = vec![
        SchedulerKind::D2ft,
        SchedulerKind::Random,
        SchedulerKind::DPruningMG,
        SchedulerKind::DPruningM,
        SchedulerKind::MoeGshard,
    ];
    let mut out = section("Table I — workload variance @60% compute budget");
    let mut table = Table::new(&["Methods", "Workload Variance", "Sample-count Variance"]);
    for m in methods {
        // Variance is a property of the schedule, not of convergence:
        // a short run suffices.
        let mut cfg = TrainerConfig::quick(SyntheticKind::Cifar100Like, m, budget.clone());
        cfg.batches = ctx.batches(4);
        cfg.pretrain_batches = 2;
        let r = run_one(ctx, cfg)?;
        table.row(&[
            r.scheduler.clone(),
            format!("{:.2}", r.workload_variance),
            format!("{:.2}", r.sample_count_variance),
        ]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table II: per-subnet execution time (modelled) + top-1 @60% budget.
pub fn table2(ctx: &ExperimentCtx) -> Result<String> {
    let budget = Budget::uniform(5, 3, 0);
    let methods = vec![
        SchedulerKind::D2ft,
        SchedulerKind::Random,
        SchedulerKind::DPruningMG,
        SchedulerKind::DPruningM,
        SchedulerKind::MoeGshard,
    ];
    let mut out = section("Table II — execution time (V100-calibrated model) + top-1 @60%");
    let mut table = Table::new(&["Methods", "Makespan", "Mean device time", "Top-1"]);
    for m in methods {
        let mut cfg = TrainerConfig::quick(SyntheticKind::Cifar100Like, m, budget.clone());
        cfg.batches = ctx.batches(16);
        let r = run_one(ctx, cfg)?;
        table.row(&[
            r.scheduler.clone(),
            format!("{:.2}ms", r.makespan_ms),
            format!("{:.2}ms", r.mean_exec_ms),
            pct(r.test_top1),
        ]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table III: backward x forward score-metric combinations.
pub fn table3(ctx: &ExperimentCtx) -> Result<String> {
    // Paper setting: 2 p_f, 2 p_o, 1 p_s on Cars.
    let budget = Budget::uniform(5, 2, 2);
    let combos: Vec<(Metric, Metric)> = vec![
        (Metric::WeightMag, Metric::Fisher),
        (Metric::Fisher, Metric::WeightMag),
        (Metric::WeightMag, Metric::GradMag),
        (Metric::GradMag, Metric::WeightMag),
        (Metric::Fisher, Metric::Taylor),
        (Metric::Taylor, Metric::Fisher),
        (Metric::WeightMag, Metric::Taylor),
        (Metric::Taylor, Metric::WeightMag),
    ];
    let mut out = section("Table III — contribution-score metric combinations (Cars-like)");
    let mut table = Table::new(&["Backward score", "Forward score", "Top-1 accuracy"]);
    for (backward, forward) in combos {
        let mut cfg =
            TrainerConfig::quick(SyntheticKind::CarsLike, SchedulerKind::D2ft, budget.clone());
        cfg.batches = ctx.batches(16);
        cfg.scores = ScoreConfig { backward, forward };
        let r = run_one(ctx, cfg)?;
        table.row(&[backward.name().into(), forward.name().into(), pct(r.test_top1)]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table IV: subnet execution time for 1..5 micro-batches (p_f vs p_o) —
/// both the paper's V100 calibration and this host's measured step/eval
/// times on the context's backend.
pub fn table4(ctx: &ExperimentCtx) -> Result<String> {
    use std::time::Instant;
    let model = ExecTimeModel::paper();
    let mut out = section("Table IV — execution time vs micro-batch count");
    let mut table = Table::new(&[
        "Micro-batches", "p_f (paper model)", "p_o (paper model)",
        "p_f (this host)", "p_o (this host)", "fwd ratio (host)",
    ]);
    // Measured: run the fused step (p_f) / eval (p_o) on this host's
    // backend.
    let mut backend = ctx.provider.open(&BackendSel::full(ctx.seed))?;
    let mc = backend.config().clone();
    let mb = backend.micro_batch();
    let spec = crate::data::DatasetSpec::preset(SyntheticKind::Cifar100Like, mc.img_size, mb, 3);
    let d = spec.generate("train");
    let (x, y) = d.gather(&(0..mb).collect::<Vec<_>>());
    let masks = crate::schedule::MaskPair::ones(mc.depth, mc.heads);
    // warmup (and, on the XLA backend, compile)
    backend.step(&x, &y, &masks, 0.0)?;
    backend.eval(&x, &y, None)?;
    for k in 1..=5usize {
        let t0 = Instant::now();
        for _ in 0..k {
            backend.step(&x, &y, &masks, 0.0)?;
        }
        let full_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        for _ in 0..k {
            backend.eval(&x, &y, None)?;
        }
        let fwd_ms = t1.elapsed().as_secs_f64() * 1e3;
        table.row(&[
            k.to_string(),
            format!("{:.2}ms", model.time_ms(Op::Full, k)),
            format!("{:.2}ms", model.time_ms(Op::ForwardOnly, k)),
            format!("{:.2}ms", full_ms),
            format!("{:.2}ms", fwd_ms),
            format!("{:.2}", fwd_ms / full_ms),
        ]);
    }
    out.push_str(&table.render());
    out.push_str("\n(paper: forward ≈ 40% of full — the cost model's c_f = 0.4 calibration)\n");
    println!("{out}");
    Ok(out)
}

/// Table V: impact of the number of subnets (partition granularity).
pub fn table5(ctx: &ExperimentCtx) -> Result<String> {
    let mc = ctx.provider.model_config().clone();
    let budget = Budget::uniform(5, 2, 2);
    let mut out = section("Table V — impact of the number of subnets (CIFAR-100-like)");
    let mut table = Table::new(&["Number of subnets", "(paper analogue)", "Top-1 accuracy"]);
    let heads = mc.heads;
    let groups: Vec<usize> = (1..=3).filter(|g| heads % g == 0).collect();
    let analogues = ["74", "38", "26"];
    for (gi, g) in groups.iter().enumerate() {
        let mut cfg =
            TrainerConfig::quick(SyntheticKind::Cifar100Like, SchedulerKind::D2ft, budget.clone());
        cfg.batches = ctx.batches(16);
        cfg.partition_group = *g;
        let n_subnets = mc.depth * heads / g + 2;
        let r = run_one(ctx, cfg)?;
        table.row(&[
            n_subnets.to_string(),
            analogues.get(gi).unwrap_or(&"-").to_string(),
            pct(r.test_top1),
        ]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table VI: impact of micro-batch size at fixed compute.
pub fn table6(ctx: &ExperimentCtx) -> Result<String> {
    let base_mb = ctx.provider.micro_batch();
    let mut out = section("Table VI — impact of micro-batch size (CIFAR-100-like)");
    let mut table = Table::new(&["Micro-batch size", "Micro-batches/batch", "Top-1 accuracy"]);
    // paper: batch 80; 40% p_f, 40% p_o, 20% p_s at every granularity.
    let mut sizes: Vec<usize> = ctx.provider.mb_variants();
    sizes.push(base_mb);
    sizes.sort_unstable();
    for mbs in sizes {
        let micros = 80 / mbs;
        let n_full = micros * 2 / 5;
        let n_fwd = micros * 2 / 5;
        let mut cfg = TrainerConfig::quick(
            SyntheticKind::Cifar100Like,
            SchedulerKind::D2ft,
            Budget::uniform(micros, n_full, n_fwd),
        );
        // fewer batches here: each batch is 80/mbs micro-steps, so
        // the total trainstep count stays comparable across rows.
        cfg.batches = ctx.batches(8);
        cfg.micros_per_batch = micros;
        if mbs != base_mb {
            // Variant models share parameters; only the per-step batch
            // size differs (a lowered trainstep variant on XLA, a plain
            // argument on the native backend).
            cfg.micro_batch = Some(mbs);
        }
        let r = run_one(ctx, cfg)?;
        table.row(&[mbs.to_string(), micros.to_string(), pct(r.test_top1)]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table VII: memory heterogeneity ({9, 14, 19} large-memory devices).
pub fn table7(ctx: &ExperimentCtx) -> Result<String> {
    let mc = ctx.provider.model_config().clone();
    let mut out = section("Table VII — memory heterogeneity (CIFAR-100-like)");
    let mut table = Table::new(&["Large-memory devices", "Devices total", "Top-1 accuracy"]);
    // homogeneous reference
    let mut base = TrainerConfig::quick(
        SyntheticKind::Cifar100Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 2),
    );
    base.batches = ctx.batches(16);
    let r0 = run_one(ctx, base.clone())?;
    table.row(&["0 (homogeneous)".into(), format!("{}", mc.body_subnets() + 2), pct(r0.test_top1)]);
    // Up to half the body subnets merge into 2-head devices; the paper's
    // {9, 14, 19} settings scale down with the model (deduped after
    // clamping so small models don't rerun identical settings).
    let max_large = mc.body_subnets() / 2;
    let mut settings: Vec<usize> = [9usize, 14, 19].iter().map(|&n| n.min(max_large)).collect();
    settings.dedup();
    for n_large in settings {
        let mut cfg = base.clone();
        cfg.hetero = Some(HeteroSpec::memory(n_large));
        let r = run_one(ctx, cfg)?;
        let devices = mc.body_subnets() - n_large + 2;
        table.row(&[n_large.to_string(), devices.to_string(), pct(r.test_top1)]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table VIII: computational heterogeneity ({9, 14, 19} fast devices).
pub fn table8(ctx: &ExperimentCtx) -> Result<String> {
    let mc = ctx.provider.model_config().clone();
    let mut out = section("Table VIII — computational heterogeneity (CIFAR-100-like)");
    let mut table = Table::new(&["High-speed devices", "Top-1 accuracy"]);
    let mut base = TrainerConfig::quick(
        SyntheticKind::Cifar100Like,
        SchedulerKind::D2ft,
        Budget::uniform(5, 2, 2),
    );
    base.batches = ctx.batches(16);
    let r0 = run_one(ctx, base.clone())?;
    table.row(&["0 (homogeneous)".into(), pct(r0.test_top1)]);
    let max_fast = mc.body_subnets();
    let mut settings: Vec<usize> = [9usize, 14, 19].iter().map(|&n| n.min(max_fast)).collect();
    settings.dedup();
    for n_fast in settings {
        let mut cfg = base.clone();
        cfg.hetero = Some(HeteroSpec::compute(n_fast));
        let r = run_one(ctx, cfg)?;
        table.row(&[n_fast.to_string(), pct(r.test_top1)]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}

/// Table IX: Forward-Only effectiveness (1 p_f fixed, 0..4 p_o).
pub fn table9(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = section("Table IX — Forward-Only (p_o) effectiveness (Cars-like)");
    let mut table = Table::new(&["Forward setting", "Computational cost", "Top-1 accuracy"]);
    for n_po in 0..=4usize {
        let budget = Budget::uniform(5, 1, n_po);
        let mut cfg =
            TrainerConfig::quick(SyntheticKind::CarsLike, SchedulerKind::D2ft, budget.clone());
        cfg.batches = ctx.batches(16);
        let r = run_one(ctx, cfg)?;
        table.row(&[
            format!("{n_po}p_o"),
            pct(budget.compute_fraction(0.4)),
            pct(r.test_top1),
        ]);
    }
    out.push_str(&table.render());
    out.push_str("\n(paper shape: accuracy rises monotonically with p_o count)\n");
    println!("{out}");
    Ok(out)
}

/// Table X: bi-level vs Scaler-lambda scheduling.
pub fn table10(ctx: &ExperimentCtx) -> Result<String> {
    let budget = Budget::uniform(5, 2, 2); // paper: 2pf, 2po, 1ps
    let mut out = section("Table X — bi-level scheduling vs Scaler (CIFAR-100-like)");
    let mut table = Table::new(&["Optimization problem", "lambda", "Top-1 accuracy"]);
    let rows: Vec<(SchedulerKind, &str)> = vec![
        (SchedulerKind::D2ft, "N/A (bi-level)"),
        (SchedulerKind::Scaler(Lambda::Max), "Max"),
        (SchedulerKind::Scaler(Lambda::Min), "Min"),
        (SchedulerKind::Scaler(Lambda::Const(0.2)), "0.2"),
        (SchedulerKind::Scaler(Lambda::Const(0.1)), "0.1"),
    ];
    for (kind, lam) in rows {
        let mut cfg = TrainerConfig::quick(SyntheticKind::Cifar100Like, kind, budget.clone());
        cfg.batches = ctx.batches(16);
        let r = run_one(ctx, cfg)?;
        let name = if matches!(kind, SchedulerKind::D2ft) { "Bi-level" } else { "Scaler" };
        table.row(&[name.into(), lam.into(), pct(r.test_top1)]);
    }
    out.push_str(&table.render());
    println!("{out}");
    Ok(out)
}
