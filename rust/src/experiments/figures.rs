//! Figure regenerators: Fig. 1 / Fig. 2 (full fine-tuning accuracy vs
//! cost) and Fig. 3 (LoRA).

use anyhow::Result;

use super::registry::ExperimentCtx;
use super::tables::{budget_points, run_one, section};
use crate::backend::BackendProvider;
use crate::coordinator::{SchedulerKind, TrainerConfig};
use crate::data::SyntheticKind;
use crate::metrics::{pct, Table};
use crate::schedule::Budget;

/// Methods compared in Figs. 1 & 2 (paper §III-A baselines).
pub(super) fn figure_methods() -> Vec<SchedulerKind> {
    vec![
        SchedulerKind::D2ft,
        SchedulerKind::Random,
        SchedulerKind::DPruningM,
        SchedulerKind::DPruningMG,
        SchedulerKind::MoeGshard,
    ]
}

fn accuracy_sweep(ctx: &ExperimentCtx, dataset: SyntheticKind, title: &str) -> Result<String> {
    let mut out = section(title);
    // Standard fine-tuning reference (100% budget).
    let mut std_cfg =
        TrainerConfig::quick(dataset, SchedulerKind::Standard, Budget::uniform(5, 5, 0));
    std_cfg.batches = ctx.batches(16);
    let std_report = run_one(ctx, std_cfg)?;
    out.push_str(&format!(
        "Standard fine-tuning: top-1 {} (compute 100%, comm 100%)\n\n",
        pct(std_report.test_top1)
    ));
    let mut table = Table::new(&[
        "Method", "Budget", "Compute", "Comm", "Top-1", "WkldVar",
    ]);
    for (label, budget) in budget_points() {
        for method in figure_methods() {
            let mut cfg = TrainerConfig::quick(dataset, method, budget.clone());
            cfg.batches = ctx.batches(16);
            let r = run_one(ctx, cfg)?;
            table.row(&[
                r.scheduler.clone(),
                label.to_string(),
                pct(r.compute_fraction),
                pct(r.comm_fraction),
                pct(r.test_top1),
                format!("{:.3}", r.workload_variance),
            ]);
        }
    }
    out.push_str(&table.render());
    out.push('\n');
    Ok(out)
}

/// Fig. 1: CIFAR-100-like + Cars-like, full fine-tuning.
pub fn fig1(ctx: &ExperimentCtx) -> Result<String> {
    let mut out = accuracy_sweep(
        ctx,
        SyntheticKind::Cifar100Like,
        "Fig. 1a — full FT, CIFAR-100-like",
    )?;
    out.push_str(&accuracy_sweep(
        ctx,
        SyntheticKind::CarsLike,
        "Fig. 1b — full FT, Stanford-Cars-like",
    )?);
    println!("{out}");
    Ok(out)
}

/// Fig. 2: CIFAR-10-like, full fine-tuning.
pub fn fig2(ctx: &ExperimentCtx) -> Result<String> {
    let out = accuracy_sweep(
        ctx,
        SyntheticKind::Cifar10Like,
        "Fig. 2 — full FT, CIFAR-10-like",
    )?;
    println!("{out}");
    Ok(out)
}

/// Fig. 3: LoRA fine-tuning on Cars-like — D2FT vs Standard LoRA
/// (standard rank) vs LoRA w/ small rank at matched budgets.
pub fn fig3(ctx: &ExperimentCtx) -> Result<String> {
    let std_rank = ctx.provider.lora_standard_rank();
    anyhow::ensure!(std_rank > 0, "provider advertises no LoRA ranks");
    let mut out = section("Fig. 3 — LoRA fine-tuning, Stanford-Cars-like");
    let dataset = SyntheticKind::CarsLike;

    // Standard LoRA reference at the standard rank.
    let n_micro = 5;
    let base_cfg = |sched, budget, rank| {
        let mut c = TrainerConfig::quick(dataset, sched, budget);
        c.batches = ctx.batches(16);
        c.lora_rank = rank;
        c
    };
    let r_std = run_one(
        ctx,
        base_cfg(SchedulerKind::Standard, Budget::uniform(n_micro, n_micro, 0), std_rank),
    )?;
    out.push_str(&format!(
        "Standard LoRA (rank {std_rank}): top-1 {}\n\n",
        pct(r_std.test_top1)
    ));

    // Compute-cost comparison (paper: 95% / 75% / 60% of standard LoRA).
    let compute_settings: Vec<(&str, Budget)> = vec![
        ("~95% (3pf,2po)", Budget::uniform(5, 3, 2)),
        ("~75% (3pf,1po)", Budget::uniform(5, 3, 1)),
        ("~60% (3pf,0po)", Budget::uniform(5, 3, 0)),
    ];
    // Small-rank baselines matched to those budgets (paper: R=200/60/1 —
    // all strictly below the standard rank, so only smaller ranks
    // qualify). Rank 4 is additionally excluded on the XLA path: its
    // lowered HLO triggers a pathological multi-minute XLA-CPU compile;
    // the neighbouring ranks bracket the same cost range (on the native
    // backend rank 4 is the standard rank, so that filter is a no-op).
    let small_ranks: Vec<usize> = ctx
        .provider
        .lora_ranks()
        .into_iter()
        .filter(|&r| r < std_rank && r != 4)
        .collect();

    let mut table = Table::new(&["Setting", "Method", "Compute", "Comm", "Top-1"]);
    for (label, budget) in &compute_settings {
        let r = run_one(ctx, base_cfg(SchedulerKind::D2ft, budget.clone(), std_rank))?;
        table.row(&[
            label.to_string(),
            format!("D2FT LoRA (R={std_rank})"),
            pct(r.compute_fraction),
            pct(r.comm_fraction),
            pct(r.test_top1),
        ]);
    }
    for &rank in &small_ranks {
        let r = run_one(
            ctx,
            base_cfg(SchedulerKind::Standard, Budget::uniform(n_micro, n_micro, 0), rank),
        )?;
        table.row(&[
            "standard schedule".into(),
            format!("LoRA w/ small rank (R={rank})"),
            "100.0%".into(),
            "100.0%".into(),
            pct(r.test_top1),
        ]);
    }
    out.push_str("Compute-cost comparison:\n");
    out.push_str(&table.render());

    // Communication-cost comparison (paper: 90% / 70% / 50%).
    let comm_settings: Vec<(&str, Budget)> = vec![
        ("~90% (3pf,2po)", Budget::uniform(5, 3, 2)),
        ("~70% (3pf,1po)", Budget::uniform(5, 3, 1)),
        ("~50% (2pf,1po)", Budget::uniform(5, 2, 1)),
    ];
    let mut table = Table::new(&["Setting", "Method", "Comm", "Top-1"]);
    for (label, budget) in &comm_settings {
        let r = run_one(ctx, base_cfg(SchedulerKind::D2ft, budget.clone(), std_rank))?;
        table.row(&[
            label.to_string(),
            format!("D2FT LoRA (R={std_rank})"),
            pct(r.comm_fraction),
            pct(r.test_top1),
        ]);
    }
    out.push_str("\nCommunication-cost comparison:\n");
    out.push_str(&table.render());
    out.push('\n');
    println!("{out}");
    Ok(out)
}
