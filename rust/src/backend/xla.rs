//! XLA/PJRT backend: the original AOT-artifact execution path, wrapped
//! behind the [`Backend`] trait. Compiled only with the optional `xla`
//! cargo feature (requires the native `xla_extension` library at build
//! time and `make artifacts` at run time).

use std::path::Path;

use anyhow::Result;

use crate::backend::{Backend, BackendProvider, BackendSel, EvalOut, StepOut};
use crate::runtime::{ArtifactRegistry, Manifest, ModelConfig, ParamStore, Session, TrainState};
use crate::schedule::MaskPair;
use crate::tensor::Tensor;

/// Provider over an opened artifact directory.
pub struct XlaProvider {
    registry: ArtifactRegistry,
}

impl XlaProvider {
    /// Open an artifacts directory (see [`ArtifactRegistry::open`]).
    pub fn open(dir: &Path) -> Result<XlaProvider> {
        Ok(XlaProvider { registry: ArtifactRegistry::open(dir)? })
    }

    /// Open `$D2FT_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<XlaProvider> {
        Ok(XlaProvider { registry: ArtifactRegistry::open_default()? })
    }

    /// The underlying artifact registry.
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }
}

impl BackendProvider for XlaProvider {
    fn label(&self) -> &'static str {
        "xla"
    }

    fn model_config(&self) -> &ModelConfig {
        &self.registry.full_manifest.config
    }

    fn micro_batch(&self) -> usize {
        self.registry.full_manifest.micro_batch
    }

    fn mb_variants(&self) -> Vec<usize> {
        self.registry.full_manifest.mb_variants.clone()
    }

    fn lora_ranks(&self) -> Vec<usize> {
        self.registry.lora_ranks.clone()
    }

    fn lora_standard_rank(&self) -> usize {
        self.registry.lora_standard_rank
    }

    fn n_params(&self) -> usize {
        self.registry.full_manifest.n_params()
    }

    fn total_elems(&self) -> usize {
        self.registry.full_manifest.total_elems
    }

    fn open(&self, sel: &BackendSel) -> Result<Box<dyn Backend + '_>> {
        let manifest: &Manifest = if sel.lora_rank > 0 {
            self.registry.lora_manifest(sel.lora_rank)?
        } else {
            &self.registry.full_manifest
        };
        let mut session = Session::new(&self.registry, manifest)?;
        let mut variant_mb = None;
        if let Some(mb) = sel.micro_batch {
            if mb != manifest.micro_batch {
                session = session.with_trainstep_variant(mb)?;
                variant_mb = Some(mb);
            }
        }
        let state = TrainState::new(&ParamStore::load(manifest, self.registry.dir())?)?;
        Ok(Box::new(XlaBackend { session, state, manifest, variant_mb }))
    }
}

/// One opened PJRT session + its mutable training state.
pub struct XlaBackend<'a> {
    session: Session<'a>,
    state: TrainState,
    manifest: &'a Manifest,
    /// Trainstep micro-batch override (Table VI); eval/probe stay at the
    /// manifest's base size.
    variant_mb: Option<usize>,
}

impl<'a> Backend for XlaBackend<'a> {
    fn label(&self) -> &'static str {
        "xla"
    }

    fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    fn micro_batch(&self) -> usize {
        self.variant_mb.unwrap_or(self.manifest.micro_batch)
    }

    fn eval_micro_batch(&self) -> usize {
        self.manifest.micro_batch
    }

    fn supports_probe(&self) -> bool {
        // The scores artifact is lowered at the manifest's micro-batch;
        // variant trainsteps have no matching probe.
        self.variant_mb.is_none()
    }

    fn step(&mut self, x: &Tensor, y: &[i32], masks: &MaskPair, lr: f32) -> Result<StepOut> {
        let xl = self.session.x_literal(x)?;
        let yl = self.session.y_literal(y)?;
        self.session.step(&mut self.state, &xl, &yl, masks, lr)
    }

    fn eval(&self, x: &Tensor, y: &[i32], fwd_mask: Option<&Tensor>) -> Result<EvalOut> {
        let xl = self.session.x_literal(x)?;
        let yl = self.session.y_literal(y)?;
        self.session.eval(&self.state, &xl, &yl, fwd_mask)
    }

    fn score_probe(&self, x: &Tensor, y: &[i32]) -> Result<Tensor> {
        let xl = self.session.x_literal(x)?;
        let yl = self.session.y_literal(y)?;
        self.session.probe_scores(&self.state, &xl, &yl)
    }

    fn reset_momentum(&mut self) -> Result<()> {
        self.state.reset_momentum()
    }

    fn param(&self, name: &str) -> Option<Tensor> {
        let mut store = ParamStore::zeros_like(self.manifest);
        self.state.write_back(&mut store).ok()?;
        store.tensor(name)
    }

    fn param_names(&self) -> Vec<String> {
        self.manifest.params.iter().map(|p| p.name.clone()).collect()
    }
}
